"""Benchmark driver — one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only qps_recall,...]

Prints ``name,us_per_call,derived`` CSV summary lines (full per-point tables
land in results/bench/*.csv).
"""
from __future__ import annotations

import argparse
import csv
import os
import subprocess
import sys
import time
from pathlib import Path

import numpy as np

from benchmarks.common import (build_methods, build_seconds, dataset, emit,
                               emit_bench_json, gt_for, recall_at_k,
                               timed_search, workloads)
from repro.core.rfann import RNSGIndex


def bench_qps_recall(n, d, nq, quick):
    """Paper Fig. 6: QPS vs recall per method × workload (ef sweep)."""
    vecs, attrs = dataset(n, d)
    methods = build_methods(vecs, attrs, quick)
    wls = workloads(attrs, nq)
    k = 10
    rows = []
    for wname, ranges in wls.items():
        qv = dataset(nq, d, seed=91)[0]
        gt = gt_for(vecs, attrs, qv, ranges, k)
        for mname, ix in methods.items():
            for ef in ((16, 32, 64, 128) if mname != "brute" else (0,)):
                (ids, _, *_), qps = timed_search(ix, qv, ranges, k, max(ef, k))
                rows.append(dict(method=mname, workload=wname, ef=ef,
                                 recall=round(recall_at_k(ids, gt), 4),
                                 qps=round(qps, 1)))
    emit("qps_recall", rows, quiet=True)
    return rows


def bench_construction_time(n, d, quick):
    """Paper Fig. 7: index construction time."""
    vecs, attrs = dataset(n, d)
    methods = build_methods(vecs, attrs, quick)
    rows = [dict(method=m, build_seconds=round(build_seconds(ix), 2))
            for m, ix in methods.items()]
    emit("construction_time", rows, quiet=True)
    return rows


def bench_index_size(n, d, quick):
    """Paper Fig. 8: index memory (graph structure bytes; vectors excluded
    uniformly — every method stores the same payload)."""
    vecs, attrs = dataset(n, d)
    methods = build_methods(vecs, attrs, quick)
    rows = [dict(method=m, index_mb=round(ix.index_bytes / 2**20, 3))
            for m, ix in methods.items()]
    emit("index_size", rows, quiet=True)
    return rows


def bench_param_sensitivity(n, d, nq, quick):
    """Paper Fig. 9/10: RNSG sensitivity to ef_attribute / ef_spatial / m."""
    vecs, attrs = dataset(n, d)
    qv = dataset(nq, d, seed=91)[0]
    from repro.data.ann import mixed_workload
    ranges, _ = mixed_workload(attrs, nq, seed=1)
    k = 10
    gt = gt_for(vecs, attrs, qv, ranges, k)
    base = dict(m=16, ef_spatial=16, ef_attribute=24)
    sweeps = {"ef_attribute": (8, 24, 48), "ef_spatial": (8, 16, 32),
              "m": (8, 16, 32)}
    rows = []
    for pname, vals in sweeps.items():
        for v in vals:
            kw = dict(base, **{pname: v})
            ix = RNSGIndex.build(vecs, attrs, **kw)
            (ids, _, st), qps = timed_search(ix, qv, ranges, k, 64)
            rows.append(dict(param=pname, value=v,
                             build_seconds=round(ix.g.build_seconds, 2),
                             recall=round(recall_at_k(ids, gt), 4),
                             qps=round(qps, 1),
                             edges=ix.n_edges))
    emit("param_sensitivity", rows, quiet=True)
    return rows


def bench_vary_k(n, d, nq, quick):
    """Paper Fig. 11: recall/QPS across k."""
    vecs, attrs = dataset(n, d)
    ix = RNSGIndex.build(vecs, attrs, m=16, ef_spatial=16, ef_attribute=24)
    qv = dataset(nq, d, seed=91)[0]
    from repro.data.ann import mixed_workload
    ranges, _ = mixed_workload(attrs, nq, seed=1)
    rows = []
    for k in (1, 10, 20, 50):
        gt = gt_for(vecs, attrs, qv, ranges, k)
        (ids, _, _), qps = timed_search(ix, qv, ranges, k, max(64, 2 * k))
        rows.append(dict(k=k, recall=round(recall_at_k(ids, gt), 4),
                         qps=round(qps, 1)))
    emit("vary_k", rows, quiet=True)
    return rows


def bench_scalability(d, nq, quick):
    """Paper Fig. 12: build time / size / QPS-at-recall vs dataset size."""
    rows = []
    sizes = (2048, 4096, 8192) if quick else (4096, 8192, 16384, 32768)
    for n in sizes:
        vecs, attrs = dataset(n, d)
        ix = RNSGIndex.build(vecs, attrs, m=16, ef_spatial=16, ef_attribute=24)
        qv = dataset(nq, d, seed=91)[0]
        from repro.data.ann import mixed_workload
        ranges, _ = mixed_workload(attrs, nq, seed=1)
        gt = gt_for(vecs, attrs, qv, ranges, 10)
        (ids, _, st), qps = timed_search(ix, qv, ranges, 10, 64)
        rows.append(dict(n=n, build_seconds=round(ix.g.build_seconds, 2),
                         index_mb=round(ix.index_bytes / 2**20, 3),
                         recall=round(recall_at_k(ids, gt), 4),
                         qps=round(qps, 1),
                         mean_hops=round(float(st["hops"].mean()), 1)))
    emit("scalability", rows, quiet=True)
    return rows


def bench_planner(n, d, nq, quick):
    """Adaptive planner vs pure-graph vs brute across selectivity regimes.
    Narrow workloads must route to the fused range_scan (exact, faster);
    wide workloads must stay on beam search."""
    from repro.index.baselines import BruteForceIndex
    vecs, attrs = dataset(n, d)
    m = 24 if quick else 48
    ix = RNSGIndex.build(vecs, attrs, m=m, ef_spatial=m, ef_attribute=2 * m)
    brute = BruteForceIndex(vecs, attrs)
    wls = {
        "narrow_0.4pct": 0.004,
        "narrow_1pct": 0.01,
        "medium_10pct": 0.10,
        "wide_50pct": 0.50,
    }
    k, ef = 10, 64
    rows = []
    for wname, frac in wls.items():
        from repro.data.ann import selectivity_ranges
        ranges = selectivity_ranges(attrs, nq, frac, seed=17)
        qv = dataset(nq, d, seed=91)[0]
        gt = gt_for(vecs, attrs, qv, ranges, k)
        # planner warms twice: the second warm runs with a calibrated cost
        # model, so the timed repeats see the steady-state routing
        (pids, _, pst), pqps = timed_search(ix, qv, ranges, k, ef,
                                            warmups=2, plan="auto")
        (gids, _, _), gqps = timed_search(ix, qv, ranges, k, ef, plan="graph")
        (bids, _, _), bqps = timed_search(brute, qv, ranges, k, ef)
        for mname, ids, qps, sf in (
                ("planner", pids, pqps, round(float(pst["scan_frac"]), 3)),
                ("graph", gids, gqps, ""),
                ("brute", bids, bqps, "")):
            rows.append(dict(method=mname, workload=wname, ef=ef,
                             recall=round(recall_at_k(ids, gt), 4),
                             qps=round(qps, 1), scan_frac=sf))
    emit("planner", rows, quiet=True)
    return rows


def bench_search_substrate(n, d, nq, quick):
    """Pre/post-refactor comparison on the unified search substrate at
    narrow/medium/wide selectivities: the beam early-out (pre = legacy
    condition that burns steps_cap on under-filled pools) must cut
    narrow-range beam latency with bit-identical results, and the routed
    substrate paths ride on top."""
    import jax.numpy as jnp

    from repro.core.beam import beam_search_batch
    from repro.search import remap_ids, select_entry

    vecs, attrs = dataset(n, d)
    m = 24 if quick else 48
    ix = RNSGIndex.build(vecs, attrs, m=m, ef_spatial=m, ef_attribute=2 * m)
    sub = ix.substrate
    k, ef = 10, 64
    wls = {"narrow_1pct": 0.01, "medium_10pct": 0.10, "wide_50pct": 0.50}
    rows = []
    for wname, frac in wls.items():
        from repro.data.ann import selectivity_ranges
        ranges = selectivity_ranges(attrs, nq, frac, seed=23)
        qv = dataset(nq, d, seed=91)[0]
        gt = gt_for(vecs, attrs, qv, ranges, k)
        lo, hi = ix.rank_range(ranges)
        qj, loj, hij = jnp.asarray(qv), jnp.asarray(lo), jnp.asarray(hi)
        entry = select_entry(sub._rmq, sub._dist_c, loj, hij, ix.g.n)
        for tag, es in (("beam_pre_early_out", False),
                        ("beam_post_early_out", True)):
            args = (sub._vecs, sub._nbrs, qj, loj, hij, entry)
            np.asarray(beam_search_batch(*args, k=k, ef=ef,
                                         early_stop=es)[0])     # warm
            t0 = time.perf_counter()
            ids, _, _ = beam_search_batch(*args, k=k, ef=ef, early_stop=es)
            ids = np.asarray(ids)
            dt = time.perf_counter() - t0
            rec = recall_at_k(remap_ids(ix.g.order, ids), gt)
            rows.append(dict(method=tag, workload=wname, ef=ef,
                             recall=round(rec, 4), qps=round(nq / dt, 1)))
        for plan in ("graph", "auto"):
            (ids, _, st), qps = timed_search(ix, qv, ranges, k, ef,
                                             warmups=2, plan=plan)
            rows.append(dict(method=f"substrate_{plan}", workload=wname,
                             ef=ef, recall=round(recall_at_k(ids, gt), 4),
                             qps=round(qps, 1)))
    emit("search_substrate", rows, quiet=True)
    pre = next(r for r in rows if r["method"] == "beam_pre_early_out"
               and r["workload"] == "narrow_1pct")
    post = next(r for r in rows if r["method"] == "beam_post_early_out"
                and r["workload"] == "narrow_1pct")
    emit_bench_json("substrate", {
        "n": n, "d": d, "nq": nq, "k": k, "ef": ef,
        "rows": rows,
        "narrow_early_out_speedup": round(
            post["qps"] / max(pre["qps"], 1e-9), 3),
    })
    return rows


def bench_mesh_auto(n, d, nq, quick):
    """Mesh-path strategy routing: ``DistributedRFANN(plan="auto")`` vs the
    graph-only mesh path on a shard_map mesh across selectivity regimes.

    Needs a multi-device mesh; with a single local device the bench re-execs
    itself under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
    flag must be set before jax initializes its backends) and returns the
    rows the child wrote to results/bench/mesh_auto.csv."""
    import jax

    root = Path(__file__).resolve().parent.parent
    if jax.device_count() == 1 and not os.environ.get("RNSG_MESH_BENCH"):
        env = dict(os.environ,
                   XLA_FLAGS="--xla_force_host_platform_device_count=8",
                   RNSG_MESH_BENCH="1",
                   PYTHONPATH=os.pathsep.join(
                       [str(root / "src"),
                        os.environ.get("PYTHONPATH", "")]).rstrip(os.pathsep))
        r = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--only", "mesh_auto",
             "--n", str(n)] + ([] if quick else ["--full"]),
            env=env, cwd=str(root), capture_output=True, text=True,
            timeout=3600)
        if r.returncode != 0:
            raise RuntimeError(f"mesh_auto subprocess failed:\n{r.stdout}\n"
                               f"{r.stderr}")
        with open(root / "results" / "bench" / "mesh_auto.csv") as f:
            return list(csv.DictReader(f))

    from repro.data.ann import selectivity_ranges
    from repro.search import rank_interval
    from repro.serving.distributed import DistributedRFANN

    devices = jax.device_count()
    shards = devices
    n -= n % shards                       # corpus must be a shard multiple
    vecs, attrs = dataset(n, d)
    m = 16 if quick else 32
    mesh = jax.make_mesh((devices,), ("data",))
    dist = DistributedRFANN(vecs, attrs, n_shards=shards, mesh=mesh,
                            m=m, ef_spatial=m, ef_attribute=2 * m)
    k, ef = 10, 64
    wls = {"narrow_1pct": 0.01, "medium_10pct": 0.10, "wide_50pct": 0.50}
    rows = []
    for wname, frac in wls.items():
        ranges = selectivity_ranges(attrs, nq, frac, seed=29)
        qv = dataset(nq, d, seed=91)[0]
        gt = gt_for(vecs, attrs, qv, ranges, k)
        lo, hi = rank_interval(dist.attrs_sorted, ranges)
        strat, _ = dist.mesh_substrate.plan_strategies(lo, hi, k=k, ef=ef,
                                                       mode="auto")
        scan_frac = round(float((strat == 0).mean()), 3)
        for plan in ("graph", "auto"):
            (ids, _), qps = timed_search(dist, qv, ranges, k, ef, plan=plan)
            rows.append(dict(method=f"mesh_{plan}", workload=wname, ef=ef,
                             recall=round(recall_at_k(np.asarray(ids), gt), 4),
                             qps=round(qps, 1),
                             scan_frac=scan_frac if plan == "auto" else "",
                             devices=devices, shards=shards))
    emit("mesh_auto", rows, quiet=True)
    return rows


def bench_async_cache(n, d, nq, quick):
    """Async + cached search substrate:

    * cache rows — repeat-query QPS with the ``SearchCache`` installed
      (second pass: every row a hit, zero device work) vs the uncached
      substrate, per plan, asserting bit-identical results;
    * async rows — the 8-shard ``DistributedRFANN`` local path with async
      per-shard dispatch (enqueue all shards, block at the merge) vs the
      sequential dispatch+block baseline, asserting identical merged top-k.
    """
    from repro.search import SearchCache
    from repro.serving.distributed import DistributedRFANN

    vecs, attrs = dataset(n, d)
    m = 24 if quick else 48
    ix = RNSGIndex.build(vecs, attrs, m=m, ef_spatial=m, ef_attribute=2 * m)
    qv = dataset(nq, d, seed=91)[0]
    from repro.data.ann import mixed_workload
    ranges, _ = mixed_workload(attrs, nq, seed=1)
    k, ef = 10, 64
    rows = []
    for plan in ("graph", "auto"):
        ix.install_cache(None)
        (u_ids, u_d, _), u_qps = timed_search(ix, qv, ranges, k, ef,
                                              warmups=2, plan=plan)
        cache = SearchCache(max_bytes=64 << 20)
        ix.install_cache(cache)
        fill = ix.search(qv, ranges, k=k, ef=ef, plan=plan)   # populate
        # timed repeats are all-hit passes (timed_search warms once first)
        (c_ids, c_d, c_st), c_qps = timed_search(ix, qv, ranges, k, ef,
                                                 plan=plan)
        ix.install_cache(None)
        # the cache contract: hits are bit-identical to the dispatch that
        # POPULATED them (fill vs cached).  u_ids is not part of the flag —
        # under plan="auto" online recalibration between the uncached and
        # fill passes can legitimately flip a boundary query's routing
        identical = bool(np.array_equal(fill.ids, c_ids)
                         and np.array_equal(fill.dists, c_d))
        rows.append(dict(method="cache_repeat", plan=plan,
                         qps_base=round(u_qps, 1), qps_new=round(c_qps, 1),
                         speedup=round(c_qps / max(u_qps, 1e-9), 2),
                         identical=identical,
                         detail=f"hits={c_st['cache_hits']}"))
    n8 = n - n % 8
    dist = DistributedRFANN(vecs[:n8], attrs[:n8], n_shards=8, m=m,
                            ef_spatial=m, ef_attribute=2 * m)
    # paired best-of-8: the seq/async gap on CPU is a few percent (the
    # device queue serializes shard kernels either way; async only overlaps
    # host-side prep with device compute), smaller than machine-load drift
    # across separate measurement windows — so each repeat times both modes
    # back to back and the bests come from the same windows
    for plan in ("graph", "auto"):
        results, best = {}, {False: np.inf, True: np.inf}
        for mode in (False, True):              # warm both jit paths first
            dist.async_dispatch = mode
            dist.search(qv, ranges, k=k, ef=ef, plan=plan)
        for _ in range(8):
            for mode in (False, True):
                dist.async_dispatch = mode
                t0 = time.perf_counter()
                results[mode] = dist.search(qv, ranges, k=k, ef=ef, plan=plan)
                best[mode] = min(best[mode], time.perf_counter() - t0)
        (s_ids, s_d), (a_ids, a_d) = results[False], results[True]
        s_qps, a_qps = nq / best[False], nq / best[True]
        identical = bool(np.array_equal(s_ids, a_ids)
                         and np.array_equal(s_d, a_d))
        rows.append(dict(method="async_local_8shard", plan=plan,
                         qps_base=round(s_qps, 1), qps_new=round(a_qps, 1),
                         speedup=round(a_qps / max(s_qps, 1e-9), 2),
                         identical=identical, detail="seq->async"))
    emit("async_cache", rows, quiet=True)
    return rows


def bench_beam_width(n, d, nq, quick):
    """Kernel-fused batched beam expansion: ``beam_width ∈ {1, 2, 4, 8}`` ×
    narrow (1%) / wide (50%) selectivities, direct ``beam_search_batch``
    dispatches (no planner noise).  ``beam_width=1`` is the legacy
    single-expansion path — the PR-4-era baseline every other row is
    compared against.

    Emits results/bench/beam_width.csv plus the machine-readable
    BENCH_beam.json trajectory (repo root + results/bench copy: QPS /
    recall / ndist / hops per point, baseline QPS, and the best
    narrow-range speedup at equal recall)."""
    import jax.numpy as jnp

    from repro.core.beam import beam_search_batch
    from repro.search import remap_ids, select_entry

    vecs, attrs = dataset(n, d)
    m = 24 if quick else 48
    ix = RNSGIndex.build(vecs, attrs, m=m, ef_spatial=m, ef_attribute=2 * m)
    sub = ix.substrate
    k, ef = 10, 64
    wls = {"narrow_1pct": 0.01, "wide_50pct": 0.50}
    widths = (1, 2, 4, 8)
    rows = []
    for wname, frac in wls.items():
        from repro.data.ann import selectivity_ranges
        ranges = selectivity_ranges(attrs, nq, frac, seed=17)
        qv = dataset(nq, d, seed=91)[0]
        gt = gt_for(vecs, attrs, qv, ranges, k)
        lo, hi = ix.rank_range(ranges)
        qj, loj, hij = jnp.asarray(qv), jnp.asarray(lo), jnp.asarray(hi)
        entry = select_entry(sub._rmq, sub._dist_c, loj, hij, ix.g.n)
        args = (sub._vecs, sub._nbrs, qj, loj, hij, entry)
        ids_bw4 = None
        for bw in widths:
            np.asarray(beam_search_batch(*args, k=k, ef=ef,
                                         beam_width=bw)[0])          # warm
            best = np.inf
            for _ in range(3 if quick else 5):
                t0 = time.perf_counter()
                ids, _, st = beam_search_batch(*args, k=k, ef=ef,
                                               beam_width=bw)
                ids = np.asarray(ids)
                best = min(best, time.perf_counter() - t0)
            if bw == 4:
                ids_bw4 = ids
            rec = recall_at_k(remap_ids(ix.g.order, ids), gt)
            rows.append(dict(workload=wname, beam_width=bw, ef=ef,
                             qps=round(nq / best, 1),
                             recall=round(rec, 4),
                             ndist=round(float(np.asarray(st["ndist"]).mean()), 1),
                             hops=round(float(np.asarray(st["hops"]).mean()), 1)))
        # kernel smoke: the blocked gather/top-k path (interpret mode on
        # CPU, Mosaic on TPU) must reproduce the jnp path exactly — this is
        # what makes the CI bench-beam-smoke step kernel-sensitive
        nk = min(nq, 50)
        ids_k = np.asarray(beam_search_batch(
            args[0], args[1], args[2][:nk], args[3][:nk], args[4][:nk],
            args[5][:nk], k=k, ef=ef, beam_width=4, use_kernel=True)[0])
        if not np.array_equal(ids_k, ids_bw4[:nk]):
            raise AssertionError(
                f"{wname}: kernel-path beam (beam_width=4) diverged from "
                f"the jnp path")
    emit("beam_width", rows, quiet=True)
    nb, best_narrow = _beam_width_best(rows)
    summary = {
        "n": n, "d": d, "nq": nq, "k": k, "ef": ef,
        "widths": list(widths),
        "baseline": {w: next(r for r in rows if r["workload"] == w
                             and r["beam_width"] == 1) for w in wls},
        "rows": rows,
        "narrow_speedup_at_equal_recall": round(
            best_narrow["qps"] / max(nb["qps"], 1e-9), 3) if best_narrow
        else None,
        "narrow_best_beam_width": best_narrow["beam_width"] if best_narrow
        else None,
    }
    emit_bench_json("beam", summary)
    return rows


def _beam_width_best(rows, tol: float = 0.001):
    """(baseline bw=1 narrow row, best narrow row at >=baseline-tol recall
    or None) — the single eligibility rule behind both BENCH_beam.json and
    the console summary line."""
    nb = next(r for r in rows if r["workload"] == "narrow_1pct"
              and r["beam_width"] == 1)
    eligible = [r for r in rows if r["workload"] == "narrow_1pct"
                and r["beam_width"] > 1 and r["recall"] >= nb["recall"] - tol]
    return nb, max(eligible, key=lambda r: r["qps"], default=None)


def bench_quantized(n, d, nq, quick):
    """Quantized distance scoring (int8/bf16 corpus + exact f32 rerank) vs
    the f32 baseline: recall@k and QPS per precision × narrow (1%) / wide
    (50%) selectivity × forced scan / beam strategy, plus scored
    bytes-per-vector.  Every quantized row is asserted to return the exact
    f32 top-k id set (the rerank contract) — this is what makes the CI
    bench-quant-smoke step a kernel-parity gate for int8/bf16 in interpret
    mode.

    Emits results/bench/quantized.csv plus BENCH_quant.json (repo root +
    results/bench copy).  ``speedup_note`` documents the host caveat: on
    CPU the Pallas kernels run in interpret mode, where the quantized pass
    emulates dequantization element-wise and pays the rerank on top — the
    memory-bandwidth win that motivates quantization (4× fewer scored
    bytes for int8) is a TPU property, so interpret-mode QPS ratios are
    correctness trajectories, not hardware speedups."""
    from repro.data.ann import selectivity_ranges
    from repro.kernels.quantize import quantize_corpus

    vecs, attrs = dataset(n, d)
    m = 24 if quick else 48
    ix = RNSGIndex.build(vecs, attrs, m=m, ef_spatial=m, ef_attribute=2 * m)
    precisions = ("f32", "bf16", "int8")
    for prec in precisions[1:]:
        ix.install_quantized(prec)
    bpv = {"f32": float(4 * d)}
    for prec in precisions[1:]:
        bpv[prec] = quantize_corpus(
            np.asarray(ix.substrate._vecs), prec).bytes_per_vector
    k, ef = 10, 64
    wls = {"narrow_1pct": 0.01, "wide_50pct": 0.50}
    rows = []
    for wname, frac in wls.items():
        ranges = selectivity_ranges(attrs, nq, frac, seed=17)
        qv = dataset(nq, d, seed=91)[0]
        gt = gt_for(vecs, attrs, qv, ranges, k)
        for strategy in ("scan", "beam"):
            base_ids, base_rec = None, None
            for prec in precisions:
                (ids, dd, _), qps = timed_search(
                    ix, qv, ranges, k, ef, plan=strategy, precision=prec)
                ids = np.asarray(ids)
                rec = recall_at_k(ids, gt)
                if prec == "f32":
                    base_ids, base_rec = np.sort(ids, 1), rec
                elif strategy == "scan":
                    # scan is exact at any ef: the rerank contract makes the
                    # quantized id set bit-compatible with the f32 oracle
                    if not np.array_equal(np.sort(ids, 1), base_ids):
                        raise AssertionError(
                            f"{wname}/scan/{prec}: quantized ids diverged "
                            f"from the f32 oracle (rerank contract broken)")
                elif rec < base_rec - 0.05:
                    # beam traversal under quantization may legally visit a
                    # slightly different frontier at sub-covering ef (exact
                    # id parity at ef >= |slice| is asserted in the tests);
                    # here the recall envelope must hold
                    raise AssertionError(
                        f"{wname}/beam/{prec}: recall {rec:.4f} fell below "
                        f"the f32 envelope {base_rec:.4f} - 0.05")
                rows.append(dict(
                    workload=wname, strategy=strategy, precision=prec,
                    ef=ef, recall=round(rec, 4),
                    qps=round(qps, 1), bytes_per_vector=round(bpv[prec], 2)))
    emit("quantized", rows, quiet=True)

    def row(w, s, p):
        return next(r for r in rows if r["workload"] == w
                    and r["strategy"] == s and r["precision"] == p)

    ns_f32 = row("narrow_1pct", "scan", "f32")
    ns_int8 = row("narrow_1pct", "scan", "int8")
    speedup = round(ns_int8["qps"] / max(ns_f32["qps"], 1e-9), 3)
    import jax
    interpret = jax.default_backend() != "tpu"
    summary = {
        "n": n, "d": d, "nq": nq, "k": k, "ef": ef,
        "precisions": list(precisions),
        "bytes_per_vector": {p: round(v, 2) for p, v in bpv.items()},
        "scored_bytes_ratio_f32_over_int8": round(
            bpv["f32"] / bpv["int8"], 2),
        "rows": rows,
        "exact_scan_id_parity_vs_f32": True,  # asserted per scan row above
        "narrow_scan_int8_speedup_vs_f32": speedup,
        "narrow_scan_int8_recall": ns_int8["recall"],
        "speedup_note": (
            "CPU host: Pallas runs in interpret mode, which emulates the "
            "int8 dequant element-wise and adds the f32 rerank pass on "
            "top, so the >=1.3x bandwidth-bound scan win is not realizable "
            "here; the 4x scored-bytes reduction is the hardware-invariant "
            "metric" if interpret and speedup < 1.3 else
            "measured on a compiled backend"),
    }
    emit_bench_json("quant", summary)
    return rows


def bench_streaming(n, d, nq, quick):
    """Streaming ingest trajectory: QPS + recall as the mutable delta
    segment grows to {0, 1%, 5%, 20%} of the live corpus, with a
    compaction (and its pause-time histogram sample) folding the delta
    into the base between fraction points.

    Emits results/bench/streaming.csv plus BENCH_stream.json (repo root +
    results/bench copy): per-fraction QPS/recall rows, compaction pause
    p50/p99 from the obs histograms, and the post-compaction identity
    check (a compacted index must answer exactly like its base — the
    delta is empty).  Interpret-mode wall times on CPU are correctness
    trajectories, not hardware numbers."""
    from repro.data.ann import selectivity_ranges
    from repro.obs import MetricsRegistry
    from repro.streaming import StreamingRFANN

    vecs, attrs = dataset(n, d)
    m = 16 if quick else 32
    s = StreamingRFANN(vecs, attrs, m=m, ef_spatial=m, ef_attribute=2 * m,
                       max_delta=10**9)
    reg = MetricsRegistry()
    s.install_metrics(reg)
    rng = np.random.default_rng(41)
    k, ef = 10, 64
    fractions = (0.0, 0.01, 0.05, 0.20)
    rows = []
    for frac in fractions:
        live_now = s.stats()["n_live"]
        target = int(round(frac * live_now / max(1.0 - frac, 1e-9)))
        for _ in range(target - s.stats()["n_delta"]):
            s.insert(rng.standard_normal(d).astype(np.float32),
                     float(rng.random()))
        lv, la, li = s.live_items()
        ranges = selectivity_ranges(la, nq, 0.10, seed=23)
        qv = dataset(nq, d, seed=91)[0]
        gt_rows = gt_for(lv, la, qv, ranges, k)
        gt = np.where(gt_rows >= 0, li[np.maximum(gt_rows, 0)], -1)
        res, qps = timed_search(s, qv, ranges, k, ef, plan="auto")
        rec = recall_at_k(np.asarray(res.ids), gt)
        st = s.stats()
        rows.append(dict(delta_frac_target=frac,
                         delta_frac=round(st["delta_frac"], 4),
                         n_live=st["n_live"], n_delta=st["n_delta"],
                         recall=round(rec, 4), qps=round(qps, 1)))
        if st["n_delta"]:       # fold in before the next fraction point
            s.compact(wait=True)
    assert s.stats()["n_delta"] == 0 and s.stats()["tombstones"] == 0
    emit("streaming", rows, quiet=True)
    snap = reg.snapshot()
    pause = snap["histograms"].get("stream_compaction_pause_ms", {})
    build = snap["histograms"].get("stream_compaction_build_ms", {})
    summary = {
        "n": n, "d": d, "nq": nq, "k": k, "ef": ef,
        "fractions": list(fractions),
        "rows": rows,
        "compactions": s.compactions,
        "compaction_pause_ms": {"p50": round(pause.get("p50", 0.0), 3),
                                "p99": round(pause.get("p99", 0.0), 3)},
        "compaction_build_ms": {"p50": round(build.get("p50", 0.0), 3),
                                "p99": round(build.get("p99", 0.0), 3)},
        "recall_floor": min(r["recall"] for r in rows),
        "note": ("pause = locked swap only; the rebuild runs off-lock on "
                 "the worker thread (build histogram)"),
    }
    emit_bench_json("stream", summary)
    s.close()
    return rows


def bench_kernels(quick):
    """Kernel microbench (interpret mode on CPU: correctness + derived
    roofline terms; wall numbers are *not* TPU times)."""
    import jax.numpy as jnp
    from repro.kernels.ops import gather_dist, l2dist
    from repro.kernels.ref import gather_dist_ref, l2dist_ref
    rng = np.random.default_rng(0)
    rows = []
    for (q, nn, dd) in ((128, 1024, 128), (256, 4096, 128)):
        a = jnp.asarray(rng.standard_normal((q, dd)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((nn, dd)), jnp.float32)
        for name, fn in (("l2dist_pallas", l2dist), ("l2dist_ref", l2dist_ref)):
            np.asarray(fn(a, b))
            t0 = time.perf_counter()
            np.asarray(fn(a, b))
            dt = time.perf_counter() - t0
            flops = 2 * q * nn * dd
            rows.append(dict(kernel=name, shape=f"{q}x{nn}x{dd}",
                             us_per_call=round(dt * 1e6, 1),
                             gflops_at_wall=round(flops / dt / 1e9, 2),
                             tpu_roofline_us=round(flops / 197e12 * 1e6, 2)))
    x = jnp.asarray(rng.standard_normal((4096, 128)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 4096, 64), jnp.int32)
    qv = jnp.asarray(rng.standard_normal(128), jnp.float32)
    for name, fn in (("gather_dist_pallas", gather_dist),
                     ("gather_dist_ref", gather_dist_ref)):
        np.asarray(fn(x, ids, qv))
        t0 = time.perf_counter()
        np.asarray(fn(x, ids, qv))
        dt = time.perf_counter() - t0
        byts = 64 * 128 * 4
        rows.append(dict(kernel=name, shape="64of4096x128",
                         us_per_call=round(dt * 1e6, 1),
                         gflops_at_wall=round(64 * 3 * 128 / dt / 1e9, 3),
                         tpu_roofline_us=round(byts / 819e9 * 1e6, 3)))
    emit("kernels", rows, quiet=True)
    return rows


def bench_build(n, d, quick):
    """Sharded construction + persistence: build wall vs shard count (with
    bit-identity to the single-host build asserted per point), and the
    directory-format save/restore wall vs an O(n²) rebuild.

    Needs a multi-device mesh; with one local device the bench re-execs
    itself under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    (same pattern as mesh_auto) and returns the child's rows."""
    import jax

    root = Path(__file__).resolve().parent.parent
    if jax.device_count() == 1 and not os.environ.get("RNSG_BUILD_BENCH"):
        env = dict(os.environ,
                   XLA_FLAGS="--xla_force_host_platform_device_count=8",
                   RNSG_BUILD_BENCH="1",
                   PYTHONPATH=os.pathsep.join(
                       [str(root / "src"),
                        os.environ.get("PYTHONPATH", "")]).rstrip(os.pathsep))
        r = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--only", "build",
             "--n", str(n)] + ([] if quick else ["--full"]),
            env=env, cwd=str(root), capture_output=True, text=True,
            timeout=3600)
        if r.returncode != 0:
            raise RuntimeError(f"build subprocess failed:\n{r.stdout}\n"
                               f"{r.stderr}")
        with open(root / "results" / "bench" / "build.csv") as f:
            return list(csv.DictReader(f))

    import tempfile

    from repro.core.build_sharded import build_rnsg_sharded
    from repro.core.construction import build_rnsg
    from repro.index import io as index_io

    vecs, attrs = dataset(n, d)
    m = 16 if quick else 32
    t0 = time.perf_counter()
    ref = build_rnsg(vecs, attrs, m=m, ef_spatial=m, ef_attribute=2 * m)
    t_single = time.perf_counter() - t0
    rows = [dict(method="build_single", shards=1,
                 seconds=round(t_single, 3), restore_seconds="",
                 identical=1)]
    fields = ("vecs", "attrs", "nbrs", "order", "centroid", "dist_c", "rmq")
    shard_counts = [s for s in (1, 2, 4, 8) if s <= jax.device_count()]
    build_curve = {}
    identical_all = True
    for S in shard_counts:
        t0 = time.perf_counter()
        g = build_rnsg_sharded(vecs, attrs, n_shards=S, m=m, ef_spatial=m,
                               ef_attribute=2 * m)
        dt = time.perf_counter() - t0
        same = all(np.array_equal(getattr(ref, f), getattr(g, f))
                   for f in fields)
        identical_all &= same
        build_curve[str(S)] = round(dt, 3)
        rows.append(dict(method="build_sharded", shards=S,
                         seconds=round(dt, 3), restore_seconds="",
                         identical=int(same)))

    idx = RNSGIndex(ref)
    idx.install_quantized("int8")
    persist = {}
    with tempfile.TemporaryDirectory() as td:
        for S in (1, 8):
            p = os.path.join(td, f"idx{S}")
            t0 = time.perf_counter()
            index_io.save_index(idx, p, shards=S)
            t_save = time.perf_counter() - t0
            t0 = time.perf_counter()
            got = index_io.load_index(p)
            t_restore = time.perf_counter() - t0
            assert np.array_equal(got.g.nbrs, ref.nbrs)
            persist[str(S)] = dict(save_seconds=round(t_save, 3),
                                   restore_seconds=round(t_restore, 3))
            rows.append(dict(method="persist", shards=S,
                             seconds=round(t_save, 3),
                             restore_seconds=round(t_restore, 3),
                             identical=1))
    emit("build", rows, quiet=True)
    t_restore_best = min(p["restore_seconds"] for p in persist.values())
    emit_bench_json("build", dict(
        n=n, d=d, m=m, devices=jax.device_count(),
        single_host_build_seconds=round(t_single, 3),
        sharded_build_seconds=build_curve,
        bit_identical_all_shard_counts=bool(identical_all),
        persist=persist,
        restore_speedup_vs_rebuild=round(
            t_single / max(t_restore_best, 1e-9), 1),
        speedup_note="shard walls measured on fake host-platform devices "
                     "sharing one CPU's cores, so the per-shard walls do "
                     "not drop with S locally; on a real multi-chip mesh "
                     "the O(n²d) KNN + prune FLOPs shard linearly. The "
                     "restore-vs-rebuild ratio is hardware-honest (both "
                     "sides run on this host)."))
    return rows


def bench_wal(n, d, quick):
    """Durability cost curve: insert throughput under each WAL sync
    policy (none attached, sync=none, group-commit batch, fsync-always)
    plus the recovery path (checkpoint restore + tail replay) wall.

    Emits results/bench/wal.csv + BENCH_wal.json.  The interesting
    derived numbers are the overhead ratios vs the no-WAL baseline —
    ``batch`` should sit close to 1x while ``always`` pays one fsync
    per acknowledged mutation — and replayed-records/sec on recovery.
    """
    import shutil
    import tempfile

    from repro.index import io as iio
    from repro.streaming import StreamingRFANN
    from repro.streaming import wal as walmod

    n0 = min(n, 2048)
    vecs, attrs = dataset(n0, d)
    m = 8 if quick else 16
    n_ops = 400 if quick else 4000
    tmp = Path(tempfile.mkdtemp(prefix="bench_wal_"))
    rows = []
    replay_row = {}
    try:
        for sync in ("nowal", "none", "batch", "always"):
            s = StreamingRFANN(vecs, attrs, m=m, ef_spatial=m,
                               ef_attribute=2 * m, max_delta=10**9)
            wd = tmp / f"wal_{sync}"
            if sync != "nowal":
                s.attach_wal(wd, sync=sync)
            rng = np.random.default_rng(17)
            t0 = time.perf_counter()
            for _ in range(n_ops):
                s.insert(rng.standard_normal(d).astype(np.float32),
                         float(rng.random()))
            dt = time.perf_counter() - t0
            st = s._wal.stats() if sync != "nowal" else {}
            rows.append(dict(sync=sync, ops=n_ops,
                             ops_per_s=round(n_ops / dt, 1),
                             us_per_op=round(dt / n_ops * 1e6, 1),
                             fsyncs=st.get("fsyncs", 0),
                             wal_bytes=st.get("bytes_written", 0)))
            if sync == "batch":     # recovery wall off the batch log
                ck = tmp / "ckpt"
                iio.save_index(
                    StreamingRFANN(vecs, attrs, m=m, ef_spatial=m,
                                   ef_attribute=2 * m, max_delta=10**9), ck)
                s._wal.flush()
                t0 = time.perf_counter()
                rec = StreamingRFANN.recover(ck, wd, attach=False)
                t_rec = time.perf_counter() - t0
                assert rec.stats()["n_live"] == s.stats()["n_live"]
                replay_row = dict(
                    recovery_seconds=round(t_rec, 3),
                    replayed_records=n_ops,
                    replay_records_per_s=round(n_ops / max(t_rec, 1e-9), 1),
                    segments=walmod.describe(wd)["segments"])
                rec.close()
            s.close()
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    emit("wal", rows, quiet=True)
    base = next(r for r in rows if r["sync"] == "nowal")["us_per_op"]
    summary = {
        "n0": n0, "d": d, "n_ops": n_ops,
        "rows": rows,
        "overhead_vs_nowal": {
            r["sync"]: round(r["us_per_op"] / max(base, 1e-9), 2)
            for r in rows if r["sync"] != "nowal"},
        "recovery": replay_row,
        "note": ("inserts pay an O(delta) host re-sort that grows over the "
                 "run; it is identical across sync policies, so the ratios "
                 "isolate the WAL cost"),
    }
    emit_bench_json("wal", summary)
    return rows


ALL = ["qps_recall", "construction_time", "index_size", "param_sensitivity",
       "vary_k", "scalability", "planner", "search_substrate", "mesh_auto",
       "async_cache", "beam_width", "quantized", "streaming", "kernels",
       "build", "wal"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--n", type=int, default=0)
    args = ap.parse_args()
    quick = not args.full
    n = args.n or (4096 if quick else 16384)
    d = 32 if quick else 64
    nq = 200 if quick else 1000
    only = set(args.only.split(",")) if args.only else set(ALL)

    print("name,us_per_call,derived")
    t_all = time.perf_counter()
    if "qps_recall" in only:
        rows = bench_qps_recall(n, d, nq, quick)
        best = max((r for r in rows if r["method"] == "rnsg"
                    and r["workload"] == "mixed"), key=lambda r: r["recall"])
        print(f"qps_recall,{1e6/best['qps']:.1f},"
              f"rnsg_mixed_recall={best['recall']}@qps={best['qps']}")
    if "construction_time" in only:
        rows = bench_construction_time(n, d, quick)
        rn = next(r for r in rows if r["method"] == "rnsg")
        sg = next(r for r in rows if r["method"] == "segtree")
        print(f"construction_time,{rn['build_seconds']*1e6:.0f},"
              f"rnsg={rn['build_seconds']}s_segtree={sg['build_seconds']}s")
    if "index_size" in only:
        rows = bench_index_size(n, d, quick)
        rn = next(r for r in rows if r["method"] == "rnsg")
        sg = next(r for r in rows if r["method"] == "segtree")
        print(f"index_size,0,rnsg={rn['index_mb']}MB_segtree={sg['index_mb']}MB"
              f"_ratio={sg['index_mb']/max(rn['index_mb'],1e-9):.1f}x")
    if "param_sensitivity" in only:
        rows = bench_param_sensitivity(n, d, nq, quick)
        print(f"param_sensitivity,0,points={len(rows)}")
    if "vary_k" in only:
        rows = bench_vary_k(n, d, nq, quick)
        print(f"vary_k,0,recall@50={rows[-1]['recall']}")
    if "scalability" in only:
        rows = bench_scalability(d, nq, quick)
        print(f"scalability,0,qps_{rows[0]['n']}={rows[0]['qps']}"
              f"_qps_{rows[-1]['n']}={rows[-1]['qps']}")
    if "planner" in only:
        rows = bench_planner(n, d, nq, quick)
        print("method,workload,ef,recall,qps,scan_frac")
        for r in rows:
            print(f"{r['method']},{r['workload']},{r['ef']},{r['recall']},"
                  f"{r['qps']},{r['scan_frac']}")
        np_ = next(r for r in rows if r["method"] == "planner"
                   and r["workload"] == "narrow_1pct")
        ng = next(r for r in rows if r["method"] == "graph"
                  and r["workload"] == "narrow_1pct")
        wp = next(r for r in rows if r["method"] == "planner"
                  and r["workload"] == "wide_50pct")
        print(f"planner,{1e6/np_['qps']:.1f},"
              f"narrow_speedup_vs_graph={np_['qps']/max(ng['qps'],1e-9):.2f}x"
              f"_narrow_recall={np_['recall']}vs{ng['recall']}"
              f"_narrow_scan_frac={np_['scan_frac']}"
              f"_wide_scan_frac={wp['scan_frac']}")
    if "search_substrate" in only:
        rows = bench_search_substrate(n, d, nq, quick)
        pre = next(r for r in rows if r["method"] == "beam_pre_early_out"
                   and r["workload"] == "narrow_1pct")
        post = next(r for r in rows if r["method"] == "beam_post_early_out"
                    and r["workload"] == "narrow_1pct")
        print(f"search_substrate,{1e6/post['qps']:.1f},"
              f"narrow_beam_early_out_speedup={post['qps']/max(pre['qps'],1e-9):.2f}x"
              f"_recall={post['recall']}vs{pre['recall']}")
    if "mesh_auto" in only:
        rows = bench_mesh_auto(n, d, nq, quick)
        print("method,workload,ef,recall,qps,scan_frac,devices,shards")
        for r in rows:
            print(f"{r['method']},{r['workload']},{r['ef']},{r['recall']},"
                  f"{r['qps']},{r['scan_frac']},{r['devices']},{r['shards']}")
        na = next(r for r in rows if r["method"] == "mesh_auto"
                  and r["workload"] == "narrow_1pct")
        ng = next(r for r in rows if r["method"] == "mesh_graph"
                  and r["workload"] == "narrow_1pct")
        print(f"mesh_auto,{1e6/float(na['qps']):.1f},"
              f"narrow_speedup_vs_mesh_graph="
              f"{float(na['qps'])/max(float(ng['qps']),1e-9):.2f}x"
              f"_narrow_recall={na['recall']}vs{ng['recall']}"
              f"_narrow_scan_frac={na['scan_frac']}")
    if "async_cache" in only:
        rows = bench_async_cache(n, d, nq, quick)
        print("method,plan,qps_base,qps_new,speedup,identical,detail")
        for r in rows:
            print(f"{r['method']},{r['plan']},{r['qps_base']},{r['qps_new']},"
                  f"{r['speedup']},{r['identical']},{r['detail']}")
        cg = next(r for r in rows if r["method"] == "cache_repeat"
                  and r["plan"] == "graph")
        ag = next(r for r in rows if r["method"] == "async_local_8shard"
                  and r["plan"] == "auto")
        print(f"async_cache,{1e6/float(cg['qps_new']):.1f},"
              f"cache_repeat_speedup={cg['speedup']}x"
              f"_identical={cg['identical']}"
              f"_async_vs_seq={ag['speedup']}x")
    if "beam_width" in only:
        rows = bench_beam_width(n, d, nq, quick)
        print("workload,beam_width,ef,qps,recall,ndist,hops")
        for r in rows:
            print(f"{r['workload']},{r['beam_width']},{r['ef']},{r['qps']},"
                  f"{r['recall']},{r['ndist']},{r['hops']}")
        nb, bb = _beam_width_best(rows)
        if bb is None:
            print(f"beam_width,{1e6/nb['qps']:.1f},"
                  f"no_width_matches_baseline_recall={nb['recall']}")
        else:
            print(f"beam_width,{1e6/bb['qps']:.1f},"
                  f"narrow_speedup_bw{bb['beam_width']}="
                  f"{bb['qps']/max(nb['qps'],1e-9):.2f}x"
                  f"_recall={bb['recall']}vs{nb['recall']}"
                  f"_hops={bb['hops']}vs{nb['hops']}")
    if "quantized" in only:
        rows = bench_quantized(n, d, nq, quick)
        print("workload,strategy,precision,ef,recall,qps,bytes_per_vector")
        for r in rows:
            print(f"{r['workload']},{r['strategy']},{r['precision']},"
                  f"{r['ef']},{r['recall']},{r['qps']},"
                  f"{r['bytes_per_vector']}")
        f32 = next(r for r in rows if r["workload"] == "narrow_1pct"
                   and r["strategy"] == "scan" and r["precision"] == "f32")
        i8 = next(r for r in rows if r["workload"] == "narrow_1pct"
                  and r["strategy"] == "scan" and r["precision"] == "int8")
        print(f"quantized,{1e6/i8['qps']:.1f},"
              f"narrow_scan_int8_speedup={i8['qps']/max(f32['qps'],1e-9):.2f}x"
              f"_recall={i8['recall']}vs{f32['recall']}"
              f"_bytes={i8['bytes_per_vector']}vs{f32['bytes_per_vector']}")
    if "streaming" in only:
        rows = bench_streaming(n, d, nq, quick)
        print("delta_frac_target,delta_frac,n_live,n_delta,recall,qps")
        for r in rows:
            print(f"{r['delta_frac_target']},{r['delta_frac']},{r['n_live']},"
                  f"{r['n_delta']},{r['recall']},{r['qps']}")
        r0 = rows[0]
        r20 = rows[-1]
        print(f"streaming,{1e6/r20['qps']:.1f},"
              f"recall_delta0={r0['recall']}_delta20pct={r20['recall']}"
              f"_qps_ratio={r20['qps']/max(r0['qps'],1e-9):.2f}x")
    if "kernels" in only:
        rows = bench_kernels(quick)
        for r in rows:
            print(f"kernel_{r['kernel']},{r['us_per_call']},"
                  f"shape={r['shape']}_tpu_roofline_us={r['tpu_roofline_us']}")
    if "build" in only:
        rows = bench_build(n, d, quick)
        print("method,shards,seconds,restore_seconds,identical")
        for r in rows:
            print(f"{r['method']},{r['shards']},{r['seconds']},"
                  f"{r['restore_seconds']},{r['identical']}")
        single = next(r for r in rows if r["method"] == "build_single")
        restores = [r for r in rows if r["method"] == "persist"]
        best = min(float(r["restore_seconds"]) for r in restores)
        ident = all(int(r["identical"]) for r in rows)
        print(f"build,{float(single['seconds'])*1e6:.0f},"
              f"restore_speedup_vs_rebuild="
              f"{float(single['seconds'])/max(best,1e-9):.1f}x"
              f"_bit_identical={ident}")
    if "wal" in only:
        rows = bench_wal(n, d, quick)
        print("sync,ops,ops_per_s,us_per_op,fsyncs,wal_bytes")
        for r in rows:
            print(f"{r['sync']},{r['ops']},{r['ops_per_s']},"
                  f"{r['us_per_op']},{r['fsyncs']},{r['wal_bytes']}")
        nw = next(r for r in rows if r["sync"] == "nowal")
        bt = next(r for r in rows if r["sync"] == "batch")
        aw = next(r for r in rows if r["sync"] == "always")
        print(f"wal,{aw['us_per_op']},"
              f"batch_overhead={bt['us_per_op']/max(nw['us_per_op'],1e-9):.2f}x"
              f"_always_overhead="
              f"{aw['us_per_op']/max(nw['us_per_op'],1e-9):.2f}x"
              f"_always_fsyncs={aw['fsyncs']}")
    print(f"# total benchmark wall: {time.perf_counter()-t_all:.1f}s")


if __name__ == "__main__":
    main()
