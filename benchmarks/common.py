"""Shared benchmark harness: datasets, method registry, timing, CSV output."""
from __future__ import annotations

import csv
import os
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.rfann import RNSGIndex
from repro.data.ann import (ground_truth, make_attrs, make_vectors,
                            mixed_workload, recall_at_k, selectivity_ranges)
from repro.index.baselines import (BruteForceIndex, MRNGIndex,
                                   SegmentTreeIndex)

RESULTS = Path(__file__).resolve().parent.parent / "results" / "bench"


def dataset(n: int, d: int, seed: int = 0):
    vecs = make_vectors(n, d, seed=seed)
    attrs = make_attrs(n, seed=seed)
    return vecs, attrs


def gt_for(vecs, attrs, queries, ranges, k):
    order = np.argsort(attrs, kind="stable")
    gt_r, _ = ground_truth(vecs[order], attrs[order], queries, ranges, k)
    return np.where(gt_r >= 0, order[np.maximum(gt_r, 0)], -1)


def workloads(attrs, nq: int, seed: int = 1) -> Dict[str, np.ndarray]:
    """The paper's protocol: mixed 2^0..2^-9 plus fixed 1% / 10% / 25%."""
    mixed, _ = mixed_workload(attrs, nq, seed=seed)
    return {
        "mixed": mixed,
        "sel_1pct": selectivity_ranges(attrs, nq, 0.01, seed=seed + 1),
        "sel_10pct": selectivity_ranges(attrs, nq, 0.10, seed=seed + 2),
        "sel_25pct": selectivity_ranges(attrs, nq, 0.25, seed=seed + 3),
    }


def build_methods(vecs, attrs, quick: bool = True) -> Dict[str, object]:
    # paper-proportionate parameters (the paper uses m=150..300,
    # ef_attribute ≈ 5..30× m at n=1M; scaled to CPU-sized n)
    m = 24 if quick else 48
    out = {}
    t0 = time.perf_counter()
    out["rnsg"] = RNSGIndex.build(vecs, attrs, m=m, ef_spatial=m,
                                  ef_attribute=2 * m)
    out["mrng-infilter"] = MRNGIndex(vecs, attrs, m=m, ef_spatial=2 * m,
                                     mode="infilter")
    out["mrng-postfilter"] = MRNGIndex(vecs, attrs, m=m, ef_spatial=2 * m,
                                       mode="postfilter")
    out["segtree"] = SegmentTreeIndex(vecs, attrs, m=m, ef_spatial=2 * m)
    out["brute"] = BruteForceIndex(vecs, attrs)
    return out


def build_seconds(ix) -> float:
    if hasattr(ix, "g"):
        return ix.g.build_seconds
    return getattr(ix, "build_seconds", 0.0)


def timed_search(ix, qv, ranges, k, ef, repeats: int = 2, warmups: int = 1,
                 **search_kw):
    for _ in range(max(warmups, 1)):             # warm the jit (planner paths
        ix.search(qv, ranges, k=k, ef=ef, **search_kw)   # may recalibrate)
    best = np.inf
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = ix.search(qv, ranges, k=k, ef=ef, **search_kw)
        best = min(best, time.perf_counter() - t0)
    return out, len(qv) / best


def emit(name: str, rows: List[Dict], quiet: bool = False):
    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / f"{name}.csv"
    if rows:
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
    if not quiet:
        for r in rows:
            print(",".join(str(v) for v in r.values()))
    return path
