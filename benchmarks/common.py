"""Shared benchmark harness: datasets, method registry, timing, CSV output."""
from __future__ import annotations

import csv
import json
import os
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.rfann import RNSGIndex
from repro.data.ann import (ground_truth, make_attrs, make_vectors,
                            mixed_workload, selectivity_ranges)
from repro.index.baselines import (BruteForceIndex, MRNGIndex,
                                   SegmentTreeIndex)

ROOT = Path(__file__).resolve().parent.parent
RESULTS = ROOT / "results" / "bench"


def recall_at_k(found: np.ndarray, gt: np.ndarray, *,
                gt_dists: Optional[np.ndarray] = None,
                found_dists: Optional[np.ndarray] = None,
                eps: float = 1e-5) -> float:
    """recall@k = |found ∩ gt| / |gt-valid|, micro-averaged over queries.

    The canonical benchmark/acceptance metric, with two edge rules every
    caller needs:

    * ``k > |interval|`` — ground-truth rows are ``-1``-padded when the rank
      slice holds fewer than k points; the denominator is the count of
      *valid* gt entries per row (fully-empty rows are skipped entirely), so
      an exact method scores 1.0 on sub-k slices instead of being penalized
      for ids that do not exist.
    * tie handling — when both ``gt_dists`` and ``found_dists`` are given, a
      found id outside the gt id set still counts as a hit if its distance
      is within ``eps`` of the row's worst valid gt distance: equidistant
      points at the k-th boundary are interchangeable, and a different
      tie-break order must not read as recall loss.  Per-row hits stay
      capped at the valid-gt count so recall never exceeds 1.0.
    """
    found = np.asarray(found)
    gt = np.asarray(gt)
    tot, hit = 0, 0
    for i in range(len(gt)):
        gs = {int(x) for x in gt[i] if x >= 0}
        if not gs:
            continue
        fs = [int(x) for x in found[i] if x >= 0]
        row_hit = len(gs & set(fs))
        if gt_dists is not None and found_dists is not None:
            kth = max(float(d) for d, g in zip(gt_dists[i], gt[i]) if g >= 0)
            row_hit += sum(
                1 for j, x in enumerate(found[i])
                if x >= 0 and int(x) not in gs
                and float(found_dists[i][j]) <= kth + eps)
            row_hit = min(row_hit, len(gs))
        hit += row_hit
        tot += len(gs)
    return hit / max(tot, 1)


def emit_bench_json(stem: str, summary: dict) -> Path:
    """Write a machine-readable ``BENCH_<stem>.json`` trajectory file at the
    repo root (tracked across PRs) plus a copy under results/bench/."""
    RESULTS.mkdir(parents=True, exist_ok=True)
    text = json.dumps(summary, indent=2, sort_keys=True)
    for path in (ROOT / f"BENCH_{stem}.json", RESULTS / f"BENCH_{stem}.json"):
        with open(path, "w") as f:
            f.write(text + "\n")
    return ROOT / f"BENCH_{stem}.json"


def dataset(n: int, d: int, seed: int = 0):
    vecs = make_vectors(n, d, seed=seed)
    attrs = make_attrs(n, seed=seed)
    return vecs, attrs


def gt_for(vecs, attrs, queries, ranges, k):
    order = np.argsort(attrs, kind="stable")
    gt_r, _ = ground_truth(vecs[order], attrs[order], queries, ranges, k)
    return np.where(gt_r >= 0, order[np.maximum(gt_r, 0)], -1)


def workloads(attrs, nq: int, seed: int = 1) -> Dict[str, np.ndarray]:
    """The paper's protocol: mixed 2^0..2^-9 plus fixed 1% / 10% / 25%."""
    mixed, _ = mixed_workload(attrs, nq, seed=seed)
    return {
        "mixed": mixed,
        "sel_1pct": selectivity_ranges(attrs, nq, 0.01, seed=seed + 1),
        "sel_10pct": selectivity_ranges(attrs, nq, 0.10, seed=seed + 2),
        "sel_25pct": selectivity_ranges(attrs, nq, 0.25, seed=seed + 3),
    }


def build_methods(vecs, attrs, quick: bool = True) -> Dict[str, object]:
    # paper-proportionate parameters (the paper uses m=150..300,
    # ef_attribute ≈ 5..30× m at n=1M; scaled to CPU-sized n)
    m = 24 if quick else 48
    out = {}
    t0 = time.perf_counter()
    out["rnsg"] = RNSGIndex.build(vecs, attrs, m=m, ef_spatial=m,
                                  ef_attribute=2 * m)
    out["mrng-infilter"] = MRNGIndex(vecs, attrs, m=m, ef_spatial=2 * m,
                                     mode="infilter")
    out["mrng-postfilter"] = MRNGIndex(vecs, attrs, m=m, ef_spatial=2 * m,
                                       mode="postfilter")
    out["segtree"] = SegmentTreeIndex(vecs, attrs, m=m, ef_spatial=2 * m)
    out["brute"] = BruteForceIndex(vecs, attrs)
    return out


def build_seconds(ix) -> float:
    if hasattr(ix, "g"):
        return ix.g.build_seconds
    return getattr(ix, "build_seconds", 0.0)


def timed_search(ix, qv, ranges, k, ef, repeats: int = 2, warmups: int = 1,
                 **search_kw):
    for _ in range(max(warmups, 1)):             # warm the jit (planner paths
        ix.search(qv, ranges, k=k, ef=ef, **search_kw)   # may recalibrate)
    best = np.inf
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = ix.search(qv, ranges, k=k, ef=ef, **search_kw)
        best = min(best, time.perf_counter() - t0)
    return out, len(qv) / best


def emit(name: str, rows: List[Dict], quiet: bool = False):
    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / f"{name}.csv"
    if rows:
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
    if not quiet:
        for r in rows:
            print(",".join(str(v) for v in r.values()))
    return path
