"""Top-k routed MoE with sort-based (permutation) dispatch.

Two distributed modes, both expressed inside ``shard_map`` so the collective
pattern is explicit:

* **EP** (``E % tp == 0``): experts sharded over the ``model`` axis; tokens are
  dispatched locally into an ``(E, C, D)`` buffer, exchanged with a tiled
  ``all_to_all`` over ``model``, processed by the local expert slice, and
  returned with the reverse ``all_to_all``.
* **fallback** (``E`` not divisible, e.g. mixtral's 8 experts on TP=16):
  expert weights are replicated inside the block (the FSDP all-gather is
  inserted by GSPMD at the shard_map boundary) and every device processes its
  own tokens' experts locally.

The single-device path (``mesh=None``) runs the same local math — used by the
smoke tests and the pure-jnp MoE oracle tests.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.layers import norm
from repro.models.params import ModelDims
from repro.parallel.sharding import shard_map_compat


def _route(xt: jax.Array, router: jax.Array, k: int):
    logits = (xt @ router).astype(jnp.float32)               # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)                    # (T,k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # load-balance aux (Switch-style) + router z-loss
    e = router.shape[-1]
    me = jnp.mean(probs, axis=0)                             # (E,)
    ce = jnp.mean(jax.nn.one_hot(eidx[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce) + 1e-3 * jnp.mean(jnp.square(jax.nn.logsumexp(logits, -1)))
    return gates, eidx, aux


def _capacity(t: int, k: int, e: int, cf: float) -> int:
    return max(1, int(math.ceil(t * k * cf / e)))


def _sort_dispatch(xt: jax.Array, eidx: jax.Array, e: int, c: int):
    t, k = eidx.shape
    d = xt.shape[-1]
    flat_e = eidx.reshape(-1)                                 # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    e_s = flat_e[order]
    tok_s = order // k
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * k) - starts[e_s]
    keep = pos < c
    slot = jnp.where(keep, e_s * c + pos, e * c)              # overflow -> dump row
    buf = jnp.zeros((e * c + 1, d), xt.dtype).at[slot].set(xt[tok_s])
    return buf[:e * c].reshape(e, c, d), (tok_s, slot, keep, order)


def _combine(out_buf: jax.Array, meta, gates: jax.Array, t: int):
    tok_s, slot, keep, order = meta
    e_c, d = out_buf.shape[0] * out_buf.shape[1], out_buf.shape[-1]
    padded = jnp.concatenate([out_buf.reshape(e_c, d),
                              jnp.zeros((1, d), out_buf.dtype)], axis=0)
    y_s = padded[slot] * gates.reshape(-1)[order][:, None].astype(out_buf.dtype)
    return jnp.zeros((t, d), out_buf.dtype).at[tok_s].add(y_s)


def _expert_ffn(buf: jax.Array, w_in, w_gate, w_out) -> jax.Array:
    h = jnp.einsum("ecd,edf->ecf", buf, w_in)
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    h = jax.nn.silu(g) * h
    return jnp.einsum("ecf,efd->ecd", h, w_out)


def _moe_local(xt, router, w_in, w_gate, w_out, k: int, cf: float,
               axis_names: Tuple[str, ...] = (), tp_axis: Optional[str] = None,
               e_total: int = 0):
    """Per-device MoE body. If tp_axis is set, experts are EP-sharded over it."""
    t = xt.shape[0]
    e_local = w_in.shape[0]
    e = e_total or e_local
    gates, eidx, aux = _route(xt, router, k)
    c = _capacity(t, k, e, cf)
    buf, meta = _sort_dispatch(xt, eidx, e, c)
    if tp_axis is not None:
        buf = jax.lax.all_to_all(buf, tp_axis, split_axis=0, concat_axis=1,
                                 tiled=True)                  # (E_loc, tp*C, D)
        out = _expert_ffn(buf, w_in, w_gate, w_out)
        out = jax.lax.all_to_all(out, tp_axis, split_axis=1, concat_axis=0,
                                 tiled=True)                  # (E, C, D)
    else:
        out = _expert_ffn(buf, w_in, w_gate, w_out)
    y = _combine(out, meta, gates, t)
    if axis_names:
        aux = jax.lax.pmean(aux, axis_names)
    return y, aux


def moe_ffn(x: jax.Array, p: Dict, cfg: ArchConfig, dm: ModelDims,
            mesh=None) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,D) -> (y, aux_loss). Pre-norm applied here."""
    h = norm(x, p, cfg.norm)
    b, s, d = h.shape
    t = b * s
    xt = h.reshape(t, d)

    if mesh is None or math.prod(mesh.shape.values()) == 1:
        y, aux = _moe_local(xt, p["router"], p["w_in"], p["w_gate"], p["w_out"],
                            cfg.moe_top_k, cfg.capacity_factor)
        return y.reshape(b, s, d), aux

    names = tuple(mesh.shape.keys())                          # e.g. (pod,data,model)
    tp = mesh.shape.get("model", 1)
    ep = tp > 1 and dm.e % tp == 0
    # shard tokens as widely as divisibility allows
    tok_axes = ()
    for cand in (names, names[:-1], names[:1], ()):
        if math.prod(mesh.shape[a] for a in cand) and \
           t % max(1, math.prod(mesh.shape[a] for a in cand)) == 0:
            tok_axes = cand
            break
    tok_spec = P(tok_axes if tok_axes else None, None)
    w_spec = P("model", None, None) if ep else P(None, None, None)

    body = partial(_moe_local, k=cfg.moe_top_k, cf=cfg.capacity_factor,
                   axis_names=names, tp_axis="model" if ep else None,
                   e_total=dm.e)
    y, aux = shard_map_compat(
        body, mesh,
        in_specs=(tok_spec, P(None, None), w_spec, w_spec,
                  P("model", None, None) if ep else P(None, None, None)),
        out_specs=(tok_spec, P()),
    )(xt, p["router"], p["w_in"], p["w_gate"], p["w_out"])
    return y.reshape(b, s, d), aux
