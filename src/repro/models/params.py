"""Parameter-spec system.

A model is described by a flat ``{path: ParamSpec}`` dict produced once from the
``ArchConfig`` + ``ShardPlan``.  Shapes, logical sharding axes and initializers
live in one place, so ``init_params``, ``param_shapes`` (abstract, for the
dry-run) and the sharding tree can never drift apart.

Paths are '/'-separated; a leading ``blocks`` component with logical axis
``layer`` on dim0 denotes group-stacked parameters consumed by ``lax.scan``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ShardPlan:
    """Static padding / mesh-divisibility plan (tp=1 ⇒ no padding)."""
    tp: int = 1                 # size of the 'model' mesh axis
    fsdp: int = 1               # size of the 'data' mesh axis
    dp: int = 1                 # size of the 'pod' mesh axis
    vocab_multiple: int = 1     # pad vocab to this multiple (256 on real meshes)

    def pad_heads(self, h: int) -> int:
        return round_up(h, self.tp) if h else h

    def pad_vocab(self, v: int) -> int:
        m = max(self.vocab_multiple, self.tp)
        return round_up(v, m)


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]   # per-dim logical axis ("layer","fsdp","tp","vocab","expert",None)
    init: str = "normal"                 # normal | zeros | ones
    scale: float = 0.02
    dtype: str = "bfloat16"


@dataclass
class ModelDims:
    """Resolved (padded) dimensions used by the compute graph."""
    h: int          # padded q heads
    kh: int         # padded kv heads
    hd: int         # head dim
    vocab: int      # padded vocab
    d: int
    f: int
    e: int          # experts
    groups: int     # scan groups
    group_layers: int
    ssm_h: int
    ssm_p: int
    ssm_n: int
    d_inner: int
    conv_dim: int
    conv_w: int
    enc_layers: int


def resolve_dims(cfg: ArchConfig, plan: ShardPlan) -> ModelDims:
    h = plan.pad_heads(cfg.n_heads)
    kh = plan.pad_heads(cfg.n_kv_heads)
    if h and kh and h % kh:
        kh = round_up(kh, math.gcd(h, kh))  # keep grouping integral
        while h % kh:
            kh += plan.tp
    vocab = plan.pad_vocab(cfg.vocab_size)
    if cfg.family == "hybrid":
        group_layers = cfg.attn_every
    elif cfg.family == "vlm":
        group_layers = cfg.cross_attn_every
    else:
        group_layers = 1
    groups = cfg.n_layers // group_layers
    assert groups * group_layers == cfg.n_layers, (cfg.name, cfg.n_layers, group_layers)
    d_inner = cfg.ssm_expand * cfg.d_model if cfg.ssm_state else 0
    ssm_h = d_inner // cfg.ssm_head_dim if cfg.ssm_state else 0
    conv_dim = d_inner + 2 * cfg.ssm_state if cfg.ssm_state else 0   # x + B + C (n_groups=1)
    return ModelDims(
        h=h, kh=kh, hd=cfg.resolved_head_dim, vocab=vocab, d=cfg.d_model, f=cfg.d_ff,
        e=cfg.n_experts, groups=groups, group_layers=group_layers,
        ssm_h=ssm_h, ssm_p=cfg.ssm_head_dim, ssm_n=cfg.ssm_state, d_inner=d_inner,
        conv_dim=conv_dim, conv_w=cfg.ssm_conv, enc_layers=cfg.enc_layers,
    )


# ----------------------------------------------------------------------
# Spec builders (one sub-builder per sublayer kind)
# ----------------------------------------------------------------------
def _attn_specs(cfg: ArchConfig, dm: ModelDims, prefix: str, L: int, cross: bool = False) -> Dict[str, ParamSpec]:
    d, h, kh, hd = dm.d, dm.h, dm.kh, dm.hd
    dt = cfg.dtype
    lay = ("layer",) if L else ()
    Ls = (L,) if L else ()
    s: Dict[str, ParamSpec] = {
        f"{prefix}/wq": ParamSpec(Ls + (d, h * hd), lay + ("fsdp", "tp"), dtype=dt),
        f"{prefix}/wk": ParamSpec(Ls + (d, kh * hd), lay + ("fsdp", "tp"), dtype=dt),
        f"{prefix}/wv": ParamSpec(Ls + (d, kh * hd), lay + ("fsdp", "tp"), dtype=dt),
        f"{prefix}/wo": ParamSpec(Ls + (h * hd, d), lay + ("tp", "fsdp"), dtype=dt),
        f"{prefix}/norm": ParamSpec(Ls + (d,), lay + (None,), init="ones", dtype=dt),
    }
    if cfg.qkv_bias:
        s[f"{prefix}/bq"] = ParamSpec(Ls + (h * hd,), lay + ("tp",), init="zeros", dtype=dt)
        s[f"{prefix}/bk"] = ParamSpec(Ls + (kh * hd,), lay + ("tp",), init="zeros", dtype=dt)
        s[f"{prefix}/bv"] = ParamSpec(Ls + (kh * hd,), lay + ("tp",), init="zeros", dtype=dt)
    if cfg.norm == "layernorm":
        s[f"{prefix}/norm_b"] = ParamSpec(Ls + (d,), lay + (None,), init="zeros", dtype=dt)
    return s


def _mlp_specs(cfg: ArchConfig, dm: ModelDims, prefix: str, L: int) -> Dict[str, ParamSpec]:
    d, f, dt = dm.d, dm.f, cfg.dtype
    lay = ("layer",) if L else ()
    Ls = (L,) if L else ()
    s = {
        f"{prefix}/w_in": ParamSpec(Ls + (d, f), lay + ("fsdp", "tp"), dtype=dt),
        f"{prefix}/w_out": ParamSpec(Ls + (f, d), lay + ("tp", "fsdp"), dtype=dt),
        f"{prefix}/norm": ParamSpec(Ls + (d,), lay + (None,), init="ones", dtype=dt),
    }
    if cfg.mlp_act == "swiglu":
        s[f"{prefix}/w_gate"] = ParamSpec(Ls + (d, f), lay + ("fsdp", "tp"), dtype=dt)
    if cfg.norm == "layernorm":
        s[f"{prefix}/norm_b"] = ParamSpec(Ls + (d,), lay + (None,), init="zeros", dtype=dt)
    return s


def _moe_specs(cfg: ArchConfig, dm: ModelDims, prefix: str, L: int) -> Dict[str, ParamSpec]:
    d, f, e, dt = dm.d, dm.f, dm.e, cfg.dtype
    lay = ("layer",) if L else ()
    Ls = (L,) if L else ()
    s = {
        f"{prefix}/router": ParamSpec(Ls + (d, e), lay + ("fsdp", None), dtype=dt),
        f"{prefix}/w_in": ParamSpec(Ls + (e, d, f), lay + ("expert", "fsdp", "tp"), dtype=dt),
        f"{prefix}/w_gate": ParamSpec(Ls + (e, d, f), lay + ("expert", "fsdp", "tp"), dtype=dt),
        f"{prefix}/w_out": ParamSpec(Ls + (e, f, d), lay + ("expert", "tp", "fsdp"), dtype=dt),
        f"{prefix}/norm": ParamSpec(Ls + (d,), lay + (None,), init="ones", dtype=dt),
    }
    if cfg.norm == "layernorm":
        s[f"{prefix}/norm_b"] = ParamSpec(Ls + (d,), lay + (None,), init="zeros", dtype=dt)
    return s


def _ssm_specs(cfg: ArchConfig, dm: ModelDims, prefix: str, L: int) -> Dict[str, ParamSpec]:
    d, dt = dm.d, cfg.dtype
    di, n, H = dm.d_inner, dm.ssm_n, dm.ssm_h
    in_dim = 2 * di + 2 * n + H          # z, x, B, C, dt
    lay = ("layer",) if L else ()
    Ls = (L,) if L else ()
    return {
        f"{prefix}/w_in": ParamSpec(Ls + (d, in_dim), lay + ("fsdp", "tp"), dtype=dt),
        f"{prefix}/conv_w": ParamSpec(Ls + (dm.conv_w, dm.conv_dim), lay + (None, "tp"), dtype=dt),
        f"{prefix}/conv_b": ParamSpec(Ls + (dm.conv_dim,), lay + ("tp",), init="zeros", dtype=dt),
        f"{prefix}/a_log": ParamSpec(Ls + (H,), lay + ("tp",), init="ones", dtype="float32"),
        f"{prefix}/dt_bias": ParamSpec(Ls + (H,), lay + ("tp",), init="zeros", dtype="float32"),
        f"{prefix}/d_skip": ParamSpec(Ls + (H,), lay + ("tp",), init="ones", dtype="float32"),
        f"{prefix}/out_norm": ParamSpec(Ls + (di,), lay + ("tp",), init="ones", dtype=dt),
        f"{prefix}/w_out": ParamSpec(Ls + (di, d), lay + ("tp", "fsdp"), dtype=dt),
        f"{prefix}/norm": ParamSpec(Ls + (d,), lay + (None,), init="ones", dtype=dt),
    }


def build_param_specs(cfg: ArchConfig, plan: ShardPlan = ShardPlan()) -> Dict[str, ParamSpec]:
    dm = resolve_dims(cfg, plan)
    dt = cfg.dtype
    G = dm.groups
    s: Dict[str, ParamSpec] = {
        "embed": ParamSpec((dm.vocab, dm.d), ("vocab", "fsdp"), dtype=dt),
        "final_norm": ParamSpec((dm.d,), (None,), init="ones", dtype=dt),
    }
    if cfg.norm == "layernorm":
        s["final_norm_b"] = ParamSpec((dm.d,), (None,), init="zeros", dtype=dt)
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamSpec((dm.d, dm.vocab), ("fsdp", "vocab"), dtype=dt)

    fam = cfg.family
    if fam in ("dense", "moe"):
        s.update(_attn_specs(cfg, dm, "blocks/attn", G))
        if fam == "moe":
            s.update(_moe_specs(cfg, dm, "blocks/moe", G))
        else:
            s.update(_mlp_specs(cfg, dm, "blocks/mlp", G))
    elif fam == "ssm":
        s.update(_ssm_specs(cfg, dm, "blocks/ssm", G))
    elif fam == "hybrid":
        # group of `attn_every` layers: layer0 = attention, rest = mamba;
        # ffn alternates dense (even in-group idx) / moe (odd in-group idx)
        gl = dm.group_layers
        s.update(_attn_specs(cfg, dm, "blocks/attn", G))
        for j in range(1, gl):
            s.update(_ssm_specs(cfg, dm, f"blocks/ssm{j}", G))
        for j in range(gl):
            if cfg.n_experts and (j % cfg.moe_every == cfg.moe_every - 1):
                s.update(_moe_specs(cfg, dm, f"blocks/ffn{j}_moe", G))
            else:
                s.update(_mlp_specs(cfg, dm, f"blocks/ffn{j}", G))
    elif fam == "encdec":
        s.update(_attn_specs(cfg, dm, "enc_blocks/attn", dm.enc_layers))
        s.update(_mlp_specs(cfg, dm, "enc_blocks/mlp", dm.enc_layers))
        s.update(_attn_specs(cfg, dm, "blocks/attn", G))
        s.update(_attn_specs(cfg, dm, "blocks/cross", G, cross=True))
        s.update(_mlp_specs(cfg, dm, "blocks/mlp", G))
        s["enc_final_norm"] = ParamSpec((dm.d,), (None,), init="ones", dtype=dt)
        if cfg.norm == "layernorm":
            s["enc_final_norm_b"] = ParamSpec((dm.d,), (None,), init="zeros", dtype=dt)
        if cfg.frontend_dim and cfg.frontend_dim != dm.d:
            s["frontend_proj"] = ParamSpec((cfg.frontend_dim, dm.d), ("fsdp", None), dtype=dt)
    elif fam == "vlm":
        # group of `cross_attn_every` layers; layer0 additionally has image cross-attn
        gl = dm.group_layers
        s.update(_attn_specs(cfg, dm, "blocks/attn", G))
        s.update(_attn_specs(cfg, dm, "blocks/cross", G, cross=True))
        s.update(_mlp_specs(cfg, dm, "blocks/mlp", G))
        for j in range(1, gl):
            s.update(_attn_specs(cfg, dm, f"blocks/attn{j}", G))
            s.update(_mlp_specs(cfg, dm, f"blocks/mlp{j}", G))
        if cfg.frontend_dim and cfg.frontend_dim != dm.d:
            s["frontend_proj"] = ParamSpec((cfg.frontend_dim, dm.d), ("fsdp", None), dtype=dt)
    else:
        raise ValueError(fam)
    return s


# ----------------------------------------------------------------------
def unflatten(flat: Dict[str, object]) -> Dict:
    tree: Dict = {}
    for path, v in flat.items():
        node = tree
        parts = path.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def init_params(cfg: ArchConfig, plan: ShardPlan, rng: jax.Array) -> Dict:
    specs = build_param_specs(cfg, plan)
    keys = jax.random.split(rng, len(specs))
    out = {}
    for (path, spec), k in zip(sorted(specs.items()), keys):
        dtype = jnp.dtype(spec.dtype)
        if spec.init == "zeros":
            v = jnp.zeros(spec.shape, dtype)
        elif spec.init == "ones":
            if path.endswith("a_log"):           # A ~ -[1..]; store log
                v = jnp.log(jnp.arange(1, spec.shape[-1] + 1, dtype=jnp.float32)
                            ).astype(dtype) * jnp.ones(spec.shape, dtype)
            else:
                v = jnp.ones(spec.shape, dtype)
        else:
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            v = (jax.random.normal(k, spec.shape, jnp.float32) / math.sqrt(fan_in)
                 ).astype(dtype)
        out[path] = v
    return unflatten(out)


def param_shapes(cfg: ArchConfig, plan: ShardPlan) -> Dict:
    """Abstract ShapeDtypeStruct tree — no allocation (dry-run path)."""
    specs = build_param_specs(cfg, plan)
    return unflatten({p: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype))
                      for p, s in specs.items()})


def logical_axes(cfg: ArchConfig, plan: ShardPlan) -> Dict:
    specs = build_param_specs(cfg, plan)
    return unflatten({p: s.logical for p, s in specs.items()})


def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    specs = build_param_specs(cfg, ShardPlan())
    total = 0
    for path, s in specs.items():
        n = int(np.prod(s.shape))
        if active_only and ("/moe" in path or "_moe" in path) and not path.endswith("router"):
            n = n * cfg.moe_top_k // max(cfg.n_experts, 1)
        total += n
    return total
