"""Unified model: dense / MoE / SSM / hybrid / enc-dec / VLM families.

One ``Model`` object per ``ArchConfig``: parameters are group-stacked and the
layer stack is a ``lax.scan`` over groups (a group is 1 layer for uniform
stacks, ``attn_every`` layers for hybrids, ``cross_attn_every`` for VLMs).
Exposes ``loss`` (train), ``prefill`` and ``decode`` (serve) plus abstract
shape variants for the dry-run.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import ssm as ssm_mod
from repro.models.layers import cross_entropy, embed_tokens, mlp, norm
from repro.models.moe import moe_ffn
from repro.models.params import (ModelDims, ShardPlan, build_param_specs,
                                 init_params, param_shapes, resolve_dims)


def _remat(fn, mode: str):
    if mode == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


def _mlp_block(x, p, cfg):
    return x + mlp(norm(x, p, cfg.norm), p, cfg.mlp_act)


class Model:
    def __init__(self, cfg: ArchConfig, plan: ShardPlan = ShardPlan(),
                 mesh=None, act_shard=None, opts: Optional[Dict] = None):
        """opts:
          unroll (bool)      — python loops instead of lax.scan (dry-run cost
                               analysis mode: XLA counts a while body once)
          sp (bool)          — Megatron-style sequence parallelism on the
                               residual stream (seq dim over the model axis)
          q_chunk/kv_chunk   — flash-attention tile sizes
          block_skip (bool)  — skip fully-masked causal blocks (needs unroll)
          ssm_chunk          — SSD chunk length
          ce_chunk           — sequence-chunked cross-entropy slice
        """
        self.cfg = cfg
        self.plan = plan
        self.dm: ModelDims = resolve_dims(cfg, plan)
        self.mesh = mesh
        self.opts = dict(opts or {})
        self.unroll = bool(self.opts.get("unroll", False))
        self.sp = bool(self.opts.get("sp", False))
        self._attn_opts = {k: self.opts[k] for k in
                           ("q_chunk", "kv_chunk", "unroll", "block_skip")
                           if k in self.opts}
        self._ssm_opts = {k: self.opts[k] for k in
                          ("ssm_chunk", "unroll", "ssd_dtype")
                          if k in self.opts}
        # act_shard(x, logical_tuple) -> x  (sharding constraint hook)
        self._sa = act_shard or (lambda x, spec: x)

    def _res_spec(self):
        """Residual-stream activation sharding (SP shards seq over 'model')."""
        return ("batch", "sp", None) if self.sp else ("batch", None, None)

    # ------------------------------------------------------------- params
    def init(self, rng) -> Dict:
        return init_params(self.cfg, self.plan, rng)

    def param_shapes(self) -> Dict:
        return param_shapes(self.cfg, self.plan)

    # ------------------------------------------------------------- embedding
    def _embed(self, params, tokens):
        x = embed_tokens(tokens, params["embed"])
        return self._sa(x, ("batch", None, None))

    def _head_matrix(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]

    def _logits(self, params, x):
        logits = jnp.einsum("...d,dv->...v", x, self._head_matrix(params).astype(x.dtype),
                            preferred_element_type=jnp.float32)
        return self._sa(logits, ("batch", None, "tp"))

    # ------------------------------------------------------------- stacks
    def _group_train(self, x, pl, positions, memory_kv=None):
        """One scan group, full-sequence. Returns (x, aux)."""
        cfg, dm = self.cfg, self.dm
        aux = jnp.zeros((), jnp.float32)
        fam = cfg.family
        if fam in ("dense", "moe"):
            x = x + attn.self_attn_train(x, pl["attn"], cfg, dm, positions, opts=self._attn_opts)
            if fam == "moe":
                f, a = moe_ffn(x, pl["moe"], cfg, dm, self.mesh)
                x, aux = x + f, aux + a
            else:
                x = _mlp_block(x, pl["mlp"], cfg)
        elif fam == "ssm":
            x = x + ssm_mod.mamba_train(x, pl["ssm"], cfg, dm, opts=self._ssm_opts)
        elif fam == "hybrid":
            for j in range(dm.group_layers):
                if j == 0:
                    x = x + attn.self_attn_train(x, pl["attn"], cfg, dm, positions, opts=self._attn_opts)
                else:
                    x = x + ssm_mod.mamba_train(x, pl[f"ssm{j}"], cfg, dm, opts=self._ssm_opts)
                if cfg.n_experts and (j % cfg.moe_every == cfg.moe_every - 1):
                    f, a = moe_ffn(x, pl[f"ffn{j}_moe"], cfg, dm, self.mesh)
                    x, aux = x + f, aux + a
                else:
                    x = _mlp_block(x, pl[f"ffn{j}"], cfg)
        elif fam == "encdec":
            x = x + attn.self_attn_train(x, pl["attn"], cfg, dm, positions, opts=self._attn_opts)
            ckv = attn.cross_kv(memory_kv, pl["cross"], cfg, dm)
            x = x + attn.cross_attn(x, ckv, pl["cross"], cfg, dm, opts=self._attn_opts)
            x = _mlp_block(x, pl["mlp"], cfg)
        elif fam == "vlm":
            x = x + attn.self_attn_train(x, pl["attn"], cfg, dm, positions, opts=self._attn_opts)
            ckv = attn.cross_kv(memory_kv, pl["cross"], cfg, dm)
            x = x + attn.cross_attn(x, ckv, pl["cross"], cfg, dm, opts=self._attn_opts)
            x = _mlp_block(x, pl["mlp"], cfg)
            for j in range(1, dm.group_layers):
                x = x + attn.self_attn_train(x, pl[f"attn{j}"], cfg, dm, positions, opts=self._attn_opts)
                x = _mlp_block(x, pl[f"mlp{j}"], cfg)
        x = self._sa(x, self._res_spec())
        return x, aux

    def _stack_train(self, params, x, positions, memory=None):
        if self.unroll:
            aux = jnp.zeros((), jnp.float32)
            for g in range(self.dm.groups):
                pl = jax.tree.map(lambda a: a[g], params["blocks"])
                x, a = self._group_train(x, pl, positions, memory)
                aux = aux + a
            return x, aux
        # remat_group r: scan over G/r super-groups of r layers each — the
        # full-remat carry (the dominant training activation cost when TP
        # replicates the residual stream) shrinks by r at the price of r×
        # within-group recompute locality.
        r = max(1, int(self.opts.get("remat_group", self.cfg.remat_group)))
        blocks = params["blocks"]
        if r > 1 and self.dm.groups % r == 0:
            blocks = jax.tree.map(
                lambda a: a.reshape(a.shape[0] // r, r, *a.shape[1:]), blocks)

            def body0(carry, plr):
                x, aux = carry
                for i in range(r):
                    pl = jax.tree.map(lambda a: a[i], plr)
                    x, a = self._group_train(x, pl, positions, memory)
                    aux = aux + a
                return (x, aux), None
        else:
            def body0(carry, pl):
                x, a = self._group_train(carry[0], pl, positions, memory)
                return (x, carry[1] + a), None
        body = _remat(body0, self.cfg.remat)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   blocks)
        return x, aux

    def _encode(self, params, frames):
        cfg, dm = self.cfg, self.dm
        x = frames
        if "frontend_proj" in params:
            x = x @ params["frontend_proj"]
        x = self._sa(x.astype(jnp.dtype(cfg.dtype)), ("batch", None, None))
        positions = jnp.arange(x.shape[1])[None, :]

        def enc_group(h, pl):
            h = h + attn.self_attn_train(h, pl["attn"], cfg, dm,
                                         positions, causal=False,
                                         opts=self._attn_opts)
            h = _mlp_block(h, pl["mlp"], cfg)
            return self._sa(h, self._res_spec())

        if self.unroll:
            for g in range(dm.enc_layers):
                pl = jax.tree.map(lambda a: a[g], params["enc_blocks"])
                x = enc_group(x, pl)
        else:
            body = _remat(lambda c, pl: (enc_group(c, pl), None), cfg.remat)
            x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        if cfg.norm == "layernorm":
            from repro.models.layers import layernorm
            x = layernorm(x, params["enc_final_norm"], params["enc_final_norm_b"])
        else:
            from repro.models.layers import rmsnorm
            x = rmsnorm(x, params["enc_final_norm"])
        return x

    def _memory(self, params, batch):
        """Frontend memory for encdec (audio frames) / vlm (image patches)."""
        cfg = self.cfg
        if cfg.family == "encdec":
            return self._encode(params, batch["frames"])
        if cfg.family == "vlm":
            x = batch["patches"]
            if "frontend_proj" in params:
                x = x @ params["frontend_proj"]
            return x.astype(jnp.dtype(cfg.dtype))
        return None

    # ------------------------------------------------------------- train
    def loss(self, params, batch) -> Tuple[jax.Array, Dict]:
        cfg, dm = self.cfg, self.dm
        tokens, labels = batch["tokens"], batch["labels"]
        positions = jnp.arange(tokens.shape[1])[None, :]
        memory = self._memory(params, batch)
        x = self._embed(params, tokens)
        x, aux = self._stack_train(params, x, positions, memory)
        x = norm(x, params, cfg.norm, "final_norm")
        ce = self._chunked_ce(params, x, labels)
        total = ce + 0.01 * aux
        return total, {"loss": ce, "aux": aux}

    def _chunked_ce(self, params, x, labels):
        """Sequence-chunked CE so (tokens × vocab) logits are never live at
        once.  Python loop over static slices (sharding-friendly: slices of a
        seq-sharded dim stay aligned; every chunk is visible to cost analysis);
        each chunk is checkpointed so logits are recomputed in backward."""
        cfg, dm = self.cfg, self.dm
        # leave SP before the head: slices of a sharded seq dim would force
        # expensive GSPMD reshards per chunk (Megatron gathers here too)
        x = self._sa(x, ("batch", None, None))
        b, s, d = x.shape
        head = self._head_matrix(params)
        c = min(int(self.opts.get("ce_chunk", 1024)), s)
        pad = (-s) % c
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        nch = x.shape[1] // c

        @jax.checkpoint
        def chunk_loss(xc, lc, head):
            logits = jnp.einsum("bcd,dv->bcv", xc, head.astype(xc.dtype),
                                preferred_element_type=jnp.float32)
            logits = self._sa(logits, ("batch", None, "tp"))
            valid = (lc >= 0).astype(jnp.float32)
            nll = cross_entropy(logits, jnp.maximum(lc, 0), cfg.vocab_size,
                                mask=valid) * jnp.sum(valid)
            return nll, jnp.sum(valid)

        tot = jnp.zeros(())
        cnt = jnp.zeros(())
        for i in range(nch):
            xc = jax.lax.slice_in_dim(x, i * c, (i + 1) * c, axis=1)
            lc = jax.lax.slice_in_dim(labels, i * c, (i + 1) * c, axis=1)
            nll, nv = chunk_loss(xc, lc, head)
            tot = tot + nll
            cnt = cnt + nv
        return tot / jnp.maximum(cnt, 1.0)

    # ------------------------------------------------------------- serve
    def init_cache(self, batch_size: int, max_len: int, abstract: bool = False):
        cfg, dm = self.cfg, self.dm
        G = dm.groups
        mk = (lambda shape, dt: jax.ShapeDtypeStruct(shape, dt)) if abstract \
            else (lambda shape, dt: jnp.zeros(shape, dt))
        bf = jnp.dtype(cfg.dtype)
        cache: Dict = {}
        if cfg.family in ("dense", "moe", "encdec"):
            cache["k"] = mk((G, batch_size, max_len, dm.kh, dm.hd), bf)
            cache["v"] = mk((G, batch_size, max_len, dm.kh, dm.hd), bf)
        if cfg.family == "vlm":   # one KV slot per in-group self-attn layer
            gl = dm.group_layers
            cache["k"] = mk((G, gl, batch_size, max_len, dm.kh, dm.hd), bf)
            cache["v"] = mk((G, gl, batch_size, max_len, dm.kh, dm.hd), bf)
        if cfg.family == "ssm":
            cache["state"] = mk((G, batch_size, dm.ssm_h, dm.ssm_p, dm.ssm_n),
                                jnp.float32)
            cache["conv"] = mk((G, batch_size, dm.conv_w - 1, dm.conv_dim), bf)
        if cfg.family == "hybrid":
            gl = dm.group_layers
            cache["k"] = mk((G, batch_size, max_len, dm.kh, dm.hd), bf)
            cache["v"] = mk((G, batch_size, max_len, dm.kh, dm.hd), bf)
            cache["state"] = mk((G, gl - 1, batch_size, dm.ssm_h, dm.ssm_p, dm.ssm_n),
                                jnp.float32)
            cache["conv"] = mk((G, gl - 1, batch_size, dm.conv_w - 1, dm.conv_dim), bf)
        if cfg.family == "encdec":
            enc_len = max_len // 4
            cache["ck"] = mk((G, batch_size, enc_len, dm.kh, dm.hd), bf)
            cache["cv"] = mk((G, batch_size, enc_len, dm.kh, dm.hd), bf)
        if cfg.family == "vlm":
            cache["ck"] = mk((G, batch_size, cfg.n_frontend_tokens, dm.kh, dm.hd), bf)
            cache["cv"] = mk((G, batch_size, cfg.n_frontend_tokens, dm.kh, dm.hd), bf)
        return cache

    def prefill(self, params, batch, cache_len: Optional[int] = None):
        """Full-sequence forward that also builds the decode cache.
        Returns (cache, logits_last:(B,vocab))."""
        cfg, dm = self.cfg, self.dm
        tokens = batch["tokens"]
        b, s = tokens.shape
        cache_len = cache_len or s
        positions = jnp.arange(s)[None, :]
        memory = self._memory(params, batch)
        x = self._embed(params, tokens)

        def pad_kv(k):
            if cache_len == s:
                return k
            return jnp.pad(k, ((0, 0), (0, cache_len - s), (0, 0), (0, 0)))

        def body0(carry, pl):
            x, aux = carry
            ys = {}
            if cfg.family in ("dense", "moe"):
                o, (k, v) = attn.self_attn_prefill(x, pl["attn"], cfg, dm, positions, opts=self._attn_opts)
                x = x + o
                ys["k"], ys["v"] = pad_kv(k), pad_kv(v)
                if cfg.family == "moe":
                    f, a = moe_ffn(x, pl["moe"], cfg, dm, self.mesh)
                    x, aux = x + f, aux + a
                else:
                    x = _mlp_block(x, pl["mlp"], cfg)
            elif cfg.family == "ssm":
                o, (st, conv) = ssm_mod.mamba_train(x, pl["ssm"], cfg, dm,
                                                    return_state=True,
                                                    opts=self._ssm_opts)
                x = x + o
                ys["state"], ys["conv"] = st, conv
            elif cfg.family == "hybrid":
                sts, convs = [], []
                for j in range(dm.group_layers):
                    if j == 0:
                        o, (k, v) = attn.self_attn_prefill(x, pl["attn"], cfg, dm,
                                                           positions, opts=self._attn_opts)
                        x = x + o
                        ys["k"], ys["v"] = pad_kv(k), pad_kv(v)
                    else:
                        o, (st, conv) = ssm_mod.mamba_train(
                            x, pl[f"ssm{j}"], cfg, dm, return_state=True,
                            opts=self._ssm_opts)
                        x = x + o
                        sts.append(st)
                        convs.append(conv)
                    if cfg.n_experts and (j % cfg.moe_every == cfg.moe_every - 1):
                        f, a = moe_ffn(x, pl[f"ffn{j}_moe"], cfg, dm, self.mesh)
                        x, aux = x + f, aux + a
                    else:
                        x = _mlp_block(x, pl[f"ffn{j}"], cfg)
                ys["state"] = jnp.stack(sts)
                ys["conv"] = jnp.stack(convs)
            elif cfg.family == "encdec":
                o, (k, v) = attn.self_attn_prefill(x, pl["attn"], cfg, dm, positions, opts=self._attn_opts)
                x = x + o
                ys["k"], ys["v"] = pad_kv(k), pad_kv(v)
                ck, cv = attn.cross_kv(memory, pl["cross"], cfg, dm)
                x = x + attn.cross_attn(x, (ck, cv), pl["cross"], cfg, dm, opts=self._attn_opts)
                ys["ck"], ys["cv"] = ck, cv
                x = _mlp_block(x, pl["mlp"], cfg)
            elif cfg.family == "vlm":
                ks, vs = [], []
                o, (k, v) = attn.self_attn_prefill(x, pl["attn"], cfg, dm, positions, opts=self._attn_opts)
                x = x + o
                ks.append(pad_kv(k))
                vs.append(pad_kv(v))
                ck, cv = attn.cross_kv(memory, pl["cross"], cfg, dm)
                x = x + attn.cross_attn(x, (ck, cv), pl["cross"], cfg, dm, opts=self._attn_opts)
                ys["ck"], ys["cv"] = ck, cv
                x = _mlp_block(x, pl["mlp"], cfg)
                for j in range(1, dm.group_layers):
                    o, (k, v) = attn.self_attn_prefill(x, pl[f"attn{j}"], cfg, dm,
                                                       positions, opts=self._attn_opts)
                    x = x + o
                    ks.append(pad_kv(k))
                    vs.append(pad_kv(v))
                    x = _mlp_block(x, pl[f"mlp{j}"], cfg)
                ys["k"], ys["v"] = jnp.stack(ks), jnp.stack(vs)
            x = self._sa(x, ("batch", None, None))
            return (x, aux), ys

        if self.unroll:
            carry = (x, jnp.zeros((), jnp.float32))
            ys_l = []
            for g in range(self.dm.groups):
                pl = jax.tree.map(lambda a: a[g], params["blocks"])
                carry, ys = body0(carry, pl)
                ys_l.append(ys)
            x, _ = carry
            cache = jax.tree.map(lambda *a: jnp.stack(a), *ys_l)
        else:
            body = _remat(body0, self.cfg.remat) if cfg.remat != "none" else body0
            (x, _), cache = jax.lax.scan(
                body, (x, jnp.zeros((), jnp.float32)), params["blocks"])
        x = norm(x, params, cfg.norm, "final_norm")
        logits = self._logits(params, x[:, -1])
        return cache, logits

    def decode(self, params, cache, cur_len, token):
        """token:(B,) int32; cur_len: scalar int32. Returns (logits, cache)."""
        cfg, dm = self.cfg, self.dm
        x = self._embed(params, token[:, None])

        def body(x, pl_and_cache):
            pl, cl = pl_and_cache
            ncl = {}
            if cfg.family in ("dense", "moe", "encdec"):
                o, ck_, cv_ = attn.self_attn_decode(x, pl["attn"], cfg, dm,
                                                    cl["k"], cl["v"], cur_len)
                x = x + o
                ncl["k"], ncl["v"] = ck_, cv_
            if cfg.family == "moe":
                f, _ = moe_ffn(x, pl["moe"], cfg, dm, self.mesh)
                x = x + f
            elif cfg.family == "dense":
                x = _mlp_block(x, pl["mlp"], cfg)
            elif cfg.family == "ssm":
                o, st, conv = ssm_mod.mamba_decode(x, pl["ssm"], cfg, dm,
                                                   cl["state"], cl["conv"])
                x = x + o
                ncl["state"], ncl["conv"] = st, conv
            elif cfg.family == "hybrid":
                sts, convs = [], []
                for j in range(dm.group_layers):
                    if j == 0:
                        o, ck_, cv_ = attn.self_attn_decode(
                            x, pl["attn"], cfg, dm, cl["k"], cl["v"], cur_len)
                        x = x + o
                        ncl["k"], ncl["v"] = ck_, cv_
                    else:
                        o, st, conv = ssm_mod.mamba_decode(
                            x, pl[f"ssm{j}"], cfg, dm,
                            cl["state"][j - 1], cl["conv"][j - 1])
                        x = x + o
                        sts.append(st)
                        convs.append(conv)
                    if cfg.n_experts and (j % cfg.moe_every == cfg.moe_every - 1):
                        f, _ = moe_ffn(x, pl[f"ffn{j}_moe"], cfg, dm, self.mesh)
                        x = x + f
                    else:
                        x = _mlp_block(x, pl[f"ffn{j}"], cfg)
                ncl["state"] = jnp.stack(sts)
                ncl["conv"] = jnp.stack(convs)
            elif cfg.family in ("encdec", "vlm"):
                def _cross_dec(x, pc, ck, cv):
                    h = norm(x, pc, cfg.norm)
                    b = x.shape[0]
                    q = (h @ pc["wq"]).reshape(b, 1, dm.h, dm.hd)
                    if cfg.qkv_bias:
                        q = q + pc["bq"].reshape(dm.h, dm.hd)
                    enc_len = ck.shape[1]
                    o = attn.decode_attention(q, ck, cv,
                                              cur_len=jnp.asarray(enc_len))
                    return x + o.reshape(b, 1, dm.h * dm.hd) @ pc["wo"]

                ncl["ck"], ncl["cv"] = cl["ck"], cl["cv"]
                if cfg.family == "encdec":
                    x = _cross_dec(x, pl["cross"], cl["ck"], cl["cv"])
                    x = _mlp_block(x, pl["mlp"], cfg)
                else:  # vlm: per-in-group-layer self-attn caches
                    ks, vs = [], []
                    o, ck_, cv_ = attn.self_attn_decode(
                        x, pl["attn"], cfg, dm, cl["k"][0], cl["v"][0], cur_len)
                    x = x + o
                    ks.append(ck_)
                    vs.append(cv_)
                    x = _cross_dec(x, pl["cross"], cl["ck"], cl["cv"])
                    x = _mlp_block(x, pl["mlp"], cfg)
                    for j in range(1, dm.group_layers):
                        o, ck_, cv_ = attn.self_attn_decode(
                            x, pl[f"attn{j}"], cfg, dm, cl["k"][j], cl["v"][j],
                            cur_len)
                        x = x + o
                        ks.append(ck_)
                        vs.append(cv_)
                        x = _mlp_block(x, pl[f"mlp{j}"], cfg)
                    ncl["k"], ncl["v"] = jnp.stack(ks), jnp.stack(vs)
            x = self._sa(x, ("batch", None, None))
            return x, ncl

        if self.unroll:
            ncl_l = []
            for g in range(self.dm.groups):
                pl = jax.tree.map(lambda a: a[g], params["blocks"])
                cl = jax.tree.map(lambda a: a[g], cache)
                x, ncl = body(x, (pl, cl))
                ncl_l.append(ncl)
            new_cache = jax.tree.map(lambda *a: jnp.stack(a), *ncl_l)
        else:
            x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
        x = norm(x, params, cfg.norm, "final_norm")
        logits = self._logits(params, x[:, -1])
        return logits, new_cache
