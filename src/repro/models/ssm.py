"""Mamba2 (SSD — state-space duality) block, chunked dual form.

Training/prefill uses the block-decomposed SSD algorithm (intra-chunk
quadratic term + inter-chunk state recurrence via ``lax.scan``); decode is a
single-step state update.  Layout follows the minimal-SSD reference:
``x:(B,S,H,P)  dt:(B,S,H)  A:(H)<0  Bm,Cm:(B,S,N)`` (n_groups = 1).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import rmsnorm, norm
from repro.models.params import ModelDims


def _chunk(x: jax.Array, q: int) -> jax.Array:
    b, s = x.shape[:2]
    return x.reshape(b, s // q, q, *x.shape[2:])


def ssd_chunked(x, dt, a, bm, cm, chunk: int = 128, unroll: bool = False,
                dtype16: bool = False):
    """Returns y:(B,S,H,P) and final state:(B,H,P,N). f32 math.
    unroll=True replaces the inter-chunk lax.scan with a python loop (dry-run
    cost-analysis mode).  dtype16=True keeps the O(S·Q·H) intra-chunk decay /
    weight tensors in bf16 (halves their HBM traffic; accumulation stays f32)."""
    b, s, h, p = x.shape
    n = bm.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    xb, dtb = _chunk(x, q), _chunk(dt, q)
    bb, cb = _chunk(bm, q), _chunk(cm, q)
    nc = s // q
    wdt = jnp.bfloat16 if dtype16 else jnp.float32

    da = dtb * a                                        # (B,nc,Q,H)
    da_cs = jnp.cumsum(da, axis=2)                      # (B,nc,Q,H)

    # ---- intra-chunk (diagonal blocks) ----
    # L[i,j] = exp(da_cs[i] - da_cs[j]) for i >= j else 0
    seg = (da_cs[:, :, :, None, :].astype(wdt)
           - da_cs[:, :, None, :, :].astype(wdt))               # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    # mask BEFORE exp: the i<j entries are large-positive and would overflow
    # (and poison gradients through the where)
    seg = jnp.where(tri[None, None, :, :, None], seg, -jnp.inf)
    l_mat = jnp.exp(seg)
    cb_bt = jnp.einsum("bcin,bcjn->bcij", cb.astype(wdt), bb.astype(wdt),
                       preferred_element_type=wdt)               # (B,nc,Q,Q)
    w = cb_bt[..., None] * l_mat * dtb[:, :, None, :, :].astype(wdt)
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", w, xb.astype(wdt),
                        preferred_element_type=jnp.float32)

    # ---- per-chunk final states ----
    decay_tail = jnp.exp(da_cs[:, :, -1:, :] - da_cs)            # (B,nc,Q,H)
    st = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", bb, decay_tail * dtb, xb,
                    preferred_element_type=jnp.float32)          # (B,nc,H,P,N)

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(jnp.sum(da, axis=2))                   # (B,nc,H)

    def step(state, inp):
        st_c, dec_c = inp                                        # (B,H,P,N),(B,H)
        prev = state
        state = prev * dec_c[:, :, None, None] + st_c
        return state, prev

    init = jnp.zeros((b, h, p, n), jnp.float32)
    if unroll:
        state, prevs_l = init, []
        for ci in range(nc):
            state, prev = step(state, (st[:, ci], chunk_decay[:, ci]))
            prevs_l.append(prev)
        final = state
        prev_states = jnp.stack(prevs_l, axis=1)                 # (B,nc,H,P,N)
    else:
        st_s = jnp.moveaxis(st, 1, 0)
        dec_s = jnp.moveaxis(chunk_decay, 1, 0)
        final, prevs = jax.lax.scan(step, init, (st_s, dec_s))
        prev_states = jnp.moveaxis(prevs, 0, 1)                  # (B,nc,H,P,N)

    # ---- off-diagonal contribution ----
    decay_in = jnp.exp(da_cs)                                    # (B,nc,Q,H)
    y_off = jnp.einsum("bcin,bcih,bchpn->bcihp", cb, decay_in, prev_states,
                       preferred_element_type=jnp.float32)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final


def ssd_decode_step(state, x1, dt1, a, b1, c1):
    """state:(B,H,P,N); x1:(B,H,P); dt1:(B,H); b1,c1:(B,N). One token."""
    da = jnp.exp(dt1 * a)                                        # (B,H)
    upd = jnp.einsum("bhp,bn->bhpn", x1 * dt1[..., None], b1)
    state = state * da[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, c1)
    return y, state


# ----------------------------------------------------------------------
def _conv_full(xbc: jax.Array, w: jax.Array, bias: jax.Array) -> jax.Array:
    """Causal depthwise conv; xbc:(B,S,C), w:(W,C)."""
    width, ch = w.shape
    out = jax.lax.conv_general_dilated(
        xbc, w[:, None, :].astype(xbc.dtype),
        window_strides=(1,), padding=[(width - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=ch)
    return out + bias.astype(xbc.dtype)


def _split_in(h: jax.Array, dm: ModelDims):
    di, n, H = dm.d_inner, dm.ssm_n, dm.ssm_h
    z = h[..., :di]
    xbc = h[..., di:di + dm.conv_dim]
    dt = h[..., di + dm.conv_dim:]
    assert dt.shape[-1] == H
    return z, xbc, dt


def mamba_train(x: jax.Array, p: Dict, cfg: ArchConfig, dm: ModelDims,
                return_state: bool = False, opts: Optional[dict] = None):
    """Full-sequence Mamba2 sublayer (pre-norm; residual added by caller)."""
    opts = opts or {}
    bsz, s, _ = x.shape
    h = norm(x, p, cfg.norm) @ p["w_in"]
    z, xbc, dt = _split_in(h, dm)
    xbc = jax.nn.silu(_conv_full(xbc, p["conv_w"], p["conv_b"]))
    xi = xbc[..., :dm.d_inner].reshape(bsz, s, dm.ssm_h, dm.ssm_p).astype(jnp.float32)
    bm = xbc[..., dm.d_inner:dm.d_inner + dm.ssm_n].astype(jnp.float32)
    cm = xbc[..., dm.d_inner + dm.ssm_n:].astype(jnp.float32)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    default_chunk = 256 if s >= 8192 else 128   # intra∝Q vs state-pass∝1/Q
    y, state = ssd_chunked(xi, dtf, a, bm, cm,
                           chunk=opts.get("ssm_chunk", default_chunk),
                           unroll=opts.get("unroll", False),
                           dtype16=opts.get("ssd_dtype", "") == "bfloat16")
    y = y + xi * p["d_skip"][:, None]
    y = y.reshape(bsz, s, dm.d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["out_norm"])
    out = y @ p["w_out"]
    if return_state:
        conv_tail = xbc_raw_tail(x, p, cfg, dm)
        return out, (state, conv_tail)
    return out


def xbc_raw_tail(x, p, cfg: ArchConfig, dm: ModelDims):
    """Last (conv_w - 1) pre-conv xBC activations — the decode conv state."""
    h = norm(x, p, cfg.norm) @ p["w_in"]
    _, xbc, _ = _split_in(h, dm)
    return xbc[:, -(dm.conv_w - 1):, :]


def mamba_decode(x1: jax.Array, p: Dict, cfg: ArchConfig, dm: ModelDims,
                 state: jax.Array, conv_state: jax.Array):
    """x1:(B,1,D); state:(B,H,P,N); conv_state:(B,W-1,conv_dim)."""
    bsz = x1.shape[0]
    h = norm(x1, p, cfg.norm) @ p["w_in"]
    z, xbc, dt = _split_in(h, dm)
    xbc1 = xbc[:, 0]                                             # (B,conv_dim)
    window = jnp.concatenate([conv_state, xbc1[:, None, :]], axis=1)  # (B,W,C)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xbc1 = jax.nn.silu(conv_out)
    xi = xbc1[:, :dm.d_inner].reshape(bsz, dm.ssm_h, dm.ssm_p)
    b1 = xbc1[:, dm.d_inner:dm.d_inner + dm.ssm_n]
    c1 = xbc1[:, dm.d_inner + dm.ssm_n:]
    dtf = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    y, state = ssd_decode_step(state, xi, dtf, a, b1, c1)
    y = y + xi * p["d_skip"][:, None]
    y = y.reshape(bsz, 1, dm.d_inner).astype(x1.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["out_norm"])
    new_conv = window[:, 1:, :].astype(conv_state.dtype)
    return y @ p["w_out"], state, new_conv
