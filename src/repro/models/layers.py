"""Stateless layer ops: norms, RoPE, MLPs, embedding, vocab-sharded cross entropy."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def norm(x: jax.Array, p: Dict, kind: str, key: str = "norm") -> jax.Array:
    if kind == "layernorm":
        return layernorm(x, p[key], p[f"{key}_b"])
    return rmsnorm(x, p[key])


# ----------------------------------------------------------------------
def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)
    ang = positions.astype(jnp.float32)[..., None] * inv            # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                                # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
def mlp(x: jax.Array, p: Dict, act: str) -> jax.Array:
    h = x @ p["w_in"]
    if act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * h
    else:
        h = jax.nn.gelu(h)
    return h @ p["w_out"]


def embed_tokens(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def lm_logits(x: jax.Array, params: Dict, tie: bool) -> jax.Array:
    if tie:
        return jnp.einsum("...d,vd->...v", x, params["embed"],
                          preferred_element_type=jnp.float32)
    return jnp.einsum("...d,dv->...v", x, params["lm_head"],
                      preferred_element_type=jnp.float32)


def cross_entropy(logits: jax.Array, labels: jax.Array, vocab_real: int,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Vocab-sharded-safe CE: pure jnp reductions over the (possibly padded)
    vocab dim; padded entries are masked to -inf so they never win."""
    v_pad = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if v_pad != vocab_real:
        pad_mask = jnp.arange(v_pad) >= vocab_real
        logits = jnp.where(pad_mask, -1e30, logits)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, v_pad, dtype=jnp.float32)
    gold = jnp.sum(logits * onehot, axis=-1)
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
