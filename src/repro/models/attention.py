"""GQA attention: double-chunked (flash-style) prefill/train path, direct decode
path, cross-attention. Pure jnp/lax — fixed shapes, online softmax, f32 accum."""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import apply_rope, norm
from repro.models.params import ModelDims

NEG = -1e30


def _pad_to(x: jax.Array, axis: int, mult: int) -> Tuple[jax.Array, int]:
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x, s
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), s


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0, q_offset: int = 0,
                    kv_valid: Optional[jax.Array] = None,
                    q_chunk: int = 1024, kv_chunk: int = 1024,
                    unroll: bool = False,
                    block_skip: bool = False) -> jax.Array:
    """q: (B,Sq,H,hd); k,v: (B,Skv,Kh,hd) with H % Kh == 0.  Returns (B,Sq,H,hd).

    Double-chunked online-softmax attention: outer loop over q chunks, inner
    loop over kv chunks.  All masking (causal / sliding window / kv validity /
    padding) happens on the f32 score tile.

    unroll=True runs python loops instead of lax.scan — used by the dry-run
    analysis mode so HLO cost analysis sees every block (XLA counts a while
    body once).  block_skip=True (requires unroll) skips fully-masked blocks
    above the causal diagonal / outside the sliding window.
    """
    B, Sq, H, hd = q.shape
    Skv, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    scale = hd ** -0.5
    qc = min(q_chunk, max(Sq, 1))
    kc = min(kv_chunk, max(Skv, 1))

    qp, Sq0 = _pad_to(q, 1, qc)
    kp, Skv0 = _pad_to(k, 1, kc)
    vp, _ = _pad_to(v, 1, kc)
    nq, nk = qp.shape[1] // qc, kp.shape[1] // kc

    if kv_valid is None:
        kv_valid = jnp.asarray(Skv0, jnp.int32)

    qp = qp.reshape(B, nq, qc, Kh, G, hd)
    kp = kp.reshape(B, nk, kc, Kh, hd)
    vp = vp.reshape(B, nk, kc, Kh, hd)

    def kv_block(carry, qi, iq_glob, kj, vj, jk):
        m, l, acc = carry
        jk_glob = jk * kc + jnp.arange(kc)
        s = jnp.einsum("bqkgh,bjkh->bkgqj", qi, kj,
                       preferred_element_type=jnp.float32) * scale
        mask = jk_glob[None, :] < kv_valid
        if causal:
            mask = mask & (jk_glob[None, :] <= iq_glob[:, None])
        if window:
            mask = mask & (jk_glob[None, :] > iq_glob[:, None] - window)
        s = jnp.where(mask[None, None, None], s, NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqj,bjkh->bkgqh", p.astype(vj.dtype), vj,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return m_new, l, acc

    def q_block(qi, iq):
        iq_glob = q_offset + iq * qc + jnp.arange(qc)
        m0 = jnp.full((B, Kh, G, qc), NEG, jnp.float32)
        l0 = jnp.zeros((B, Kh, G, qc), jnp.float32)
        a0 = jnp.zeros((B, Kh, G, qc, hd), jnp.float32)
        if unroll:
            carry = (m0, l0, a0)
            for jk in range(nk):
                if block_skip and causal and isinstance(iq, int):
                    if jk * kc > q_offset + iq * qc + qc - 1:
                        continue        # block fully above causal diagonal
                    if window and (jk + 1) * kc - 1 <= q_offset + iq * qc - window:
                        continue        # block fully outside the window
                carry = kv_block(carry, qi, iq_glob, kp[:, jk], vp[:, jk],
                                 jnp.asarray(jk))
            m, l, acc = carry
        else:
            def kv_step(carry, x):
                kj, vj, jk = x
                return kv_block(carry, qi, iq_glob, kj, vj, jk), None
            ks = jnp.moveaxis(kp, 1, 0)
            vs = jnp.moveaxis(vp, 1, 0)
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                          (ks, vs, jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]        # (B,Kh,G,qc,hd)
        return jnp.moveaxis(out, 3, 1).astype(q.dtype)       # (B,qc,Kh,G,hd)

    if unroll:
        outs = [q_block(qp[:, i], i) for i in range(nq)]
        out = jnp.stack(outs, axis=1)
    elif block_skip and causal and isinstance(q_offset, int):
        # Production block skipping, differentiable form: one scan over the
        # STATIC lower-triangle (iq, jk) block list — exactly the causal /
        # windowed band is computed (≈2× fewer blocks than the dense grid);
        # accumulators for all q chunks ride in the carry.
        pairs = []
        for i in range(nq):
            j_hi = min(nk - 1, (q_offset + (i + 1) * qc - 1) // kc)
            j_lo = max(0, (q_offset + i * qc - window) // kc) if window else 0
            pairs.extend((i, j) for j in range(j_lo, j_hi + 1))
        iq_arr = jnp.asarray([p[0] for p in pairs], jnp.int32)
        jk_arr = jnp.asarray([p[1] for p in pairs], jnp.int32)

        def tri_step(carry, ij):
            m, l, acc = carry                 # (B,Kh,G,nq,qc[,hd])
            iq, jk = ij
            qi = jax.lax.dynamic_index_in_dim(qp, iq, 1, keepdims=False)
            kj = jax.lax.dynamic_index_in_dim(kp, jk, 1, keepdims=False)
            vj = jax.lax.dynamic_index_in_dim(vp, jk, 1, keepdims=False)
            iq_glob = q_offset + iq * qc + jnp.arange(qc)
            mi = jax.lax.dynamic_index_in_dim(m, iq, 3, keepdims=False)
            li = jax.lax.dynamic_index_in_dim(l, iq, 3, keepdims=False)
            ai = jax.lax.dynamic_index_in_dim(acc, iq, 3, keepdims=False)
            mi, li, ai = kv_block((mi, li, ai), qi, iq_glob, kj, vj, jk)
            m = jax.lax.dynamic_update_index_in_dim(m, mi, iq, 3)
            l = jax.lax.dynamic_update_index_in_dim(l, li, iq, 3)
            acc = jax.lax.dynamic_update_index_in_dim(acc, ai, iq, 3)
            return (m, l, acc), None

        m0 = jnp.full((B, Kh, G, nq, qc), NEG, jnp.float32)
        l0 = jnp.zeros((B, Kh, G, nq, qc), jnp.float32)
        a0 = jnp.zeros((B, Kh, G, nq, qc, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(tri_step, (m0, l0, a0), (iq_arr, jk_arr))
        out = acc / jnp.maximum(l, 1e-30)[..., None]      # (B,Kh,G,nq,qc,hd)
        out = jnp.moveaxis(out.reshape(B, Kh, G, nq * qc, hd), 3, 1)
        out = out.astype(q.dtype)                          # (B,S,Kh,G,hd)
    else:
        qs = jnp.moveaxis(qp, 1, 0)
        _, outs = jax.lax.scan(lambda _, x: (None, q_block(*x)), None,
                               (qs, jnp.arange(nq)))
        out = jnp.moveaxis(outs, 0, 1)
    out = out.reshape(B, nq * qc, H, hd)
    return out[:, :Sq0]


def decode_attention(q1: jax.Array, k: jax.Array, v: jax.Array, *,
                     cur_len: jax.Array, window: int = 0) -> jax.Array:
    """q1: (B,1,H,hd); k,v: (B,S,Kh,hd) cache. Attends to positions < cur_len."""
    B, _, H, hd = q1.shape
    S, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    qg = q1.reshape(B, Kh, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    pos = jnp.arange(S)
    mask = pos < cur_len
    if window:
        mask = mask & (pos > cur_len - window)
    s = jnp.where(mask[None, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q1.dtype)


# ----------------------------------------------------------------------
def _qkv(x: jax.Array, p: Dict, cfg: ArchConfig, dm: ModelDims):
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    B, S = x.shape[0], x.shape[1]
    return (q.reshape(B, S, dm.h, dm.hd),
            k.reshape(B, S, dm.kh, dm.hd),
            v.reshape(B, S, dm.kh, dm.hd))


def self_attn_train(x: jax.Array, p: Dict, cfg: ArchConfig, dm: ModelDims,
                    positions: jax.Array, causal: bool = True,
                    opts: Optional[Dict] = None) -> jax.Array:
    """Full-sequence self-attention sublayer (pre-norm, residual added by caller)."""
    h = norm(x, p, cfg.norm)
    q, k, v = _qkv(h, p, cfg, dm)
    if cfg.rope_theta:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    o = flash_attention(q, k, v, causal=causal, window=cfg.sliding_window,
                        **(opts or {}))
    return o.reshape(*x.shape[:2], dm.h * dm.hd) @ p["wo"]


def self_attn_prefill(x, p, cfg: ArchConfig, dm: ModelDims, positions,
                      opts: Optional[Dict] = None):
    """Like train, but also returns (k, v) for the cache."""
    h = norm(x, p, cfg.norm)
    q, k, v = _qkv(h, p, cfg, dm)
    if cfg.rope_theta:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    o = flash_attention(q, k, v, causal=True, window=cfg.sliding_window,
                        **(opts or {}))
    return o.reshape(*x.shape[:2], dm.h * dm.hd) @ p["wo"], (k, v)


def self_attn_decode(x1, p, cfg: ArchConfig, dm: ModelDims, cache_k, cache_v, cur_len):
    """x1: (B,1,D). cache_k/v: (B,S,Kh,hd). Returns (out, new_k, new_v)."""
    h = norm(x1, p, cfg.norm)
    q, k, v = _qkv(h, p, cfg, dm)
    if cfg.rope_theta:
        pos = jnp.full((1,), 0, jnp.int32) + cur_len
        q = apply_rope(q, pos[None, :], cfg.rope_theta)
        k = apply_rope(k, pos[None, :], cfg.rope_theta)
    ck = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                      (0, cur_len, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                      (0, cur_len, 0, 0))
    o = decode_attention(q, ck, cv, cur_len=cur_len + 1, window=cfg.sliding_window)
    return o.reshape(x1.shape[0], 1, dm.h * dm.hd) @ p["wo"], ck, cv


# ----------------------------------------------------------------------
def cross_kv(memory: jax.Array, p: Dict, cfg: ArchConfig, dm: ModelDims):
    B, S = memory.shape[:2]
    k = (memory @ p["wk"]).reshape(B, S, dm.kh, dm.hd)
    v = (memory @ p["wv"]).reshape(B, S, dm.kh, dm.hd)
    if cfg.qkv_bias:
        k = k + p["bk"].reshape(dm.kh, dm.hd)
        v = v + p["bv"].reshape(dm.kh, dm.hd)
    return k, v


def cross_attn(x, memory_kv, p, cfg: ArchConfig, dm: ModelDims,
               opts: Optional[Dict] = None):
    """Cross-attention sublayer: queries from x, K/V precomputed from memory."""
    k, v = memory_kv
    h = norm(x, p, cfg.norm)
    B, S = x.shape[:2]
    q = (h @ p["wq"]).reshape(B, S, dm.h, dm.hd)
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(dm.h, dm.hd)
    opts = dict(opts or {})
    opts.pop("block_skip", None)        # no causal structure to skip
    o = flash_attention(q, k, v, causal=False, **opts)
    return o.reshape(B, S, dm.h * dm.hd) @ p["wo"]
