"""Algorithm 3 — entry-node generation.

The faithful monotone-stack construction (``entry_stacks``) keeps, for every
right endpoint r, the suffix-minima of δ(v, centroid) over ranks ≤ r — the
paper proves the expected stack size is O(log n) (Lemma 4.8).

Query-time equivalence: the entry for [L, R] is the stack element of q_R with
the smallest attribute ≥ L, which *is* argmin_{id∈[L,R]} δ(v_id, c).  We
therefore answer queries with an O(1) range-argmin sparse table over the same
distance array; ``tests/test_entry.py`` property-checks stack-vs-RMQ equality.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def centroid_dists(vecs: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    c = vecs.mean(axis=0)
    d = np.sum((vecs - c) ** 2, axis=1)
    return c, d.astype(np.float32)


def entry_stacks(dist_c: np.ndarray) -> List[List[int]]:
    """Faithful Algorithm 3: returns the stack q after processing each v_i."""
    stacks: List[List[int]] = []
    q: List[int] = []
    for i, d in enumerate(dist_c):
        while q and dist_c[q[-1]] > d:
            q.pop()
        q.append(i)
        stacks.append(list(q))
    return stacks


def entry_from_stack(stacks: List[List[int]], dist_c: np.ndarray,
                     lo: int, hi: int) -> int:
    """Paper query rule: take q at the in-range node with largest rank ≤ hi,
    pick its element with the smallest attribute value ≥ lo."""
    q = stacks[hi]
    for node in q:                      # ascending attribute order
        if node >= lo:
            return node
    raise ValueError("empty range")


# ----------------------------------------------------------------------
def build_rmq(dist_c: np.ndarray) -> np.ndarray:
    """Sparse table of range-argmin ids: (LOG, n) int32."""
    n = len(dist_c)
    logn = max(1, int(np.floor(np.log2(max(n, 1)))) + 1)
    table = np.zeros((logn, n), np.int32)
    table[0] = np.arange(n)
    j = 1
    while (1 << j) <= n:
        span = 1 << (j - 1)
        a = table[j - 1, : n - 2 * span + 1]
        b = table[j - 1, span: n - span + 1]
        table[j, : n - 2 * span + 1] = np.where(dist_c[a] <= dist_c[b], a, b)
        # tail: clamp to previous level
        table[j, n - 2 * span + 1:] = table[j - 1, n - 2 * span + 1:]
        j += 1
    return table


def rmq_query_np(table: np.ndarray, dist_c: np.ndarray, lo: int, hi: int) -> int:
    ln = hi - lo + 1
    j = int(np.floor(np.log2(ln)))
    a = table[j, lo]
    b = table[j, hi - (1 << j) + 1]
    return int(a if dist_c[a] <= dist_c[b] else b)


def rmq_query_jax(table: jax.Array, dist_c: jax.Array,
                  lo: jax.Array, hi: jax.Array) -> jax.Array:
    """Vectorizable O(1) range-argmin (entry node for [lo, hi])."""
    ln = (hi - lo + 1).astype(jnp.float32)
    j = jnp.floor(jnp.log2(jnp.maximum(ln, 1.0))).astype(jnp.int32)
    a = table[j, lo]
    b = table[j, hi - (1 << j) + 1]
    return jnp.where(dist_c[a] <= dist_c[b], a, b)
