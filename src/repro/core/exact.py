"""Exact O(n³) oracles: MRNG (Def 2.1-style, edge-witness) and RRNG (Def 3.1).

Both use the paper's "basic approach" (§3.2): process all pairs in ascending
distance order; the longest edge of a triangle can only be pruned by already-
decided shorter *edges*.  Used by tests and tiny-scale demos only.

Convention: points are pre-sorted by attribute, so index order == attribute
order (ids are attribute ranks).
"""
from __future__ import annotations

import numpy as np


def pair_dists(vecs: np.ndarray) -> np.ndarray:
    n2 = np.sum(vecs * vecs, axis=1)
    d = n2[:, None] - 2.0 * vecs @ vecs.T + n2[None, :]
    np.fill_diagonal(d, np.inf)
    return np.maximum(d, 0.0)


def _pairs_ascending(d: np.ndarray):
    n = d.shape[0]
    iu, ju = np.triu_indices(n, 1)
    order = np.argsort(d[iu, ju], kind="stable")
    return iu[order], ju[order]


def exact_rrng(vecs: np.ndarray) -> np.ndarray:
    """Directed adjacency (n,n) bool: out[x,y].

    Formalization note (DESIGN.md §7): Definition 3.1 is stated on unordered
    pairs, but Theorem 3.3's proof needs the witness edge to hang off the
    *search* node and Algorithm 1 prunes per-node out-edges — the consistent
    reading is a directed graph where out-edge x→y is pruned iff some kept
    out-edge x→z has δ(x,z)<δ(x,y), δ(y,z)<δ(x,y) and z strictly attribute-
    between x and y.  Witnesses are both gap- and distance-smaller than the
    pruned edge, so distance-ascending (here) and gap-ascending (Algorithm 1)
    processing provably reach the same fixpoint (Thm 4.3)."""
    d = pair_dists(vecs)
    n = d.shape[0]
    adj = np.zeros((n, n), bool)
    for x, y in zip(*_pairs_ascending(d)):
        dxy = d[x, y]
        for s, t in ((x, y), (y, x)):
            zs = np.flatnonzero(adj[s])
            zs = zs[(zs > min(s, t)) & (zs < max(s, t))]
            pruned = np.any((d[s, zs] < dxy) & (d[t, zs] < dxy))
            if not pruned:
                adj[s, t] = True
    return adj


def exact_mrng(vecs: np.ndarray) -> np.ndarray:
    """Directed MRNG-style oracle: same scheme without attribute-betweenness
    (edge-witness lune pruning, pairs in ascending distance)."""
    d = pair_dists(vecs)
    n = d.shape[0]
    adj = np.zeros((n, n), bool)
    for x, y in zip(*_pairs_ascending(d)):
        dxy = d[x, y]
        for s, t in ((x, y), (y, x)):
            zs = np.flatnonzero(adj[s])
            pruned = np.any((d[s, zs] < dxy) & (d[t, zs] < dxy))
            if not pruned:
                adj[s, t] = True
    return adj


# ----------------------------------------------------------------------
def greedy_monotonic_reachable(vecs: np.ndarray, adj: np.ndarray,
                               src: int, dst: int) -> bool:
    """Greedy walk: move to any neighbor strictly closer to dst (Thm 3.3)."""
    d = pair_dists(vecs)
    np.fill_diagonal(d, 0.0)      # reaching dst must register as distance 0
    cur = src
    for _ in range(len(vecs) + 1):
        if cur == dst:
            return True
        nbrs = np.flatnonzero(adj[cur])
        if len(nbrs) == 0:
            return False
        best = nbrs[np.argmin(d[nbrs, dst])]
        if d[best, dst] < d[cur, dst] or best == dst:
            cur = best
        else:
            return False
    return False


def induced(adj: np.ndarray, lo: int, hi: int) -> np.ndarray:
    """Subgraph induced by rank interval [lo, hi] inclusive."""
    return adj[lo:hi + 1, lo:hi + 1]


def strongly_connected(adj: np.ndarray) -> bool:
    n = adj.shape[0]
    if n == 0:
        return True
    seen = np.zeros(n, bool)
    stack = [0]
    seen[0] = True
    while stack:
        u = stack.pop()
        for v in np.flatnonzero(adj[u]):
            if not seen[v]:
                seen[v] = True
                stack.append(v)
    return bool(seen.all())
