"""Sharded RNSG construction: Algorithm 2 as one batched jit/shard_map body.

The single-host pipeline (``build_rnsg``) is embarrassingly parallel in the
attribute-rank dimension: every per-node result — the exact-KNN row, the
±ef_attribute rank window, the gap-sorted candidate arrays, and the
Algorithm-1 keep/prune recurrence — depends only on that node's own row and
the (read-only) corpus.  This module shards all four stages by contiguous
attribute-rank **slab** across the mesh's ``data`` axis with one
``shard_map`` dispatch per build:

* **exact KNN** — each device scores its slab's query rows against the
  replicated corpus in the same 512-row blocks (and the same pad geometry)
  as ``index.knn.exact_knn``, so every real row's top-k is the bit-identical
  float comparison sequence;
* **rank window + gap sort** — pure id arithmetic on the slab's global rank
  offsets.  The ±ef_attribute window rows a slab edge needs ("halo" rows) come
  free from the replicated corpus — a future multi-host port would exchange
  only those 2·ef_attribute boundary rows per slab;
* **prune + pack** — the shared traceable bodies from ``core.pruning``
  (``prune_side`` / ``pack_kept``), gathering candidate vectors from the
  replicated corpus.

Because every stage is row-independent and the sorts are *stable* (a stable
sort's permutation is uniquely determined by its keys, independent of the
implementation), the sharded build is **bit-identical** to ``build_rnsg``
for every shard count — property-tested across S ∈ {1, 2, 8} in
``tests/test_build_sharded.py``.

The corpus is replicated per device (the dominant build costs — the O(n²d)
KNN matmuls and the O(n·C²·d) prune tiles — shard perfectly; the replicated
operand is the standard single-pod trade, and the slab outputs are the only
cross-device traffic).  Entry structures (centroid distances + RMQ table)
are O(n·d) host work and stay global.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.construction import RNSGGraph
from repro.core.entry import build_rmq, centroid_dists
from repro.core.pruning import pack_kept, prune_side
from repro.index.knn import sq_dists
from repro.parallel.sharding import shard_map_compat

_PAD_VAL = 1e9          # must match index.knn.exact_knn's pad rows


def _gap_sorted_side_jnp(ids, n: int, knn_ids, ef_attribute: int, side: str):
    """jnp port of ``construction._gap_sorted_side`` over one slab.

    ``ids``: (B, 1) global attribute ranks of the slab rows.  Same
    candidate set, same stable sorts — stable argsort permutations are
    unique given the keys, so the output matches the numpy reference
    bit for bit (gap values fit int32: |cand - id| < n < 2³¹).
    """
    big = np.iinfo(np.int32).max // 2
    win_off = jnp.arange(1, ef_attribute + 1, dtype=jnp.int32)[None, :]
    win = ids - win_off if side == "l" else ids + win_off
    win_ok = (win >= 0) & (win < n)
    kn = knn_ids
    kn_ok = ((kn >= 0) & (kn < n)
             & ((kn < ids) if side == "l" else (kn > ids)))
    cand = jnp.concatenate([jnp.where(win_ok, win, -1),
                            jnp.where(kn_ok, kn, -1)], axis=1)
    gap = jnp.where(cand >= 0, jnp.abs(cand - ids), big)
    order = jnp.argsort(gap, axis=1, stable=True)
    cand = jnp.take_along_axis(cand, order, axis=1)
    gap = jnp.take_along_axis(gap, order, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros((cand.shape[0], 1), bool),
         (cand[:, 1:] == cand[:, :-1]) & (cand[:, 1:] >= 0)], axis=1)
    cand = jnp.where(dup, -1, cand)
    gap = jnp.where(dup, big, gap)
    order = jnp.argsort(gap, axis=1, stable=True)
    return jnp.take_along_axis(cand, order, axis=1).astype(jnp.int32)


def _slab_nbrs_body(n: int, n_pad: int, rows_per_shard: int, block: int,
                    k: int, ef_attribute: int, m: int, axis: str):
    """The per-device shard_map body: slab rows -> slab adjacency."""
    half = max(m // 2, 1)

    def body(q_slab, corpus):
        # q_slab: (rows_per_shard, d) this slab's rows (pad rows = 1e9);
        # corpus: (n_pad, d) replicated, identical to exact_knn's padding
        row0 = jax.lax.axis_index(axis) * rows_per_shard

        def knn_block(i):
            q = jax.lax.dynamic_slice_in_dim(q_slab, i * block, block)
            d = sq_dists(q, corpus)
            rows = row0 + i * block + jnp.arange(block)
            # exclude self; clamp keeps shard-pad rows (global id >= n_pad,
            # results discarded) in bounds without touching real rows
            d = d.at[jnp.arange(block),
                     jnp.minimum(rows, n_pad - 1)].set(jnp.inf)
            _, ni = jax.lax.top_k(-d, k)
            return ni

        knn = jax.lax.map(knn_block,
                          jnp.arange(rows_per_shard // block))
        knn = knn.reshape(rows_per_shard, k)
        # pad-row ids (>= n) never survive: the gap-sort side mask drops
        # them exactly like the host pipeline's kn < n bound
        ids = (row0 + jnp.arange(rows_per_shard, dtype=jnp.int32))[:, None]
        cand_l = _gap_sorted_side_jnp(ids, n, knn, ef_attribute, "l")
        cand_r = _gap_sorted_side_jnp(ids, n, knn, ef_attribute, "r")

        def prune_block(i):
            xv = jax.lax.dynamic_slice_in_dim(q_slab, i * block, block)
            cl = jax.lax.dynamic_slice_in_dim(cand_l, i * block, block)
            cr = jax.lax.dynamic_slice_in_dim(cand_r, i * block, block)
            kept_l = prune_side(xv, cl, corpus[jnp.maximum(cl, 0)], half)
            kept_r = prune_side(xv, cr, corpus[jnp.maximum(cr, 0)], half)
            return pack_kept(cl, kept_l, cr, kept_r, m)

        nbrs = jax.lax.map(prune_block,
                           jnp.arange(rows_per_shard // block))
        return nbrs.reshape(rows_per_shard, m)

    return body


def build_rnsg_sharded(vectors: np.ndarray, attrs: np.ndarray, *,
                       n_shards: Optional[int] = None, mesh: Optional[Mesh] = None,
                       axis: str = "data", m: int = 32, ef_spatial: int = 32,
                       ef_attribute: int = 48, block: int = 512,
                       reverse_edges: bool = False,
                       reverse_cap: Optional[int] = None) -> RNSGGraph:
    """Sharded Algorithm 2 — bit-identical to ``build_rnsg`` (exact KNN).

    ``n_shards`` defaults to the mesh's ``axis`` size (or the local device
    count when no mesh is given); a one-axis mesh over the first
    ``n_shards`` local devices is built when none is passed.  ``block``
    must match the exact-KNN row block (512) for bit-identical float
    geometry — it is exposed only for tests.
    """
    t0 = time.perf_counter()
    vectors = np.asarray(vectors, np.float32)
    attrs = np.asarray(attrs, np.float32)
    n = len(attrs)
    if mesh is None:
        devs = jax.devices()
        n_shards = n_shards or len(devs)
        if n_shards > len(devs):
            raise ValueError(f"build_rnsg_sharded: n_shards={n_shards} "
                             f"exceeds the {len(devs)} available devices")
        mesh = Mesh(np.asarray(devs[:n_shards]), (axis,))
    else:
        n_shards = n_shards or mesh.shape[axis]
        if n_shards != mesh.shape[axis]:
            raise ValueError(f"build_rnsg_sharded: n_shards={n_shards} != "
                             f"mesh axis {axis!r} size {mesh.shape[axis]}")
    k_eff = min(ef_spatial, n - 1)
    if k_eff < 1:               # degenerate corpus: nothing to shard
        from repro.core.construction import build_rnsg
        g = build_rnsg(vectors, attrs, m=m, ef_spatial=ef_spatial,
                       ef_attribute=ef_attribute,
                       reverse_edges=reverse_edges, reverse_cap=reverse_cap)
        g.meta["shards"] = n_shards
        return g

    order = np.argsort(attrs, kind="stable")
    vs, as_ = vectors[order], attrs[order]

    # corpus padding identical to exact_knn (pad rows sit at 1e9); the
    # query-side slab padding extends further so every shard holds the
    # same whole number of 512-row blocks
    n_pad = n + (-n) % block
    rows_per_shard = -(-n_pad // (n_shards * block)) * block
    total = n_shards * rows_per_shard
    corpus = np.full((n_pad, vs.shape[1]), _PAD_VAL, np.float32)
    corpus[:n] = vs
    queries = np.full((total, vs.shape[1]), _PAD_VAL, np.float32)
    queries[:n] = vs

    body = _slab_nbrs_body(n, n_pad, rows_per_shard, block, k_eff,
                           ef_attribute, m, axis)
    fn = jax.jit(shard_map_compat(body, mesh,
                                  in_specs=(P(axis), P()),
                                  out_specs=P(axis)))
    nbrs = np.asarray(fn(jnp.asarray(queries), jnp.asarray(corpus)))[:n]

    if reverse_edges:
        from repro.index.baselines import add_reverse_edges
        nbrs = add_reverse_edges(nbrs, reverse_cap or int(m * 1.25))

    c, dist_c = centroid_dists(vs)
    rmq = build_rmq(dist_c)
    dt = time.perf_counter() - t0
    return RNSGGraph(vecs=vs, attrs=as_, nbrs=nbrs,
                     order=order.astype(np.int32),
                     centroid=c.astype(np.float32), dist_c=dist_c, rmq=rmq,
                     build_seconds=dt,
                     meta=dict(m=m, ef_spatial=ef_spatial,
                               ef_attribute=ef_attribute, knn="exact",
                               shards=n_shards))
