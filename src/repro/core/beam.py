"""Range-filtered beam search over the RNSG, in pure ``jax.lax`` control flow.

The search never materializes the induced subgraph: the range filter is an
id-interval mask applied to neighbor expansions (ids are attribute ranks), and
Theorem 4.7 (heredity) guarantees this equals searching the induced RNSG.

Fixed shapes throughout: candidate pool = sorted (ef,) arrays, visited set =
(n,) bitmask, one `while_loop` per query, `vmap` over the query batch.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

INF = jnp.inf


@partial(jax.jit, static_argnames=("k", "ef", "max_steps", "use_kernel",
                                   "early_stop"))
def beam_search_batch(vecs: jax.Array, nbrs: jax.Array, qv: jax.Array,
                      lo: jax.Array, hi: jax.Array, entry: jax.Array,
                      *, k: int = 10, ef: int = 64, max_steps: int = 0,
                      use_kernel: bool = False, early_stop: bool = True):
    """vecs:(n,d) f32; nbrs:(n,m) i32; qv:(Q,d); lo/hi/entry:(Q,) rank ids.
    Returns (ids:(Q,k) i32 rank ids (-1 pad), dists:(Q,k), stats dict).

    ``early_stop`` exits the while_loop as soon as no finite unexpanded
    candidate remains.  When the in-range node count is below ``ef`` the
    pool never fills, so the worst-candidate bound stays +inf and the
    legacy condition (kept under ``early_stop=False`` for A/B benchmarks)
    burns the full ``steps_cap``; the results are identical either way —
    the extra iterations re-expand the best already-expanded node, whose
    neighbors are all visited."""
    n, m = nbrs.shape
    steps_cap = max_steps or 8 * ef + 64

    if use_kernel:
        from repro.kernels.ops import gather_dist as _gd
    else:
        _gd = None

    def neighbor_dists(q, ids, valid):
        if _gd is not None:
            d = _gd(vecs, ids, q)
        else:
            nv = vecs[jnp.maximum(ids, 0)]
            diff = nv - q[None, :]
            d = jnp.sum(diff * diff, axis=-1)
        return jnp.where(valid, d, INF)

    def one_query(q, L, R, e0):
        empty = L > R
        e0 = jnp.atleast_1d(e0)[:ef]                          # (E,) multi-entry
        ev = (e0 >= 0) & ~empty
        e0c = jnp.clip(e0, 0, n - 1)
        ne = e0.shape[0]
        d0 = jnp.sum(jnp.square(vecs[e0c] - q[None, :]), axis=-1)
        d0 = jnp.where(ev, d0, INF)
        cand_ids = jnp.full((ef,), -1, jnp.int32).at[:ne].set(e0c.astype(jnp.int32))
        cand_d = jnp.full((ef,), INF).at[:ne].set(d0)
        expanded = jnp.zeros((ef,), bool).at[:ne].set(~ev)
        visited = jnp.zeros((n + 1,), bool).at[jnp.where(ev, e0c, n)].set(True)

        def cond(st):
            cand_d, expanded, _, _, steps, _ = st
            unexp = jnp.where(~expanded, cand_d, INF)
            best = jnp.min(unexp)
            worst = jnp.max(jnp.where(jnp.isfinite(cand_d), cand_d, -INF))
            worst = jnp.where(jnp.any(~jnp.isfinite(cand_d)), INF, worst)
            go = (best <= worst) & (steps < steps_cap)
            if early_stop:
                go &= jnp.isfinite(best)
            return go

        def body(st):
            cand_d, expanded, cand_ids, visited, steps, ndist = st
            unexp = jnp.where(~expanded, cand_d, INF)
            bi = jnp.argmin(unexp)
            expanded = expanded.at[bi].set(True)
            node = jnp.maximum(cand_ids[bi], 0)
            nb = nbrs[node]                                   # (m,)
            valid = (nb >= 0) & (nb >= L) & (nb <= R)
            valid = valid & ~visited[jnp.maximum(nb, 0)]
            visited = visited.at[jnp.where(valid, nb, n)].set(True)
            d_nb = neighbor_dists(q, nb, valid)
            ids_all = jnp.concatenate([cand_ids, nb.astype(jnp.int32)])
            d_all = jnp.concatenate([cand_d, d_nb])
            exp_all = jnp.concatenate([expanded, ~valid])     # invalid: never expand
            order = jnp.argsort(d_all)[:ef]
            return (d_all[order], exp_all[order], ids_all[order], visited,
                    steps + 1, ndist + jnp.sum(valid))

        st = (cand_d, expanded, cand_ids, visited,
              jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
        cand_d, _, cand_ids, _, steps, ndist = jax.lax.while_loop(cond, body, st)
        out_ids = jnp.where(jnp.isfinite(cand_d[:k]), cand_ids[:k], -1)
        out_d = cand_d[:k]
        return out_ids, out_d, steps, ndist

    ids, dists, steps, ndist = jax.vmap(one_query)(qv, lo, hi, entry)
    return ids, dists, {"hops": steps, "ndist": ndist}
