"""Range-filtered beam search over the RNSG, in pure ``jax.lax`` control flow.

The search never materializes the induced subgraph: the range filter is an
id-interval mask applied to neighbor expansions (ids are attribute ranks), and
Theorem 4.7 (heredity) guarantees this equals searching the induced RNSG.

Two hot paths share one ``while_loop``-per-query / ``vmap``-over-batch shape:

* ``beam_width=1`` — the legacy single-node expansion: candidate pool =
  (ef,) arrays re-argsorted each hop, visited set = (n+1,) bitmask.  Kept
  verbatim as the A/B oracle (every parity test doubles as a correctness
  check of the batched path).
* ``beam_width=B>1`` — kernel-fused batched expansion: each iteration pops
  the best ``B`` unexpanded candidates, scores all ``B*m`` neighbors in one
  fused gather+score call, folds them into the sorted pool with a bounded
  O(ef+B*m) merge (sort only the fresh distances, then a stable
  two-pointer merge via ``searchsorted`` — never a full pool argsort), and
  tracks visited nodes in a **fixed-size lossy hash table** (2-probe,
  open-addressed, sized by ``ef*m`` — independent of the corpus size n, so
  a vmapped batch carries (Q, H) state instead of (Q, n+1)).  Hash
  collisions only ever cause false *negatives*: a forgotten node is
  re-scored, and the merge provably drops it (the pool's worst distance is
  monotonically non-increasing once full), so results stay exact.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

INF = jnp.inf

# Knuth / Murmur-style odd multipliers for the two probe hashes.
_HASH1 = 2654435761
_HASH2 = 2246822519


def visited_table_size(ef: int, m: int) -> int:
    """Slots in the per-query lossy visited table (power of two).

    A search scores ~ef·m̄ distinct nodes (the cost model's ``ndist_per_ef``
    prior), but most re-discoveries are already caught by the pool-
    membership dedup, so ~half a slot per potential insertion keeps the
    collision — i.e. re-score — rate in the low percent while the carried
    (Q, H) loop state stays small (the table is copied once per iteration
    on backends that can't scatter in place, so oversizing it costs more
    than the re-scores it prevents).  Deliberately **independent of n**:
    this is the whole point of replacing the (n+1,) bitmask."""
    target = max(int(ef), 1) * max(int(m), 4) // 2
    size = 1 << (target - 1).bit_length()
    return int(min(max(size, 256), 1 << 13))


def _hash_slots(ids: jax.Array, size: int) -> Tuple[jax.Array, jax.Array]:
    """Two independent probe slots in [0, size) for each id (size pow2)."""
    bits = int(size).bit_length() - 1
    u = ids.astype(jnp.uint32)
    h1 = ((u * jnp.uint32(_HASH1)) >> (32 - bits)).astype(jnp.int32)
    h2 = ((u * jnp.uint32(_HASH2)) >> (32 - bits)).astype(jnp.int32)
    return h1, h2


def _table_insert(table: jax.Array, ids: jax.Array, size: int) -> jax.Array:
    """Insert ids (−1 = skip) into the 2-probe table ((size+1,), slot
    ``size`` is the write sink).  First probe wins if its slot is empty or
    already holds the id; otherwise the second probe is overwritten —
    lossy by design, the evicted id is merely re-scored if met again."""
    valid = ids >= 0
    h1, h2 = _hash_slots(ids, size)
    cur = table[h1]
    slot = jnp.where((cur == -1) | (cur == ids), h1, h2)
    slot = jnp.where(valid, slot, size)
    return table.at[slot].set(jnp.where(valid, ids, -1))


def _table_lookup(table: jax.Array, ids: jax.Array, size: int) -> jax.Array:
    """Membership test: exact-positive (the slot stores the id itself, so a
    hit is never spurious), lossy-negative (an evicted id reads as new)."""
    h1, h2 = _hash_slots(ids, size)
    return (table[h1] == ids) | (table[h2] == ids)


def _merge_sorted(pool_d, pool_i, pool_e, fresh_d, fresh_i, fresh_e, ef: int):
    """Stable bounded merge: two distance-sorted candidate lists -> the best
    ``ef``.  The batched path's replacement for the legacy full argsort
    over the (ef+m) pool: one ``searchsorted`` places every pool entry in
    the merged order (pool entries win distance ties, matching the stable
    argsort over ``[pool, fresh]`` the legacy path performs), a second
    inverts that placement so each output lane *gathers* its element —
    scatter-free on purpose, vmapped scatters serialize on CPU/XLA while
    gathers vectorize."""
    f = fresh_d.shape[0]
    # the two searchsorted calls below are a sorted-list *merge*, not rank
    # resolution — exempted from the single-source-resolve guard
    pos_p = jnp.arange(ef) + jnp.searchsorted(                # sorted-merge
        fresh_d, pool_d, side="left")
    j = jnp.arange(ef)
    i = jnp.searchsorted(pos_p, j, side="left")               # sorted-merge
    ic = jnp.minimum(i, ef - 1)
    is_pool = pos_p[ic] == j
    jf = jnp.clip(j - i, 0, f - 1)                # fresh index for non-pool lanes
    md = jnp.where(is_pool, pool_d[ic], fresh_d[jf])
    mi = jnp.where(is_pool, pool_i[ic], fresh_i[jf])
    me = jnp.where(is_pool, pool_e[ic], fresh_e[jf])
    return md, mi, me


def rerank_pool(vecs, pool_ids, qv, k: int, use_kernel: bool):
    """Exact f32 rescore of each query's final candidate pool — the rerank
    stage of the quantized beam: traversal ordered by quantized distances,
    the returned top-k rescored against the f32 vectors.  Pool ids are
    sorted ascending first (``sort_candidates``) so the stable tie-breaking
    of the top-k matches the exact path's tie-toward-lower-rank."""
    from repro.kernels.quantize import sort_candidates
    ids_s = sort_candidates(pool_ids)                        # (Q, ef)
    if use_kernel and k <= 128:
        from repro.kernels.ops import gather_rerank
        return gather_rerank(vecs, ids_s, qv, k=k)
    rows = vecs[jnp.maximum(ids_s, 0)]                       # (Q, ef, d)
    d2 = jnp.sum(jnp.square(rows - qv[:, None, :]), axis=-1)
    d2 = jnp.where(ids_s >= 0, d2, INF)
    neg, sel = jax.lax.top_k(-d2, k)
    ids = jnp.where(jnp.isfinite(neg), jnp.take_along_axis(ids_s, sel,
                                                           axis=1), -1)
    return ids, -neg


def _pool_finish(cand_d, cand_ids, live, k: int, quant):
    """Final per-query pool stage shared by both expansion paths: drop
    tombstoned candidates (``live`` (n,) bool — FreshDiskANN semantics:
    deleted nodes stay *traversable* routing nodes all through the search,
    they just never leave it), then slice the top-k, or hand the pool to
    the f32 rerank.  The argsort after masking is stable, so surviving
    candidates keep their ascending-distance / tie-toward-lower-rank
    order."""
    if live is not None:
        dead = (cand_ids < 0) | ~live[jnp.maximum(cand_ids, 0)]
        cand_d = jnp.where(dead, INF, cand_d)
        o = jnp.argsort(cand_d)
        cand_d, cand_ids = cand_d[o], cand_ids[o]
    if quant is not None:           # return the full pool for the f32 rerank
        return jnp.where(jnp.isfinite(cand_d), cand_ids, -1), cand_d
    return (jnp.where(jnp.isfinite(cand_d[:k]), cand_ids[:k], -1),
            cand_d[:k])


@partial(jax.jit, static_argnames=("k", "ef", "max_steps", "use_kernel",
                                   "early_stop", "beam_width"))
def beam_search_batch(vecs: jax.Array, nbrs: jax.Array, qv: jax.Array,
                      lo: jax.Array, hi: jax.Array, entry: jax.Array,
                      *, k: int = 10, ef: int = 64, max_steps: int = 0,
                      use_kernel: bool = False, early_stop: bool = True,
                      beam_width: int = 1, quant=None, live=None):
    """vecs:(n,d) f32; nbrs:(n,m) i32; qv:(Q,d); lo/hi/entry:(Q,) rank ids.
    Returns (ids:(Q,k) i32 rank ids (-1 pad), dists:(Q,k), stats dict).

    ``quant=(data, scale)`` switches neighbor scoring to the quantized
    corpus copy (``data``: (n,d) int8/bf16 in the same rank order;
    ``scale``: (d,) f32 per-dim dequant factors, or None for bf16) — the
    traversal then moves 4x/2x fewer bytes per scored neighbor, and the
    final pool is rescored in f32 (``rerank_pool``) before the top-k is
    taken, so whenever the pool saw every true neighbor (any time the f32
    search would return them, e.g. the exhaustive ``ef ≥ |interval|``
    regime) the returned id set is exactly the f32 one.

    ``early_stop`` exits the while_loop as soon as no finite unexpanded
    candidate remains.  When the in-range node count is below ``ef`` the
    pool never fills, so the worst-candidate bound stays +inf and the
    legacy condition (kept under ``early_stop=False`` for A/B benchmarks)
    burns the full ``steps_cap``; the results are identical either way —
    the extra iterations re-expand the best already-expanded node, whose
    neighbors are all visited.

    ``beam_width=B>1`` expands the best B unexpanded candidates per
    iteration (batched-expansion path, see module docstring; widths beyond
    ``ef`` are clamped — the pool only ever holds ``ef`` candidates);
    ``hops`` in the stats then counts *iterations* (≈ node expansions / B),
    while ``ndist`` stays the number of scored neighbors and is comparable
    across widths.

    ``live`` ((n,) bool, optional) is the streaming tombstone mask: dead
    nodes are traversed exactly like live ones (they keep the graph
    navigable — removing them would break the heredity argument) but are
    filtered out of the final pool before the top-k / rerank."""
    n, m = nbrs.shape
    steps_cap = max_steps or 8 * ef + 64
    if live is not None:
        live = live.astype(bool)

    if beam_width > 1:
        return _beam_batched(vecs, nbrs, qv, lo, hi, entry, k=k, ef=ef,
                             steps_cap=steps_cap, use_kernel=use_kernel,
                             early_stop=early_stop, beam_width=beam_width,
                             quant=quant, live=live)

    # traversal scores against the quantized copy when one is given (the
    # dtype is trace-static, so the scale branch costs nothing at runtime)
    score_x, score_scale = (vecs, None) if quant is None else quant

    if use_kernel:
        from repro.kernels.ops import gather_dist as _gd
    else:
        _gd = None

    def neighbor_dists(q, ids, valid):
        if _gd is not None:
            d = _gd(score_x, ids, q, scale=score_scale)
        else:
            nv = score_x[jnp.maximum(ids, 0)].astype(jnp.float32)
            if score_scale is not None:
                nv = nv * score_scale[None, :]
            diff = nv - q[None, :]
            d = jnp.sum(diff * diff, axis=-1)
        return jnp.where(valid, d, INF)

    def entry_dists(q, e0c, ev):
        nv = score_x[e0c].astype(jnp.float32)
        if score_scale is not None:
            nv = nv * score_scale[None, :]
        return jnp.where(ev, jnp.sum(jnp.square(nv - q[None, :]), axis=-1),
                         INF)

    def one_query(q, L, R, e0):
        empty = L > R
        e0 = jnp.atleast_1d(e0)[:ef]                          # (E,) multi-entry
        ev = (e0 >= 0) & ~empty
        e0c = jnp.clip(e0, 0, n - 1)
        ne = e0.shape[0]
        d0 = entry_dists(q, e0c, ev)
        cand_ids = jnp.full((ef,), -1, jnp.int32).at[:ne].set(e0c.astype(jnp.int32))
        cand_d = jnp.full((ef,), INF).at[:ne].set(d0)
        expanded = jnp.zeros((ef,), bool).at[:ne].set(~ev)
        visited = jnp.zeros((n + 1,), bool).at[jnp.where(ev, e0c, n)].set(True)

        def cond(st):
            cand_d, expanded, _, _, steps, _ = st
            unexp = jnp.where(~expanded, cand_d, INF)
            best = jnp.min(unexp)
            worst = jnp.max(jnp.where(jnp.isfinite(cand_d), cand_d, -INF))
            worst = jnp.where(jnp.any(~jnp.isfinite(cand_d)), INF, worst)
            go = (best <= worst) & (steps < steps_cap)
            if early_stop:
                go &= jnp.isfinite(best)
            return go

        def body(st):
            cand_d, expanded, cand_ids, visited, steps, ndist = st
            unexp = jnp.where(~expanded, cand_d, INF)
            bi = jnp.argmin(unexp)
            expanded = expanded.at[bi].set(True)
            node = jnp.maximum(cand_ids[bi], 0)
            nb = nbrs[node]                                   # (m,)
            valid = (nb >= 0) & (nb >= L) & (nb <= R)
            valid = valid & ~visited[jnp.maximum(nb, 0)]
            visited = visited.at[jnp.where(valid, nb, n)].set(True)
            d_nb = neighbor_dists(q, nb, valid)
            ids_all = jnp.concatenate([cand_ids, nb.astype(jnp.int32)])
            d_all = jnp.concatenate([cand_d, d_nb])
            exp_all = jnp.concatenate([expanded, ~valid])     # invalid: never expand
            order = jnp.argsort(d_all)[:ef]
            return (d_all[order], exp_all[order], ids_all[order], visited,
                    steps + 1, ndist + jnp.sum(valid))

        st = (cand_d, expanded, cand_ids, visited,
              jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
        cand_d, _, cand_ids, _, steps, ndist = jax.lax.while_loop(cond, body, st)
        out_ids, out_d = _pool_finish(cand_d, cand_ids, live, k, quant)
        return out_ids, out_d, steps, ndist

    ids, dists, steps, ndist = jax.vmap(one_query)(qv, lo, hi, entry)
    if quant is not None:
        ids, dists = rerank_pool(vecs, ids, qv, k, use_kernel)
    return ids, dists, {"hops": steps, "ndist": ndist}


# ======================================================================
# Batched multi-node expansion (beam_width > 1)
# ======================================================================
def _beam_batched(vecs, nbrs, qv, lo, hi, entry, *, k: int, ef: int,
                  steps_cap: int, use_kernel: bool, early_stop: bool,
                  beam_width: int, quant=None, live=None):
    n, m = nbrs.shape
    score_x, score_scale = (vecs, None) if quant is None else quant
    # the pool holds ef candidates, so at most ef can be unexpanded — a
    # wider request (e.g. --beam-width 128 at the default ef=64) is clamped
    # rather than rejected
    B = min(int(beam_width), ef)
    F = B * m                           # fresh neighbors per iteration
    H = visited_table_size(ef, m)
    # only the best min(F, ef) fresh candidates can survive the bounded
    # merge, so the fused kernel keeps a running top-fm in VMEM and the
    # full (F,) distance vector never leaves it
    fm = min(F, ef)

    if use_kernel:
        from repro.kernels.ops import gather_dist as _gd
        from repro.kernels.ops import gather_topk as _gtk
        kernel_topk = fm <= 128         # running top-k lives in one lane row
    else:
        _gd = _gtk = None
        kernel_topk = False

    def fresh_sorted(q, ids_f, valid):
        """(F,) masked neighbor ids -> distance-sorted (fm,) fresh list
        (ids -1 / dist inf beyond the valid entries)."""
        ids_m = jnp.where(valid, ids_f, -1)
        if kernel_topk:
            fi, fd = _gtk(score_x, ids_m, q, k=fm, scale=score_scale)
            return fd, fi
        if _gd is not None:
            d = jnp.where(valid, _gd(score_x, ids_f, q, scale=score_scale),
                          INF)
        else:
            nv = score_x[jnp.maximum(ids_f, 0)].astype(jnp.float32)
            if score_scale is not None:
                nv = nv * score_scale[None, :]
            diff = nv - q[None, :]
            d = jnp.where(valid, jnp.sum(diff * diff, axis=-1), INF)
        o = jnp.argsort(d)[:fm]         # sort F fresh values, never the pool
        return d[o], ids_m[o]

    def one_query(q, L, R, e0):
        empty = L > R
        e0 = jnp.atleast_1d(e0)[:ef]
        ev = (e0 >= 0) & ~empty
        e0c = jnp.clip(e0, 0, n - 1)
        ne = e0.shape[0]
        nv0 = score_x[e0c].astype(jnp.float32)
        if score_scale is not None:
            nv0 = nv0 * score_scale[None, :]
        d0 = jnp.sum(jnp.square(nv0 - q[None, :]), axis=-1)
        d0 = jnp.where(ev, d0, INF)
        cand_ids = jnp.full((ef,), -1, jnp.int32).at[:ne].set(
            e0c.astype(jnp.int32))
        cand_d = jnp.full((ef,), INF).at[:ne].set(d0)
        expanded = jnp.zeros((ef,), bool).at[:ne].set(~ev)
        o = jnp.argsort(cand_d)         # sort once; the merge keeps it sorted
        cand_d, cand_ids, expanded = cand_d[o], cand_ids[o], expanded[o]
        table = jnp.full((H + 1,), -1, jnp.int32)
        table = _table_insert(table, jnp.where(ev, e0c.astype(jnp.int32), -1),
                              H)

        def cond(st):
            cand_d, expanded, _, _, steps, _ = st
            unexp = jnp.where(~expanded, cand_d, INF)
            best = jnp.min(unexp)
            worst = jnp.max(jnp.where(jnp.isfinite(cand_d), cand_d, -INF))
            worst = jnp.where(jnp.any(~jnp.isfinite(cand_d)), INF, worst)
            go = (best <= worst) & (steps < steps_cap)
            if early_stop:
                go &= jnp.isfinite(best)
            return go

        def body(st):
            cand_d, expanded, cand_ids, table, steps, ndist = st
            # best B unexpanded: the pool is sorted, so they are the first
            # B selectable lanes
            lane = jnp.where(~expanded & jnp.isfinite(cand_d),
                             jnp.arange(ef), ef)
            lanes = jnp.sort(lane)[:B]                       # (B,)
            take = lanes < ef
            node = jnp.where(take, cand_ids[jnp.minimum(lanes, ef - 1)], -1)
            expanded = expanded | jnp.any(
                (jnp.arange(ef)[None, :] == lanes[:, None]) & take[:, None],
                axis=0)
            nb = nbrs[jnp.maximum(node, 0)]                  # (B, m)
            ids_f = nb.reshape(F).astype(jnp.int32)
            valid = ((ids_f >= 0) & (ids_f >= L) & (ids_f <= R)
                     & jnp.repeat(node >= 0, m))
            # intra-hop dedup: two expanded nodes may share a neighbor —
            # keep the first occurrence (the legacy path never sees this:
            # its single hop has unique neighbors)
            eq = ids_f[:, None] == ids_f[None, :]
            before = jnp.arange(F)[None, :] < jnp.arange(F)[:, None]
            valid &= ~jnp.any(eq & before & valid[None, :], axis=1)
            # pool-membership dedup: anything currently held in the pool is
            # by definition already scored (covers hash evictions of live
            # candidates — the exactness keystone, see module docstring)
            valid &= ~jnp.any(ids_f[:, None] == cand_ids[None, :], axis=1)
            # lossy visited set: false negatives fall through to a re-score
            valid &= ~_table_lookup(table, ids_f, H)
            table = _table_insert(table, jnp.where(valid, ids_f, -1), H)
            fd, fi = fresh_sorted(q, ids_f, valid)
            fe = fi < 0                                      # pads: never expand
            cand_d, cand_ids, expanded = _merge_sorted(
                cand_d, cand_ids, expanded, fd, fi, fe, ef)
            return (cand_d, expanded, cand_ids, table,
                    steps + 1, ndist + jnp.sum(valid))

        st = (cand_d, expanded, cand_ids, table,
              jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
        cand_d, _, cand_ids, _, steps, ndist = jax.lax.while_loop(
            cond, body, st)
        out_ids, out_d = _pool_finish(cand_d, cand_ids, live, k, quant)
        return out_ids, out_d, steps, ndist

    ids, dists, steps, ndist = jax.vmap(one_query)(qv, lo, hi, entry)
    if quant is not None:
        ids, dists = rerank_pool(vecs, ids, qv, k, use_kernel)
    return ids, dists, {"hops": steps, "ndist": ndist}
