"""Algorithm 2 — RNSG construction.

Pipeline: (1) approximate-or-exact KNN graph (spatial proximity); (2) ±ef_attribute
rank window (attribute proximity, Alg. 2 line 7 — index-based on the
attribute-sorted order); (3) per-side gap-sorted candidate arrays; (4) the
vectorized Algorithm-1 pruning engine.  Ids are attribute ranks throughout.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.core.entry import build_rmq, centroid_dists
from repro.core.pruning import prune_all_jax
from repro.index.knn import exact_knn, nndescent


@dataclass
class RNSGGraph:
    vecs: np.ndarray          # (n,d) f32, attribute-sorted
    attrs: np.ndarray         # (n,)  f32, ascending
    nbrs: np.ndarray          # (n,m) int32, -1 padded (attribute-rank ids)
    order: np.ndarray         # (n,)  original ids of each rank
    centroid: np.ndarray      # (d,)
    dist_c: np.ndarray        # (n,)  δ(v, centroid) (entry structure)
    rmq: np.ndarray           # (LOG,n) int32 range-argmin table
    build_seconds: float = 0.0
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def n(self) -> int:
        return self.vecs.shape[0]

    @property
    def m(self) -> int:
        return self.nbrs.shape[1]

    @property
    def n_edges(self) -> int:
        return int((self.nbrs >= 0).sum())

    @property
    def index_bytes(self) -> int:
        """Graph-structure bytes (adjacency + entry structures), excluding the
        raw vector payload which every method must store."""
        return self.nbrs.nbytes + self.rmq.nbytes + self.dist_c.nbytes

    def save(self, path: str) -> None:
        """Atomic single-file save: the npz is written to a sibling temp
        file, fsynced, and renamed over ``path`` — a crash mid-save never
        corrupts the only copy of the index (same idiom as
        ``QueryPlanner.save_calibration``).  The parent directory is
        fsynced after the rename (``repro.index.io.fsync_dir``) so the
        rename itself survives power failure, not just the file bytes.
        ``meta`` and ``build_seconds`` ride along as a JSON sidecar entry
        so ``load`` round-trips them."""
        from repro.index.io import fsync_dir
        if not path.endswith(".npz"):
            path += ".npz"          # match np.savez's implicit suffix
        arrays = {f.name: np.asarray(getattr(self, f.name))
                  for f in dataclasses.fields(self)
                  if f.name not in ("meta", "build_seconds")}
        info = json.dumps(dict(build_seconds=float(self.build_seconds),
                               meta=self.meta))
        tmp = f"{path}.tmp.{os.getpid()}"
        try:
            with open(tmp, "wb") as f:
                np.savez_compressed(
                    f, __meta__=np.frombuffer(info.encode(), np.uint8),
                    **arrays)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
            fsync_dir(os.path.dirname(os.path.abspath(path)))
        finally:
            if os.path.exists(tmp):
                os.remove(tmp)

    @classmethod
    def load(cls, path: str) -> "RNSGGraph":
        if not os.path.exists(path) and os.path.exists(path + ".npz"):
            path += ".npz"          # save() appends the suffix
        with np.load(path) as z:    # context manager: no leaked npz handle
            arrays = {k: z[k] for k in z.files
                      if k not in ("__meta__", "build_seconds")}
            if "__meta__" in z.files:
                info = json.loads(bytes(z["__meta__"]).decode())
                return cls(**arrays,
                           build_seconds=float(info.get("build_seconds", 0.0)),
                           meta=dict(info.get("meta", {})))
            # legacy layout: build_seconds stored as a 0-d array, no meta
            bs = (float(z["build_seconds"])
                  if "build_seconds" in z.files else 0.0)
            return cls(**arrays, build_seconds=bs, meta={})


def _gap_sorted_side(n: int, knn_ids: np.ndarray, ef_attribute: int,
                     side: str) -> np.ndarray:
    """Per-node candidate ids of one side, ascending rank-gap, -1 padded.
    Side candidates = attribute window ∪ same-side KNN neighbors."""
    k = knn_ids.shape[1]
    ch = ef_attribute + k
    ids = np.arange(n)[:, None]
    win_off = np.arange(1, ef_attribute + 1)[None, :]
    win = ids - win_off if side == "l" else ids + win_off          # (n, ef)
    win_ok = (win >= 0) & (win < n)
    kn = knn_ids.copy()
    # kn < n guards against out-of-range candidates (e.g. pad-row ids from a
    # k >= n exact_knn, or a caller-supplied approximate KNN graph): an id
    # >= n would flow into prune_all_jax's vector gathers and the final
    # adjacency, corrupting the index
    kn_ok = ((kn >= 0) & (kn < n)
             & ((kn < ids) if side == "l" else (kn > ids)))
    cand = np.concatenate([np.where(win_ok, win, -1),
                           np.where(kn_ok, kn, -1)], axis=1)        # (n, ch)
    gap = np.where(cand >= 0, np.abs(cand - ids), np.iinfo(np.int64).max // 2)
    order = np.argsort(gap, axis=1, kind="stable")
    cand = np.take_along_axis(cand, order, axis=1)
    gap = np.take_along_axis(gap, order, axis=1)
    dup = np.zeros_like(cand, bool)
    dup[:, 1:] = (cand[:, 1:] == cand[:, :-1]) & (cand[:, 1:] >= 0)
    cand = np.where(dup, -1, cand)
    gap = np.where(dup, np.iinfo(np.int64).max // 2, gap)
    order = np.argsort(gap, axis=1, kind="stable")
    return np.take_along_axis(cand, order, axis=1).astype(np.int32)


def build_rnsg(vectors: np.ndarray, attrs: np.ndarray, *, m: int = 32,
               ef_spatial: int = 32, ef_attribute: int = 48,
               knn_method: str = "exact", knn_iters: int = 6,
               seed: int = 0, knn_ids: Optional[np.ndarray] = None,
               reverse_edges: bool = False,
               reverse_cap: Optional[int] = None) -> RNSGGraph:
    """Algorithm 2.  ``reverse_edges=True`` adds NSG-style reverse edges
    (beyond-paper knob).  Heredity note: with an UNSATURATED cap the
    augmentation commutes with range induction (a reverse edge's endpoints
    share the original edge's range), so heredity is exact; once the degree
    cap saturates, boundary slots may differ between a global and an induced
    build — the default cap 1.25·m therefore makes heredity approximate
    (tested both ways in tests/test_search.py)."""
    t0 = time.perf_counter()
    vectors = np.asarray(vectors, np.float32)
    attrs = np.asarray(attrs, np.float32)
    n = len(attrs)
    order = np.argsort(attrs, kind="stable")
    vs, as_ = vectors[order], attrs[order]

    if knn_ids is None:
        # a corpus has at most n-1 true neighbors per node; asking for more
        # only returns pad/duplicate rows (tiny-corpus regression)
        k_eff = min(ef_spatial, n - 1)
        if k_eff < 1:
            knn_ids = np.full((n, 0), -1, np.int32)
        elif knn_method == "exact":
            _, knn_ids = exact_knn(vs, k_eff)
        else:
            _, knn_ids = nndescent(vs, k_eff, iters=knn_iters, seed=seed)
    cand_l = _gap_sorted_side(n, knn_ids, ef_attribute, "l")
    cand_r = _gap_sorted_side(n, knn_ids, ef_attribute, "r")
    nbrs = prune_all_jax(vs, cand_l, cand_r, m)
    if reverse_edges:
        from repro.index.baselines import add_reverse_edges
        nbrs = add_reverse_edges(nbrs, reverse_cap or int(m * 1.25))

    c, dist_c = centroid_dists(vs)
    rmq = build_rmq(dist_c)
    dt = time.perf_counter() - t0
    return RNSGGraph(vecs=vs, attrs=as_, nbrs=nbrs, order=order.astype(np.int32),
                     centroid=c.astype(np.float32), dist_c=dist_c, rmq=rmq,
                     build_seconds=dt,
                     meta=dict(m=m, ef_spatial=ef_spatial,
                               ef_attribute=ef_attribute, knn=knn_method))
