"""High-level RFANN API: build / save / load / batched search on one RNSG
index.  All query execution is delegated to the unified search substrate
(``repro.search``) — this class only owns index lifecycle."""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.core.construction import RNSGGraph, build_rnsg


class RNSGIndex:
    """The paper's system: one hereditary graph index answering every range."""

    def __init__(self, graph: RNSGGraph):
        self.g = graph
        self._substrate = None        # lazy unified search substrate

    # ------------------------------------------------------------------
    @classmethod
    def build(cls, vectors: np.ndarray, attrs: np.ndarray, **kw) -> "RNSGIndex":
        return cls(build_rnsg(vectors, attrs, **kw))

    @classmethod
    def build_sharded(cls, vectors: np.ndarray, attrs: np.ndarray,
                      **kw) -> "RNSGIndex":
        """Multi-device construction (``core.build_sharded``) — bit-identical
        to :meth:`build` with exact KNN; ``n_shards=`` picks the slab count
        (defaults to every local device)."""
        from repro.core.build_sharded import build_rnsg_sharded
        return cls(build_rnsg_sharded(vectors, attrs, **kw))

    def save(self, path: str, *, shards: int = 0) -> None:
        """``shards=0``: legacy atomic single-npz (graph only).  ``shards>=1``:
        the sharded directory format (``repro.index.io``) — also captures
        installed quantized corpora and mmap/parallel-restores."""
        if shards:
            from repro.index import io
            io.save_index(self, path, shards=shards)
        else:
            self.g.save(path)

    @classmethod
    def load(cls, path: str) -> "RNSGIndex":
        from repro.index import io
        if io.is_index_dir(path):
            idx = io.load_index(path)
            if not isinstance(idx, cls):
                raise TypeError(f"index at {path} is "
                                f"{type(idx).__name__}, not RNSGIndex — "
                                f"load it with repro.index.io.load_index")
            return idx
        return cls(RNSGGraph.load(path))

    # ------------------------------------------------------------------
    @property
    def substrate(self):
        """Lazily-built unified search substrate (resolve/dispatch/stitch)."""
        if self._substrate is None:
            from repro.search import SearchSubstrate
            self._substrate = SearchSubstrate.from_graph(
                self.g, metrics=getattr(self, "_metrics", None))
        return self._substrate

    # Back-compat aliases from the pre-substrate layering.
    @property
    def executor(self):
        return self.substrate

    @property
    def planner(self):
        return self.substrate.planner

    def install_cache(self, cache) -> None:
        """Install (or remove, with ``None``) a ``SearchCache`` at the
        substrate choke point — see ``repro.search.cache``."""
        self.substrate.cache = cache

    def install_metrics(self, metrics) -> None:
        """Install (or remove, with ``None``) a ``MetricsRegistry`` on the
        substrate — the engine wires its registry here so substrate-level
        counters/histograms land in ``engine.metrics()``."""
        self._metrics = metrics
        self.substrate.metrics = metrics

    def rank_range(self, attr_ranges: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """[a_l, a_r] (inclusive) -> rank interval [L, R] (inclusive).
        Pure host-side resolve — does not force the substrate's device
        upload for callers that only need rank mapping."""
        from repro.search import rank_interval
        return rank_interval(self.g.attrs, np.asarray(attr_ranges, np.float32))

    def install_quantized(self, precision: str) -> None:
        """Pre-build the quantized corpus copies for one precision (int8 /
        bf16) so the first ``precision=`` search pays no build cost."""
        self.substrate.install_quantized(precision)

    def search(self, queries: np.ndarray, attr_ranges: np.ndarray, *,
               k: int = 10, ef: int = 64, use_kernel: bool = False,
               plan: str = "graph", beam_width: int = 1,
               precision: str = "f32", trace=None, live=None):
        """queries:(Q,d); attr_ranges:(Q,2) attribute values (inclusive).
        plan: "graph" (pure beam search) | "auto" (cost-based scan/beam
        routing) | "scan" / "beam" (forced strategy).
        beam_width: batched-expansion width for beam dispatches (1 = the
        legacy single-node hop; B>1 fuses B node expansions per hop).
        precision: "f32" | "int8" | "bf16" — quantized scoring with a fused
        exact f32 rerank (same top-k id set as f32).
        trace: optional ``repro.obs.QueryTrace`` — collects resolve / plan /
        dispatch / stitch spans and rides back on the result.
        Returns a ``SearchResult`` (tuple-compatible: ids, dists, stats)."""
        from repro.obs import maybe_span
        with maybe_span(trace, "resolve") as sp:
            lo, hi = self.rank_range(attr_ranges)
            sp.attrs.update(
                q=len(np.atleast_2d(queries)), n=self.g.n,
                interval_widths=np.clip(
                    np.asarray(hi, np.int64) - np.asarray(lo, np.int64) + 1,
                    0, None) if trace is not None else None)
        return self.search_ranks(queries, lo, hi, k=k, ef=ef,
                                 use_kernel=use_kernel, plan=plan,
                                 beam_width=beam_width, precision=precision,
                                 trace=trace, live=live)

    def search_ranks(self, queries, lo, hi, *, k=10, ef=64, use_kernel=False,
                     plan="graph", beam_width=1, precision="f32", trace=None,
                     live=None):
        from repro.search import SearchRequest
        return self.substrate.run(SearchRequest(
            queries=np.asarray(queries, np.float32), lo=lo, hi=hi,
            k=k, ef=ef, strategy=plan, use_kernel=use_kernel,
            beam_width=beam_width, precision=precision, trace=trace,
            live=live))

    # ------------------------------------------------------------------
    @property
    def index_bytes(self) -> int:
        return self.g.index_bytes

    @property
    def n_edges(self) -> int:
        return self.g.n_edges

    def stats(self) -> Dict:
        deg = (self.g.nbrs >= 0).sum(1)
        return dict(n=self.g.n, m=self.g.m, edges=self.g.n_edges,
                    mean_degree=float(deg.mean()), max_degree=int(deg.max()),
                    index_mb=self.index_bytes / 2**20,
                    build_seconds=self.g.build_seconds)
