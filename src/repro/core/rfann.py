"""High-level RFANN API: build / save / load / batched search on one RNSG index."""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.beam import beam_search_batch
from repro.core.construction import RNSGGraph, build_rnsg
from repro.core.entry import rmq_query_jax


class RNSGIndex:
    """The paper's system: one hereditary graph index answering every range."""

    def __init__(self, graph: RNSGGraph):
        self.g = graph
        self._vecs = jnp.asarray(graph.vecs)
        self._nbrs = jnp.asarray(graph.nbrs)
        self._rmq = jnp.asarray(graph.rmq)
        self._dist_c = jnp.asarray(graph.dist_c)
        self._executor = None          # lazy adaptive query planner

    # ------------------------------------------------------------------
    @classmethod
    def build(cls, vectors: np.ndarray, attrs: np.ndarray, **kw) -> "RNSGIndex":
        return cls(build_rnsg(vectors, attrs, **kw))

    def save(self, path: str) -> None:
        self.g.save(path)

    @classmethod
    def load(cls, path: str) -> "RNSGIndex":
        return cls(RNSGGraph.load(path))

    # ------------------------------------------------------------------
    def rank_range(self, attr_ranges: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """[a_l, a_r] (inclusive) -> rank interval [L, R] (inclusive)."""
        lo = np.searchsorted(self.g.attrs, attr_ranges[:, 0], side="left")
        hi = np.searchsorted(self.g.attrs, attr_ranges[:, 1], side="right") - 1
        return lo.astype(np.int32), hi.astype(np.int32)

    @property
    def executor(self):
        """Lazily-built adaptive planner/executor (scan-vs-beam routing)."""
        if self._executor is None:
            from repro.planner import PlanExecutor, QueryPlanner
            deg = float((self.g.nbrs >= 0).sum(1).mean())
            planner = QueryPlanner(self.g.n, deg)
            self._executor = PlanExecutor(self.g.vecs, self.g.nbrs,
                                          self.g.rmq, self.g.dist_c, planner)
        return self._executor

    def search(self, queries: np.ndarray, attr_ranges: np.ndarray, *,
               k: int = 10, ef: int = 64, use_kernel: bool = False,
               plan: str = "graph") -> Tuple[np.ndarray, np.ndarray, Dict]:
        """queries:(Q,d); attr_ranges:(Q,2) attribute values (inclusive).
        plan: "graph" (pure beam search) | "auto" (cost-based scan/beam
        routing) | "scan" / "beam" (forced strategy).
        Returns (original ids (Q,k), sq dists, stats)."""
        lo, hi = self.rank_range(np.asarray(attr_ranges, np.float32))
        return self.search_ranks(queries, lo, hi, k=k, ef=ef,
                                 use_kernel=use_kernel, plan=plan)

    def search_ranks(self, queries, lo, hi, *, k=10, ef=64, use_kernel=False,
                     plan="graph"):
        if plan not in ("graph", "auto", "scan", "beam"):
            raise ValueError(f"unknown plan {plan!r}: "
                             "expected graph|auto|scan|beam")
        if plan != "graph":
            ids, dists, stats = self.executor.execute(
                queries, lo, hi, k=k, ef=ef, mode=plan,
                use_kernel=use_kernel)
            orig = np.where(ids >= 0, self.g.order[np.maximum(ids, 0)], -1)
            return orig, dists, stats
        qv = jnp.asarray(queries, jnp.float32)
        lo_j = jnp.asarray(lo)
        hi_j = jnp.asarray(hi)
        entry = rmq_query_jax(self._rmq, self._dist_c,
                              jnp.minimum(lo_j, self.g.n - 1),
                              jnp.clip(hi_j, 0, self.g.n - 1))
        ids, dists, stats = beam_search_batch(
            self._vecs, self._nbrs, qv, lo_j, hi_j, entry,
            k=k, ef=max(ef, k), use_kernel=use_kernel)
        ids = np.asarray(ids)
        orig = np.where(ids >= 0, self.g.order[np.maximum(ids, 0)], -1)
        return orig, np.asarray(dists), jax.tree.map(np.asarray, stats)

    # ------------------------------------------------------------------
    @property
    def index_bytes(self) -> int:
        return self.g.index_bytes

    @property
    def n_edges(self) -> int:
        return self.g.n_edges

    def stats(self) -> Dict:
        deg = (self.g.nbrs >= 0).sum(1)
        return dict(n=self.g.n, m=self.g.m, edges=self.g.n_edges,
                    mean_degree=float(deg.mean()), max_degree=int(deg.max()),
                    index_mb=self.index_bytes / 2**20,
                    build_seconds=self.g.build_seconds)
