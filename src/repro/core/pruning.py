"""Algorithm 1 — Fast Range-Aware Pruning (RRNGPrune).

Two implementations:

* ``rrng_prune_np``: faithful per-node reference (numpy), matching the paper's
  pseudocode line by line (split at x.a — Lemma 4.1; scan each side by
  ascending attribute gap — Lemma 4.2; keep ≤ m/2 per side).
* ``prune_all_jax``: vectorized construction engine.  Per node the candidate
  side-arrays are pre-sorted by rank gap; the sequential keep/prune recurrence
  runs as a ``lax.fori_loop`` over candidates against precomputed distance
  tiles (the MXU-friendly form — see kernels/l2dist for the TPU tile).

Ids are attribute ranks (dataset pre-sorted by attribute).
"""
from __future__ import annotations

from functools import partial
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _sq(a, b):
    diff = a - b
    return float(np.dot(diff, diff))


def rrng_prune_np(x: int, cands: np.ndarray, vecs: np.ndarray, m: int) -> List[int]:
    """Faithful Algorithm 1. cands: candidate ids (any order, != x)."""
    cands = np.asarray([c for c in np.unique(cands) if c != x and c >= 0])
    c_l = sorted([c for c in cands if c < x], key=lambda c: x - c)   # asc gap
    c_r = sorted([c for c in cands if c > x], key=lambda c: c - x)
    half = max(m // 2, 1)

    def prune(side):
        kept: List[int] = []
        for vi in side:
            d_xi = _sq(vecs[x], vecs[vi])
            ok = True
            for vj in kept:
                if _sq(vecs[x], vecs[vj]) < d_xi and _sq(vecs[vj], vecs[vi]) < d_xi:
                    ok = False
                    break
            if ok and len(kept) < half:
                kept.append(vi)
        return kept

    return prune(c_l) + prune(c_r)


# ----------------------------------------------------------------------
def prune_side(x_vecs, cand_ids, cand_vecs, m_half: int):
    """x_vecs: (B,d); cand_ids: (B,C) gap-sorted, -1 pad; cand_vecs: (B,C,d).
    Returns kept mask (B,C) honoring the sequential RRNG rule + cap.
    Pure traceable body — also inlined per slab by the sharded builder
    (``repro.core.build_sharded``); every op is row-independent, so block
    and shard partitioning cannot change any row's result."""
    d_xc = jnp.sum(jnp.square(cand_vecs - x_vecs[:, None, :]), axis=-1)   # (B,C)
    # candidate-candidate distance tiles
    cn = jnp.sum(cand_vecs * cand_vecs, axis=-1)
    d_cc = (cn[:, :, None] - 2.0 * jnp.einsum("bcd,bed->bce", cand_vecs, cand_vecs)
            + cn[:, None, :])
    d_cc = jnp.maximum(d_cc, 0.0)
    valid = cand_ids >= 0
    C = cand_ids.shape[1]

    def body(i, kept):
        d_xi = d_xc[:, i]
        # pruned iff ∃ kept j (earlier, smaller gap): d_xj < d_xi ∧ d_ji < d_xi
        conflict = kept & (d_xc < d_xi[:, None]) & (d_cc[:, i, :] < d_xi[:, None])
        pruned = jnp.any(conflict, axis=1)
        under = jnp.sum(kept, axis=1) < m_half
        keep_i = valid[:, i] & ~pruned & under
        return kept.at[:, i].set(keep_i)

    kept = jax.lax.fori_loop(0, C, body, jnp.zeros_like(valid))
    return kept


_prune_side_batch = partial(jax.jit, static_argnames=("m_half",))(prune_side)


def pack_kept(cand_l, kept_l, cand_r, kept_r, m: int):
    """Compact the kept candidates of both sides into (B, m) neighbor ids,
    -1 padded — left-side keeps first (in gap order), then right, truncated
    at m.  A stable argsort on the ~kept mask is the vectorized equivalent
    of the per-row ``concatenate(cand[kept])`` pack (stability preserves
    the within-side candidate order and the left-before-right concat
    order), so the output is bit-identical to the sequential pack."""
    cand = jnp.concatenate([cand_l, cand_r], axis=1)
    kept = jnp.concatenate([kept_l, kept_r], axis=1)
    order = jnp.argsort(~kept, axis=1, stable=True)
    cand = jnp.take_along_axis(cand, order, axis=1)
    kept = jnp.take_along_axis(kept, order, axis=1)
    c2 = cand.shape[1]
    if c2 < m:
        cand = jnp.pad(cand, ((0, 0), (0, m - c2)), constant_values=-1)
        kept = jnp.pad(kept, ((0, 0), (0, m - c2)))
    return jnp.where(kept[:, :m], cand[:, :m], -1).astype(jnp.int32)


@partial(jax.jit, static_argnames=("m", "m_half"))
def _prune_pack_block(x_vecs, cand_l, cv_l, cand_r, cv_r, m: int,
                      m_half: int):
    kept_l = prune_side(x_vecs, cand_l, cv_l, m_half)
    kept_r = prune_side(x_vecs, cand_r, cv_r, m_half)
    return pack_kept(cand_l, kept_l, cand_r, kept_r, m)


def prune_all_jax(vecs: np.ndarray, cand_l: np.ndarray, cand_r: np.ndarray,
                  m: int, block: int = 2048) -> np.ndarray:
    """Run Algorithm 1 for every node. cand_l/cand_r: (n, Ch) rank-gap-sorted
    candidate ids per side (-1 padded). Returns (n, m) neighbor ids (-1 pad).
    The keep/prune recurrence and the kept→adjacency pack both run on
    device (``_prune_pack_block``); the host loop only blocks rows."""
    n = vecs.shape[0]
    half = max(m // 2, 1)
    v = jnp.asarray(vecs, jnp.float32)
    out = []
    for lo in range(0, n, block):
        hi = min(lo + block, n)
        xv = v[lo:hi]
        ci_l = jnp.asarray(cand_l[lo:hi], jnp.int32)
        ci_r = jnp.asarray(cand_r[lo:hi], jnp.int32)
        out.append(np.asarray(_prune_pack_block(
            xv, ci_l, v[jnp.maximum(ci_l, 0)],
            ci_r, v[jnp.maximum(ci_r, 0)], m, half)))
    if not out:
        return np.full((0, m), -1, np.int32)
    return np.concatenate(out)
