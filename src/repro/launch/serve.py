"""Serving launcher.

``--mode rfann`` (the paper's kind): build an RNSG over a synthetic corpus and
drive the dynamic-batching engine with Poisson request arrivals — reports
QPS, recall and latency percentiles.

``--mode lm``: batched LM serving (prefill + decode loop) on a smoke config.

  PYTHONPATH=src python -m repro.launch.serve --mode rfann --n 8192 --requests 512

``--metrics-path out.prom`` dumps the final metrics snapshot on shutdown:
Prometheus text exposition at the given path plus a JSON sibling
(``out.prom.json``); ``--log-interval S`` turns on the engine's periodic
one-line stats log while serving.

``--index-path DIR`` makes startup stateful: the first run builds the index
and persists it (sharded directory format, ``repro.index.io``) on
shutdown; later runs restore it in seconds instead of rebuilding.
``--build-shards S`` routes a fresh static build through the multi-device
sharded constructor (bit-identical output).

``--wal-dir DIR`` (streaming mode) adds crash durability on top: every
mutation is appended to a checksummed write-ahead log before it is
acknowledged, restart replays the uncompacted tail onto the
``--index-path`` checkpoint, SIGTERM drains gracefully (seal WAL,
checkpoint, persist calibration + metrics), and a WAL write failure
degrades the server to read-only instead of crashing it.  See
``docs/durability.md``.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.core.rfann import RNSGIndex
from repro.data.ann import (ground_truth, make_attrs, make_vectors,
                            mixed_workload, recall_at_k)
from repro.launch.specs import concrete_batch
from repro.models.lm import Model
from repro.models.params import ShardPlan
from repro.runtime.fault_tolerance import PreemptionHandler
from repro.serving.engine import RFANNEngine
from repro.streaming import ReadOnlyIndexError


def _restore_index(args, streaming: bool):
    """Restore a prebuilt index from ``--index-path`` (sharded directory
    format) when one is there and matches the requested mode/corpus shape;
    returns ``None`` when a fresh build is needed."""
    from repro.index import io
    if not (args.index_path and io.is_index_dir(args.index_path)):
        return None
    t0 = time.perf_counter()
    idx = io.load_index(args.index_path)
    from repro.streaming import StreamingRFANN
    if isinstance(idx, StreamingRFANN) != streaming:
        print(f"[serve] index at {args.index_path} is the wrong kind for "
              f"this mode — rebuilding")
        return None
    d = idx.d if streaming else idx.g.vecs.shape[1]
    n_ok = streaming or idx.g.n == args.n
    if d != args.dim or not n_ok:
        print(f"[serve] index at {args.index_path} does not match the "
              f"requested corpus (n={args.n}, dim={args.dim}) — rebuilding")
        return None
    print(f"[serve] restored index from {args.index_path} "
          f"in {time.perf_counter() - t0:.2f}s (no rebuild)")
    if streaming and getattr(args, "wal_dir", ""):
        # crash-consistent restart: the checkpoint is the floor, the WAL
        # tail on top of it is every acknowledged mutation the previous
        # process did not get to fold in (see docs/durability.md)
        replayed = idx.replay_wal(args.wal_dir)
        print(f"[serve] replayed {replayed} WAL records from "
              f"{args.wal_dir} (lsn watermark {idx.applied_lsn})")
    return idx


def serve_rfann(args):
    vecs = make_vectors(args.n, args.dim, seed=0)
    attrs = make_attrs(args.n, seed=0)
    qv = make_vectors(args.requests, args.dim, seed=7)
    ranges, _ = mixed_workload(attrs, args.requests, seed=3)
    streaming = args.max_delta > 0 or args.compact_every > 0
    rng = np.random.default_rng(0)
    idx = _restore_index(args, streaming)
    if idx is not None and streaming:
        pending_ins = [j for j in range(args.n) if j not in idx._id_loc]
        print(f"[serve] {idx.stats()}")
    elif idx is not None:
        print(f"[serve] {idx.stats()}")
    elif streaming:
        # streaming serve: seed the base with 80% of the corpus, churn the
        # held-out tail (inserts) plus random deletes through the engine
        # while the first half of the requests stream in, then measure
        # recall on the second half against the *final* live set
        from repro.streaming import StreamingRFANN
        n0 = max(args.n * 4 // 5, 256)
        print(f"[serve] building streaming RNSG base (n0={n0}) ...")
        idx = StreamingRFANN(vecs[:n0], attrs[:n0], m=args.m,
                             ef_spatial=32, ef_attribute=48,
                             max_delta=args.max_delta or 1024,
                             compact_every=args.compact_every)
        pending_ins = list(range(n0, args.n))
        print(f"[serve] {idx.stats()}")
    else:
        if args.build_shards:
            print(f"[serve] building RNSG index "
                  f"({args.build_shards} shards) ...")
            idx = RNSGIndex.build_sharded(vecs, attrs,
                                          n_shards=args.build_shards,
                                          m=args.m, ef_spatial=32,
                                          ef_attribute=48)
        else:
            print("[serve] building RNSG index ...")
            idx = RNSGIndex.build(vecs, attrs, m=args.m, ef_spatial=32,
                                  ef_attribute=48)
        print(f"[serve] {idx.stats()}")
    if args.precision != "f32":
        idx.install_quantized(args.precision)   # build quantized corpus once
    warm = idx.search(qv[:8], ranges[:8], k=args.k, ef=args.ef,
                      plan=args.plan, beam_width=args.beam_width,
                      precision=args.precision)             # warm the jit
    assert warm.ids.shape == (8, args.k)                    # SearchResult

    engine = RFANNEngine(idx, k=args.k, ef=args.ef, plan=args.plan,
                         beam_width=args.beam_width,
                         precision=args.precision,
                         max_batch=args.max_batch, max_wait_ms=2.0,
                         calibration_path=args.calibration or None,
                         cache_bytes=args.cache_mb << 20,
                         log_interval_s=args.log_interval,
                         trace_sample_every=args.trace_sample_every,
                         max_delta=args.max_delta or None,
                         compact_every=args.compact_every or None,
                         index_path=args.index_path or None,
                         index_save_shards=args.index_shards,
                         wal_dir=(args.wal_dir or None) if streaming else None,
                         wal_sync=args.wal_sync)
    if streaming and args.wal_dir and not args.index_path:
        print("[serve] note: --wal-dir without --index-path logs mutations "
              "but leaves no checkpoint to recover onto")
    # graceful SIGTERM: stop accepting work, drain in-flight futures, then
    # the normal shutdown path seals the WAL and persists index +
    # calibration + metrics — zero acknowledged mutations lost
    preempt = PreemptionHandler().install()
    futs = []
    churn_until = args.requests // 2
    churn_on = streaming
    t0 = time.perf_counter()
    for i in range(args.requests):
        if preempt.should_stop():
            print(f"[serve] SIGTERM: draining after {len(futs)} submitted "
                  f"requests, then checkpointing")
            break
        futs.append(engine.submit(qv[i], ranges[i]))
        if churn_on and i < churn_until:
            try:
                if pending_ins:
                    j = pending_ins.pop()
                    engine.insert(vecs[j], float(attrs[j]), ext_id=j)
                if i % 4 == 3:      # one delete per four churn steps
                    live = list(engine.index._id_loc)
                    engine.delete(int(live[rng.integers(len(live))]))
            except ReadOnlyIndexError as e:
                # WAL append failed: the index degraded to read-only
                # (stream_read_only gauge = 1).  Searches keep working —
                # stop mutating, keep serving.
                churn_on = False
                print(f"[serve] churn stopped, serving continues: {e}")
        if args.rate > 0:
            time.sleep(rng.exponential(1.0 / args.rate))
    # SIGTERM can land before the first submit — drain an empty futs list
    # without tripping np.stack, so shutdown still seals the WAL below
    results = (np.stack([f.result().ids for f in futs]) if futs
               else np.zeros((0, args.k), np.int64))
    dt = time.perf_counter() - t0
    engine.close()
    if streaming:
        idx.close()     # drain any in-flight compaction, seal the WAL
    if engine.cache is not None:
        print(f"[serve] result cache: {engine.cache.snapshot()}")
    if args.calibration:
        print(f"[serve] cost-model calibration persisted to {args.calibration}")
    if args.index_path:
        print(f"[serve] index persisted to {args.index_path} "
              f"({args.index_shards} shards) — restored on next startup")
    if args.metrics_path:
        # final snapshot on shutdown, alongside the calibration save:
        # Prometheus text at the given path, JSON snapshot as a sibling
        from repro.obs import write_prometheus
        write_prometheus(engine.registry, args.metrics_path)
        with open(args.metrics_path + ".json", "w") as f:
            json.dump(engine.metrics(), f, indent=2, sort_keys=True,
                      default=float)
        print(f"[serve] metrics written to {args.metrics_path} (+.json)")

    served = len(futs)
    if served == 0:
        rec = float("nan")          # drained before any request was served
        if streaming:
            print(f"[serve] streaming: {idx.stats()}")
    elif streaming and served > churn_until:
        # score only the post-churn half against the final live set (the
        # requests that raced mutations have no single ground truth)
        lv, la, li = idx.live_items()
        order = np.argsort(la, kind="stable")
        gt_r, _ = ground_truth(lv[order], la[order], qv[churn_until:served],
                               ranges[churn_until:served], args.k)
        gt = np.where(gt_r >= 0, li[order][np.maximum(gt_r, 0)], -1)
        rec = recall_at_k(results[churn_until:], gt)
        print(f"[serve] streaming: {idx.stats()}")
    elif streaming:
        rec = float("nan")          # drained before the scored half began
        print(f"[serve] streaming: {idx.stats()}")
    else:
        order = np.argsort(attrs, kind="stable")
        gt_r, _ = ground_truth(vecs[order], attrs[order], qv[:served],
                               ranges[:served], args.k)
        gt = np.where(gt_r >= 0, order[np.maximum(gt_r, 0)], -1)
        rec = recall_at_k(results, gt)
    print(f"[serve] served {served} reqs in {dt:.2f}s "
          f"({served/dt:.0f} QPS) recall@{args.k}={rec:.4f}")
    print(f"[serve] {engine.stats.summary()}")
    return rec


def serve_lm(args):
    cfg = get_smoke_config(args.arch)
    model = Model(cfg, ShardPlan())
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    b, s = args.max_batch, 32
    batch = concrete_batch(cfg, "prefill", b, s, rng)
    prefill = jax.jit(lambda p, bb: model.prefill(p, bb, cache_len=s + args.new_tokens))
    decode = jax.jit(model.decode, donate_argnums=(1,))
    cache, logits = prefill(params, batch)
    toks = [jnp.argmax(logits[:, :cfg.vocab_size], -1).astype(jnp.int32)]
    t0 = time.perf_counter()
    for i in range(args.new_tokens):
        logits, cache = decode(params, cache, jnp.asarray(s + i, jnp.int32), toks[-1])
        toks.append(jnp.argmax(logits[:, :cfg.vocab_size], -1).astype(jnp.int32))
    dt = time.perf_counter() - t0
    out = np.stack([np.asarray(t) for t in toks], 1)
    print(f"[serve] {args.arch}: batch={b} decoded {args.new_tokens} tokens "
          f"in {dt:.2f}s ({b*args.new_tokens/dt:.0f} tok/s)")
    print(f"[serve] sample continuation ids: {out[0][:12].tolist()}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["rfann", "lm"], default="rfann")
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--n", type=int, default=8192)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate (req/s); 0 = as fast as possible")
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--ef", type=int, default=64)
    ap.add_argument("--m", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--plan", choices=["auto", "graph", "scan", "beam"],
                    default="auto", help="query-planner strategy routing")
    ap.add_argument("--beam-width", type=int, default=1,
                    help="batched beam expansion width (1 = legacy "
                         "single-node hops; try 4 for throughput)")
    ap.add_argument("--precision", choices=["f32", "int8", "bf16"],
                    default="f32",
                    help="distance-scoring precision: quantized corpora "
                         "(int8/bf16) scan cheaper and rerank the survivors "
                         "in exact f32 (same ids as f32)")
    ap.add_argument("--index-path", default="",
                    help="index directory: restore the index from here at "
                         "startup (skipping the build) and persist it on "
                         "shutdown (repro.index.io sharded format)")
    ap.add_argument("--index-shards", type=int, default=1,
                    help="row-shard count for --index-path saves (restore "
                         "fills shards with parallel reads)")
    ap.add_argument("--build-shards", type=int, default=0,
                    help="static mode: build the graph with the sharded "
                         "multi-device constructor over this many device "
                         "slabs (0 = single-host build; results are "
                         "bit-identical either way)")
    ap.add_argument("--calibration", default="",
                    help="JSON path: load cost-model calibration at startup, "
                         "persist it on shutdown")
    ap.add_argument("--cache-mb", type=int, default=0,
                    help="result-cache byte budget in MiB (0 = no cache)")
    ap.add_argument("--metrics-path", default="",
                    help="write the final metrics snapshot here on shutdown "
                         "(Prometheus text; JSON sibling at <path>.json)")
    ap.add_argument("--log-interval", type=float, default=0.0,
                    help="seconds between one-line stats logs (0 = off)")
    ap.add_argument("--trace-sample-every", type=int, default=0,
                    help="attach a QueryTrace to every Nth batch (0 = off)")
    ap.add_argument("--max-delta", type=int, default=0,
                    help="streaming mode: compact when the delta segment "
                         "reaches this many rows (0 = static index)")
    ap.add_argument("--compact-every", type=int, default=0,
                    help="streaming mode: compact every N mutations "
                         "(0 = size-triggered only)")
    ap.add_argument("--wal-dir", default="",
                    help="streaming mode: write-ahead-log directory — every "
                         "mutation is logged (checksummed) before it is "
                         "applied, and a crashed server replays the tail "
                         "onto the --index-path checkpoint at restart")
    ap.add_argument("--wal-sync", choices=["always", "batch", "none"],
                    default="batch",
                    help="WAL durability: fsync per record / group commit "
                         "(every N records or T seconds) / OS page cache "
                         "only")
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args(argv)
    if args.mode == "rfann":
        serve_rfann(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
