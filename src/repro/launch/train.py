"""Training launcher: data pipeline → jit'd train step → checkpoint/restart,
with preemption handling, straggler monitoring and optional cross-pod gradient
compression.  On this CPU container it drives the reduced (smoke) configs;
on a real cluster the same driver runs the full configs over
``make_production_mesh()``.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --steps 50
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs.registry import get_config, get_smoke_config
from repro.data.tokens import Prefetcher, SyntheticTokenStream, TokenStreamConfig
from repro.models.lm import Model
from repro.models.params import ShardPlan
from repro.runtime.fault_tolerance import (PreemptionHandler, StragglerMonitor,
                                           make_compressed_grad_transform)
from repro.training.train_step import build_train_step, init_train_state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--full", action="store_true",
                    help="full config (cluster) instead of smoke (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--simulate-straggler", type=float, default=0.0,
                    help="inject this many seconds of delay on fake host 3")
    ap.add_argument("--n-hosts", type=int, default=4,
                    help="simulated hosts for the straggler monitor")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    model = Model(cfg, ShardPlan())
    import functools
    from repro.training.optim import cosine_schedule
    sched = functools.partial(cosine_schedule, base_lr=args.lr,
                              warmup=args.warmup, total=max(args.steps, 100))
    step_fn = jax.jit(build_train_step(
        model, lr_schedule=sched,
        grad_transform=(make_compressed_grad_transform()
                        if args.compress_grads else None)))

    state = init_train_state(model, jax.random.key(0))
    start_step = 0
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt and args.resume and ckpt.latest_step() is not None:
        state = ckpt.restore(state)
        start_step = ckpt.meta()["step"]
        print(f"[train] resumed from step {start_step}")

    stream = SyntheticTokenStream(TokenStreamConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch))
    data = Prefetcher(stream.iter_from(start_step), depth=2)

    preempt = PreemptionHandler().install()
    monitor = StragglerMonitor(args.n_hosts)
    losses = []
    t_start = time.perf_counter()
    for step in range(start_step, args.steps):
        batch = next(data)
        t0 = time.perf_counter()
        state, metrics = step_fn(state, {k: jax.numpy.asarray(v)
                                         for k, v in batch.items()})
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        losses.append(loss)
        # simulated per-host step times (host 3 optionally delayed)
        host_t = np.full(args.n_hosts, dt)
        if args.simulate_straggler:
            host_t[3 % args.n_hosts] += args.simulate_straggler
        verdict = monitor.record(host_t)
        if verdict["stragglers"]:
            print(f"[train] step {step}: stragglers={verdict['stragglers']} "
                  f"evict={verdict['evict']}")
        if step % args.log_every == 0:
            print(f"[train] step {step} loss={loss:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.2f} {dt*1e3:.0f}ms")
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, state)
        if preempt.should_stop():
            print("[train] preemption requested — checkpointing and exiting")
            if ckpt:
                ckpt.save(step + 1, state, blocking=True)
            return state, losses
    if ckpt:
        ckpt.save(args.steps, state, blocking=True)
    tput = (args.steps - start_step) * args.batch * args.seq / \
        max(time.perf_counter() - t_start, 1e-9)
    if losses:
        print(f"[train] done: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
              f"({tput:.0f} tok/s)")
    return state, losses


if __name__ == "__main__":
    main()
