"""Post-SPMD HLO analysis: collective-byte accounting + roofline terms.

``collective_bytes`` parses the compiled (per-device) HLO text and sums the
operand sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (async ``-start`` forms counted once).
"""
from __future__ import annotations

import math
import re
from typing import Dict

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for x in dims.split(","):
            if x:
                n *= int(x)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device operand bytes per collective kind (and 'total')."""
    # name -> result-type text (first token group before the op name)
    result_types: Dict[str, str] = {}
    for line in hlo_text.splitlines():
        mm = _DEF_RE.match(line)
        if mm:
            name, rhs = mm.groups()
            result_types[name] = rhs.split(" ")[0]

    out = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        rhs = m.group(2)
        kind = next((c for c in COLLECTIVES
                     if re.search(rf"\b{c}(-start)?\(", rhs)), None)
        if kind is None:
            continue
        if re.search(rf"\b{kind}-done\(", rhs):
            continue
        # operand section: text inside the outermost call parens
        call = re.search(rf"{kind}(?:-start)?\((.*)\)", rhs)
        args = call.group(1) if call else ""
        b = _shape_bytes(args)
        if b == 0:
            # operands printed as bare %names: resolve via definition map
            for ref in re.findall(r"%([\w.\-]+)", args):
                b += _shape_bytes(result_types.get(ref, ""))
        if b == 0:
            # last resort: result type (upper-bounds AG, matches AR)
            b = _shape_bytes(rhs.split(f" {kind}")[0])
        out[kind] += b
    out["total"] = sum(out[k] for k in COLLECTIVES)
    return out


# ----------------------------------------------------------------------
# TPU v5e (target hardware)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link


def roofline_terms(per_device_flops: float, per_device_bytes: float,
                   per_device_coll_bytes: float, chips: int) -> Dict[str, float]:
    """Three roofline times (seconds), global convention: X_global/(chips·peak)
    == X_per_device/peak."""
    t_compute = per_device_flops / PEAK_FLOPS
    t_memory = per_device_bytes / HBM_BW
    t_coll = per_device_coll_bytes / ICI_BW
    dominant = max((t_compute, "compute"), (t_memory, "memory"),
                   (t_coll, "collective"))[1]
    bound = max(t_compute, t_memory, t_coll)
    return dict(t_compute=t_compute, t_memory=t_memory, t_collective=t_coll,
                dominant=dominant, t_bound=bound,
                flops_global=per_device_flops * chips,
                bytes_global=per_device_bytes * chips,
                coll_bytes_global=per_device_coll_bytes * chips)


def model_flops(cfg, shape, n_active: int) -> float:
    """6·N_active·tokens (train) / 2·N_active·tokens (serve)."""
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens
