import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell with
ShapeDtypeStruct stand-ins (no allocation), record memory/cost analysis and
the per-device collective schedule for §Roofline.

  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod] [--jobs k/N]

Results land in results/dryrun/<arch>__<shape>__<mesh>[__variant].json.
"""  # noqa: E402

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES
from repro.configs.registry import cells, get_config
from repro.launch.hlo_analysis import (collective_bytes, model_flops,
                                       roofline_terms)
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.models.lm import Model
from repro.models.params import ShardPlan, logical_axes
from repro.parallel.sharding import (batch_logical, cache_logical,
                                     make_act_sharder, set_mesh_compat,
                                     spec_for_logical, tree_shardings)
from repro.training.train_step import build_train_step, train_state_shapes

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

VARIANTS = {
    # §Perf hillclimb variants: cfg field overrides + Model opts overrides
    "base": {},
    "nosp": {"opts": {"sp": False}},
    "remat_dots": {"cfg": {"remat": "dots"}},
    "remat_full": {"cfg": {"remat": "full"}},
    "remat_none": {"cfg": {"remat": "none"}},
    "ce2048": {"opts": {"ce_chunk": 2048}},
    "ce512": {"opts": {"ce_chunk": 512}},
    "qc2048": {"opts": {"q_chunk": 2048, "kv_chunk": 2048}},
    "blockskip": {"opts": {"block_skip": True}},          # analysis-mode only
    "cf1": {"cfg": {"capacity_factor": 1.0}},
    "ssm256": {"opts": {"ssm_chunk": 256}},
    "ssm64": {"opts": {"ssm_chunk": 64}},
    "ssm512": {"opts": {"ssm_chunk": 512}},
    "ssm128": {"opts": {"ssm_chunk": 128}},
    "replica": {"opts": {"strategy": "replica"}},
    "ssd16": {"opts": {"ssd_dtype": "bfloat16"}},
    # composed hillclimb variants
    "llama4_opt": {"cfg": {"capacity_factor": 1.0},
                   "opts": {"block_skip": True}},
    "mamba2_opt": {"opts": {"strategy": "replica", "ssd_dtype": "bfloat16",
                            "ssm_chunk": 64}},
    "mamba2_opt2": {"opts": {"strategy": "replica", "ssd_dtype": "bfloat16",
                             "ssm_chunk": 256}},
    "ssd16_256": {"opts": {"ssd_dtype": "bfloat16", "ssm_chunk": 256}},
}


def _apply_variant(cfg, variant: str):
    if variant not in VARIANTS:
        raise KeyError(f"unknown variant {variant!r}; known: {sorted(VARIANTS)}")
    v = VARIANTS[variant]
    if "cfg" in v:
        cfg = dataclasses.replace(cfg, **v["cfg"])
    return cfg, dict(v.get("opts", {}))


def _analysis_opts(shape, base_opts):
    """Unrolled-mode opts: python loops so HLO cost analysis sees every block;
    chunk sizes bumped so the unrolled HLO stays compilable."""
    o = dict(base_opts)
    o["unroll"] = True
    if shape.kind != "decode":
        o.setdefault("q_chunk", max(1024, shape.seq_len // 8))
        o.setdefault("kv_chunk", max(1024, shape.seq_len // 8))
        o.setdefault("ce_chunk", max(1024, shape.seq_len // 4))
    return o


def _depth_reduced(cfg, groups: int):
    """Same-family config with `groups` scan groups (layer-linear cost probe)."""
    from repro.models.params import resolve_dims as _rd
    from repro.models.params import ShardPlan as _SP
    gl = _rd(cfg, _SP()).group_layers
    upd = dict(n_layers=groups * gl)
    if cfg.enc_layers:
        full_groups = cfg.n_layers // gl
        upd["enc_layers"] = max(1, round(cfg.enc_layers * groups / full_groups))
    return dataclasses.replace(cfg, **upd)


def build_cell(arch: str, shape_name: str, mesh, variant: str = "base",
               analysis: bool = False, depth_groups: int = 0):
    """Returns (jitted_fn, args, meta, cfg, shape) ready to lower."""
    cfg = get_config(arch)
    cfg, opts = _apply_variant(cfg, variant)
    shape = SHAPES[shape_name]
    if depth_groups:
        cfg = _depth_reduced(cfg, depth_groups)
    if analysis:
        opts = _analysis_opts(shape, opts)
    # parallelism strategy: pure-FSDP for training (no TP activation
    # all-reduces; ZeRO-3 weights), TP for serving (KV-cache sharding)
    from repro.parallel.sharding import STRATEGIES
    strategy = opts.pop("strategy", "fsdp" if shape.kind == "train" else "tp")
    rules = STRATEGIES[strategy]
    tp = mesh.shape["model"] if strategy == "tp" else 1
    fsdp = mesh.shape["data"]
    dp = mesh.shape.get("pod", 1)
    plan = ShardPlan(tp=tp, fsdp=fsdp, dp=dp, vocab_multiple=256)
    model = Model(cfg, plan, mesh=mesh,
                  act_shard=make_act_sharder(mesh, rules), opts=opts)

    batch_sds = input_specs(cfg, shape)
    blog = batch_logical(cfg, shape.kind)
    bsh = {k: NamedSharding(mesh, spec_for_logical(blog[k], v.shape, mesh, rules))
           for k, v in batch_sds.items()}

    lax_tree = logical_axes(cfg, plan)
    psh = tree_shardings(lax_tree, model.param_shapes(), mesh, rules)

    if shape.kind == "train":
        state_sds = train_state_shapes(model)
        zrules = dict(rules)              # ZeRO-1 across pods for opt state
        zrules["fsdp"] = rules["fsdp+"]
        mvsh = jax.tree.map(
            lambda lg, s: NamedSharding(mesh, spec_for_logical(lg, s.shape, mesh,
                                                               zrules)),
            lax_tree, state_sds["opt"]["m"],
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))
        state_sh = {"params": psh,
                    "opt": {"m": mvsh, "v": mvsh,
                            "step": NamedSharding(mesh, P())}}
        step = build_train_step(model)
        fn = jax.jit(step, in_shardings=(state_sh, bsh),
                     out_shardings=(state_sh, None))
        args = (state_sds, batch_sds)
        tokens = shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        def prefill_fn(params, batch):
            return model.prefill(params, batch, cache_len=shape.seq_len)
        fn = jax.jit(prefill_fn, in_shardings=(psh, bsh))
        args = (model.param_shapes(), batch_sds)
        tokens = shape.global_batch * shape.seq_len
    else:  # decode
        cache_sds = model.init_cache(shape.global_batch, shape.seq_len,
                                     abstract=True)
        clog = cache_logical(cfg, long_context=shape.long_context)
        csh = {k: NamedSharding(
            mesh, spec_for_logical(clog[k], cache_sds[k].shape, mesh, rules))
            for k in cache_sds}
        scalar_sh = NamedSharding(mesh, P())

        def decode_fn(params, cache, cur_len, token):
            return model.decode(params, cache, cur_len, token)

        fn = jax.jit(decode_fn,
                     in_shardings=(psh, csh, scalar_sh, bsh["token"]),
                     donate_argnums=(1,))
        args = (model.param_shapes(), cache_sds,
                jax.ShapeDtypeStruct((), jnp.int32), batch_sds["token"])
        tokens = shape.global_batch
    meta = dict(arch=arch, shape=shape_name, kind=shape.kind, tokens=tokens,
                n_params=cfg.n_params(), n_active=cfg.n_active_params())
    return fn, args, meta, cfg, shape


def run_rnsg_cell(multi_pod: bool, variant: str = "base", save: bool = True):
    """Dry-run of the paper's own system at production scale: a 16.8M-vector
    corpus (65536 per 'data' shard × 16, d=128, m=32) served with the
    range-partitioned shard_map search.  Variant 'qshard' additionally shards
    the query batch over the 'model' axis (every model rank serves its own
    1/16 slice — 16× throughput at identical per-query work)."""
    from repro.core.beam import beam_search_batch
    from repro.search import (merge_topk, rank_interval_jax, remap_ids_jax,
                              select_entry)

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = 512 if multi_pod else 256
    data_sz = mesh.shape["data"] * mesh.shape.get("pod", 1)
    ns, d, m, logn = 65536, 128, 32, 17
    nq, k, ef = 1024, 10, 64
    qshard = variant == "qshard"

    def body(vecs, nbrs, attrs, rmq, dist_c, order, qv, ranges):
        vecs, nbrs, attrs = vecs[0], nbrs[0], attrs[0]
        rmq, dist_c, order = rmq[0], dist_c[0], order[0]
        lo, hi = rank_interval_jax(attrs, ranges)
        entry = select_entry(rmq, dist_c, lo, hi, ns)
        ids, dists, _ = beam_search_batch(vecs, nbrs, qv, lo, hi, entry,
                                          k=k, ef=ef)
        orig = remap_ids_jax(order, ids)
        ids_g = jax.lax.all_gather(orig, "data")
        d_g = jax.lax.all_gather(jnp.where(ids >= 0, dists, jnp.inf), "data")
        return merge_topk(ids_g, d_g, k)

    shard = P(("pod", "data") if multi_pod else "data")
    q_spec = P("model") if qshard else P()
    from repro.parallel.sharding import shard_map_compat
    fn = shard_map_compat(body, mesh,
                          in_specs=(shard,) * 6 + (q_spec, q_spec),
                          out_specs=(q_spec, q_spec))
    S = data_sz
    args = (jax.ShapeDtypeStruct((S, ns, d), jnp.float32),
            jax.ShapeDtypeStruct((S, ns, m), jnp.int32),
            jax.ShapeDtypeStruct((S, ns), jnp.float32),
            jax.ShapeDtypeStruct((S, logn, ns), jnp.int32),
            jax.ShapeDtypeStruct((S, ns), jnp.float32),
            jax.ShapeDtypeStruct((S, ns), jnp.int32),
            jax.ShapeDtypeStruct((nq, d), jnp.float32),
            jax.ShapeDtypeStruct((nq, 2), jnp.float32))
    t0 = time.perf_counter()
    with set_mesh_compat(mesh):
        lowered = jax.jit(fn).lower(*args)
        compiled = lowered.compile()
    t_compile = time.perf_counter() - t0
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    try:
        ma = compiled.memory_analysis()
        mem = dict(temp_bytes=getattr(ma, "temp_size_in_bytes", 0),
                   argument_bytes=getattr(ma, "argument_size_in_bytes", 0))
    except Exception as e:
        mem = {"error": str(e)}
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    terms = roofline_terms(flops_dev, bytes_dev, float(coll["total"]), chips)
    rec = dict(arch="rnsg-serve", shape=f"q{nq}_n{S*ns}", mesh=mesh_name,
               chips=chips, variant=variant, compile_s=round(t_compile, 2),
               cost=dict(flops_per_device=flops_dev, bytes_per_device=bytes_dev),
               memory=mem, collectives=coll, roofline=terms,
               note="beam while-loop body counted once by HLO cost analysis; "
                    "use per-query dist-eval stats from benchmarks for "
                    "absolute work accounting")
    if save:
        RESULTS.mkdir(parents=True, exist_ok=True)
        tag = f"rnsg-serve__q{nq}__{mesh_name}" + \
              ("" if variant == "base" else f"__{variant}")
        (RESULTS / f"{tag}.json").write_text(json.dumps(rec, indent=1,
                                                        default=float))
    print(f"[dryrun] rnsg-serve {mesh_name} {variant}: compile={t_compile:.1f}s"
          f" flops/dev={flops_dev:.2e} coll={coll['total']/2**20:.1f}MiB/dev"
          f" args={mem.get('argument_bytes', 0)/2**30:.1f}GiB")
    return rec


def run_cell(arch: str, shape_name: str, multi_pod: bool, variant: str = "base",
             save: bool = True, verbose: bool = True, analysis: bool = True):
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}" + \
          ("" if variant == "base" else f"__{variant}")
    out_path = RESULTS / f"{tag}.json"
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    if not shape.applicable(cfg):
        rec = dict(arch=arch, shape=shape_name, mesh=mesh_name, variant=variant,
                   skipped=f"long_500k requires sub-quadratic attention "
                           f"({cfg.family} is full-attention)")
        if save:
            out_path.parent.mkdir(parents=True, exist_ok=True)
            out_path.write_text(json.dumps(rec, indent=1))
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 512 if multi_pod else 256

    def compile_once(analysis: bool, depth_groups: int = 0):
        t0 = time.perf_counter()
        fn, args, meta, cfg, shape = build_cell(arch, shape_name, mesh, variant,
                                                analysis=analysis,
                                                depth_groups=depth_groups)
        with set_mesh_compat(mesh):
            lowered = fn.lower(*args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
        return compiled, t_lower, t_compile, meta, cfg, shape

    # 1) production artifact (scanned layers): proves compile, gives memory
    compiled_p, t_lower, t_compile, meta, cfg, shape = compile_once(False)
    try:
        ma = compiled_p.memory_analysis()
        mem = dict(argument_bytes=getattr(ma, "argument_size_in_bytes", 0),
                   output_bytes=getattr(ma, "output_size_in_bytes", 0),
                   temp_bytes=getattr(ma, "temp_size_in_bytes", 0),
                   peak_bytes=getattr(ma, "peak_memory_in_bytes", 0),
                   code_bytes=getattr(ma, "generated_code_size_in_bytes", 0))
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}

    if not analysis:   # multi-pod pass: prove the pod axis shards, skip probes
        rec = dict(arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
                   variant=variant, meta=meta, lower_s=round(t_lower, 2),
                   compile_s=round(t_compile, 2), memory=mem)
        if save:
            out_path.parent.mkdir(parents=True, exist_ok=True)
            out_path.write_text(json.dumps(rec, indent=1, default=float))
        if verbose:
            print(f"[dryrun] {tag}: compile={t_compile:.1f}s (production only) "
                  f"temp={mem.get('temp_bytes', 0)/2**30:.2f}GiB/dev")
        return rec

    # 2) analysis artifacts: fully unrolled (python-loop) models at depths
    #    g=1 and g=2; per-group cost increments are exact because groups are
    #    homogeneous, so cost(G) = cost(1) + (cost(2) - cost(1))·(G - 1).
    #    (Needed because XLA's HloCostAnalysis counts a while body once.)
    from repro.models.params import resolve_dims as _rd
    from repro.models.params import ShardPlan as _SP
    g_full = get_config(arch).n_layers // _rd(get_config(arch), _SP()).group_layers

    def probe(g):
        compiled, _, tc, *_ = compile_once(True, depth_groups=g)
        cost = compiled.cost_analysis() or {}
        coll = collective_bytes(compiled.as_text())
        return (float(cost.get("flops", 0.0)),
                float(cost.get("bytes accessed", 0.0)),
                float(coll["total"]), coll, tc)

    f1, b1, c1, coll1, tc1 = probe(1)
    if g_full > 1:
        f2, b2, c2, coll2, tc2 = probe(2)
    else:
        f2, b2, c2, coll2, tc2 = f1, b1, c1, coll1, 0.0
    t_compile_a = tc1 + tc2
    flops_dev = f1 + (f2 - f1) * (g_full - 1)
    bytes_dev = b1 + (b2 - b1) * (g_full - 1)
    coll_dev = c1 + (c2 - c1) * (g_full - 1)
    coll = {k: coll1[k] + (coll2[k] - coll1[k]) * (g_full - 1)
            for k in coll1}
    terms = roofline_terms(flops_dev, bytes_dev, coll_dev, chips)
    mf = model_flops(cfg, shape, meta["n_active"])
    rec = dict(arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
               variant=variant, meta=meta,
               lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
               compile_analysis_s=round(t_compile_a, 2),
               cost=dict(flops_per_device=flops_dev,
                         bytes_per_device=bytes_dev),
               memory=mem, collectives=coll, roofline=terms,
               model_flops=mf,
               useful_ratio=(mf / terms["flops_global"]
                             if terms["flops_global"] else None))
    if save:
        out_path.parent.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(rec, indent=1, default=float))
    if verbose:
        print(f"[dryrun] {tag}: compile={t_compile:.1f}s "
              f"dom={terms['dominant']} t={terms['t_bound']*1e3:.2f}ms "
              f"useful={rec['useful_ratio'] and round(rec['useful_ratio'],3)} "
              f"coll={coll['total']/2**20:.1f}MiB/dev "
              f"temp={mem.get('temp_bytes',0)/2**30:.2f}GiB/dev")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--variant", default="base")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--jobs", default="",
                    help="k/N: process cells with index %% N == k (sharded driver)")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-analysis", action="store_true",
                    help="production compile only (multi-pod pass)")
    args = ap.parse_args()

    if args.arch == "rnsg-serve":
        run_rnsg_cell(args.multipod, args.variant)
        return

    todo = []
    if args.all:
        meshes = [False, True] if args.both_meshes else [args.multipod]
        for mp in meshes:
            for arch, shape, skip in cells(include_inapplicable=True):
                todo.append((arch, shape, mp))
    else:
        todo = [(args.arch, args.shape, args.multipod)]
    if args.jobs:
        k, n = map(int, args.jobs.split("/"))
        todo = [t for i, t in enumerate(todo) if i % n == k]

    failures = []
    for arch, shape, mp in todo:
        mesh_name = "pod2x16x16" if mp else "pod16x16"
        tag = f"{arch}__{shape}__{mesh_name}" + \
              ("" if args.variant == "base" else f"__{args.variant}")
        out_path = RESULTS / f"{tag}.json"
        if out_path.exists() and not args.force:
            print(f"[dryrun] {tag}: cached")
            continue
        try:
            run_cell(arch, shape, mp, args.variant,
                     analysis=not args.no_analysis and not mp)
        except Exception:
            failures.append(tag)
            print(f"[dryrun] {tag}: FAILED")
            traceback.print_exc()
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
