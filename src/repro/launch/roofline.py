"""Aggregate results/dryrun/*.json into the §Dry-run and §Roofline markdown
tables, plus an analytic per-device memory model (the CPU backend's
``memory_analysis`` lacks TPU buffer-reuse accounting, so we back the fits
claim with arithmetic over params/optimizer/cache/carry bytes).

  PYTHONPATH=src python -m repro.launch.roofline [--variant base]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs.base import SHAPES
from repro.configs.registry import get_config, list_archs
from repro.models.params import ShardPlan, resolve_dims

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"
HBM_PER_CHIP = 16e9          # v5e


def analytic_memory(arch: str, shape_name: str, chips_grid=(16, 16)) -> dict:
    """Per-device bytes: params + optimizer + grads + remat carries + caches."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    data, model = chips_grid
    dev = data * model
    n = cfg.n_params()
    out = {}
    if shape.kind == "train":
        # FSDP strategy: everything ZeRO-3 over all devices
        opt_b = 2 if cfg.opt_dtype == "bfloat16" else 4
        params = 2 * n / dev
        opt = 2 * opt_b * n / dev
        grads = 2 * n / dev          # grads carry the param dtype (bf16)
        # remat carries: (B/dev_eff) × seq × d × 2B × (groups / remat_group)
        gl = resolve_dims(cfg, ShardPlan()).group_layers
        groups = cfg.n_layers // gl
        b_eff = min(shape.global_batch, dev)
        tokens_dev = shape.global_batch * shape.seq_len / b_eff
        carries = tokens_dev * cfg.d_model * 2 * max(
            groups // max(cfg.remat_group, 1), 1)
        out.update(params=params, opt=opt, grads=grads, act_carries=carries,
                   total=params + opt + grads + carries)
    else:
        # TP strategy: params fsdp×tp; KV heads padded to TP
        params = 2 * n / dev
        dm = resolve_dims(cfg, ShardPlan(tp=model, fsdp=data, vocab_multiple=256))
        cache = 0.0
        if cfg.family in ("dense", "moe", "encdec", "vlm", "hybrid"):
            layers_with_kv = (cfg.n_layers // cfg.attn_every if cfg.attn_every
                              else cfg.n_layers)
            kv = (2 * layers_with_kv * shape.global_batch * shape.seq_len
                  * dm.kh * dm.hd * 2)
            b_shard = min(shape.global_batch, data)
            h_shard = model if dm.kh % model == 0 else 1
            if shape.long_context:       # seq sharded over data instead
                kv_dev = kv / data / h_shard
            else:
                kv_dev = kv / b_shard / h_shard
            cache += kv_dev
        if cfg.ssm_state:
            n_m = (cfg.n_layers - (cfg.n_layers // cfg.attn_every
                                   if cfg.attn_every else 0)
                   if cfg.family == "hybrid" else cfg.n_layers)
            st = n_m * shape.global_batch * dm.ssm_h * dm.ssm_p * dm.ssm_n * 4
            cache += st / min(shape.global_batch, data) / \
                (model if dm.ssm_h % model == 0 else 1)
        out.update(params=params, cache=cache, total=params + cache)
    out["fits_16GB"] = out["total"] < HBM_PER_CHIP
    return out


def load(variant: str = "base", mesh: str = "pod16x16"):
    recs = {}
    suffix = "" if variant == "base" else f"__{variant}"
    for arch in list_archs():
        for sname in SHAPES:
            p = RESULTS / f"{arch}__{sname}__{mesh}{suffix}.json"
            if p.exists():
                recs[(arch, sname)] = json.loads(p.read_text())
    return recs


def fraction(rec) -> float:
    """Useful-compute fraction of the roofline bound: time the MXU would need
    for MODEL_FLOPS over the bound implied by the dominant term."""
    t_model = rec["model_flops"] / rec["chips"] / 197e12
    return t_model / max(rec["roofline"]["t_bound"], 1e-12)


def roofline_table(variant: str = "base") -> str:
    recs = load(variant)
    lines = ["| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | dominant "
             "| MODEL_FLOPS | useful | roofline frac | fits16G |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for (arch, sname), r in sorted(recs.items()):
        if "skipped" in r:
            lines.append(f"| {arch} | {sname} | — | — | — | skipped | — | — | — "
                         f"| — |")
            continue
        t = r["roofline"]
        am = analytic_memory(arch, sname)
        lines.append(
            f"| {arch} | {sname} | {t['t_compute']*1e3:.2f} | "
            f"{t['t_memory']*1e3:.2f} | {t['t_collective']*1e3:.2f} | "
            f"{t['dominant']} | {r['model_flops']:.2e} | "
            f"{(r['useful_ratio'] or 0):.3f} | {fraction(r):.3f} | "
            f"{'✓' if am['fits_16GB'] else '✗ (' + format(am['total']/2**30, '.0f') + 'G)'} |")
    return "\n".join(lines)


def dryrun_table() -> str:
    lines = ["| arch | shape | 16×16 compile | 2×16×16 compile | coll MiB/dev "
             "(1-pod) |", "|---|---|---|---|---|"]
    single = load("base", "pod16x16")
    multi = load("base", "pod2x16x16")
    for key in sorted(single):
        r1, r2 = single[key], multi.get(key, {})
        if "skipped" in r1:
            lines.append(f"| {key[0]} | {key[1]} | skipped (full attention) "
                         f"| skipped | — |")
            continue
        c1 = f"{r1['compile_s']:.1f}s ✓"
        c2 = f"{r2.get('compile_s', float('nan')):.1f}s ✓" if r2 and "skipped" not in r2 else "—"
        coll = r1["collectives"]["total"] / 2**20
        lines.append(f"| {key[0]} | {key[1]} | {c1} | {c2} | {coll:,.0f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="base")
    ap.add_argument("--table", choices=["roofline", "dryrun", "both"],
                    default="both")
    args = ap.parse_args()
    if args.table in ("dryrun", "both"):
        print("### Dry-run\n")
        print(dryrun_table())
        print()
    if args.table in ("roofline", "both"):
        print("### Roofline (single pod, 256 × v5e)\n")
        print(roofline_table(args.variant))


if __name__ == "__main__":
    main()
