"""Production mesh builders (functions — importing this module never touches
jax device state)."""
from __future__ import annotations

import jax

from repro.parallel.sharding import make_mesh_compat


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 = 256 chips (data, model).
    Multi-pod: 2×16×16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_mesh_for(devices: int, model_parallel: int = 1):
    """Elastic helper: build a (data, model) mesh from whatever devices
    survive — used by the elastic-restart path (checkpoints are
    mesh-agnostic, so resuming on a different device count just re-shards)."""
    assert devices % model_parallel == 0
    return jax.make_mesh((devices // model_parallel, model_parallel),
                         ("data", "model"))
