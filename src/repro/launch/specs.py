"""ShapeDtypeStruct input stand-ins for every (arch × shape) cell.

Shapes follow the assignment contract:
  * train/prefill: tokens (global_batch, seq_len)
  * decode_*: ONE new token with a KV cache of seq_len (serve_step, not train)
  * [audio]/[vlm]: the modality frontend is a stub — ``input_specs`` delivers
    precomputed frame/patch embeddings.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig

SDS = jax.ShapeDtypeStruct


def input_specs(cfg: ArchConfig, shape: ShapeConfig, dm=None) -> Dict:
    b, s = shape.global_batch, shape.seq_len
    bf = jnp.dtype(cfg.dtype)
    out: Dict = {}
    if shape.kind == "train":
        out["tokens"] = SDS((b, s), jnp.int32)
        out["labels"] = SDS((b, s), jnp.int32)
    elif shape.kind == "prefill":
        out["tokens"] = SDS((b, s), jnp.int32)
    elif shape.kind == "decode":
        out["token"] = SDS((b,), jnp.int32)
    if cfg.family == "encdec" and shape.kind in ("train", "prefill"):
        out["frames"] = SDS((b, max(s // 4, 1), cfg.frontend_dim or cfg.d_model), bf)
    if cfg.family == "vlm" and shape.kind in ("train", "prefill"):
        out["patches"] = SDS((b, cfg.n_frontend_tokens,
                              cfg.frontend_dim or cfg.d_model), bf)
    return out


def concrete_batch(cfg: ArchConfig, shape_kind: str, batch: int, seq: int,
                   rng: np.random.Generator) -> Dict:
    """Small concrete batch for smoke tests / examples."""
    out: Dict = {}
    v = cfg.vocab_size
    if shape_kind == "train":
        out["tokens"] = jnp.asarray(rng.integers(0, v, (batch, seq)), jnp.int32)
        out["labels"] = jnp.asarray(rng.integers(0, v, (batch, seq)), jnp.int32)
    elif shape_kind == "prefill":
        out["tokens"] = jnp.asarray(rng.integers(0, v, (batch, seq)), jnp.int32)
    elif shape_kind == "decode":
        out["token"] = jnp.asarray(rng.integers(0, v, (batch,)), jnp.int32)
    if cfg.family == "encdec" and shape_kind in ("train", "prefill"):
        out["frames"] = jnp.asarray(
            rng.standard_normal((batch, max(seq // 4, 1),
                                 cfg.frontend_dim or cfg.d_model)),
            jnp.dtype(cfg.dtype))
    if cfg.family == "vlm" and shape_kind in ("train", "prefill"):
        out["patches"] = jnp.asarray(
            rng.standard_normal((batch, cfg.n_frontend_tokens,
                                 cfg.frontend_dim or cfg.d_model)),
            jnp.dtype(cfg.dtype))
    return out
