"""Distributed RFANN serving: range-partitioned shards via ``shard_map``.

The scale-out design falls directly out of Theorem 4.7 (structural heredity):
an attribute-contiguous shard's induced subgraph *is* the RNSG built on that
shard, so

  * shards can be **constructed independently in parallel** (provably
    equivalent to slicing a global build, up to KNN approximation noise), and
  * a query with range ``q.I`` only needs the shards whose attribute span
    intersects ``q.I``; per-shard searches are exact RNSG searches on their
    sub-ranges, and a top-k merge of shard results equals the global search.

Resolution happens **once**, globally: the query's attribute range maps to a
global rank interval (``repro.search.resolve``), which each shard *clips* to
its contiguous rank slice — no per-shard ``searchsorted``.  Execution then
routes through the unified search substrate, and ``plan="auto"`` works on
**both** paths:

  * local path (``mesh=None``): one ``SearchSubstrate`` per shard, so each
    shard runs the full strategy router (fused range-scan | beam per query,
    with online cost calibration), followed by a host top-k merge.  By
    default the per-shard dispatches are **asynchronous**: every shard's
    device work is enqueued (``SearchSubstrate.dispatch``, jax async
    dispatch) before any shard's result is blocked on, so shard N+1's
    planning and upload overlap shard N's kernels; ``async_dispatch=False``
    restores the sequential dispatch+block loop (whose per-shard wall
    times feed wall-clock calibration — the async loop skips it, since a
    shard's block time includes its siblings' queued work);
  * mesh path: one shard per device along the ``data`` axis via
    ``MeshSubstrate`` — the strategy vector is planned host-side from the
    shard-clipped global intervals and the traced per-device body executes a
    branchless scan+beam select (each kernel at most once per shard),
    restitched in request order before the cross-shard ``all_gather`` +
    top-k merge.  See docs/distributed.md for the full dispatch flow.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.construction import build_rnsg
from repro.search import (MeshSubstrate, SearchCache, SearchRequest,
                          SearchResult, SearchSubstrate, clip_interval,
                          merge_topk, rank_interval)


class DistributedRFANN:
    """Attribute-range-partitioned RNSG serving across the 'data' mesh axis."""

    def __init__(self, vectors: np.ndarray, attrs: np.ndarray, *,
                 n_shards: int, mesh=None, axis: str = "data",
                 async_dispatch: bool = True, **build_kw):
        order = np.argsort(attrs, kind="stable")
        vs = np.asarray(vectors, np.float32)[order]
        as_ = np.asarray(attrs, np.float32)[order]
        n = len(as_)
        per = n // n_shards
        assert per * n_shards == n, "pad the corpus to a shard multiple"
        self.mesh = mesh
        self.axis = axis
        self.n_shards = n_shards
        self.per = per
        self.attrs_sorted = as_       # global resolve happens over this
        graphs = []
        for s in range(n_shards):      # independently buildable (heredity)
            sl = slice(s * per, (s + 1) * per)
            g = build_rnsg(vs[sl], as_[sl], **build_kw)
            graphs.append((g, order[sl]))
        self.shard_span = np.asarray(
            [[g.attrs[0], g.attrs[-1]] for g, _ in graphs], np.float32)
        stack = lambda f: jnp.asarray(np.stack([f(g, o) for g, o in graphs]))  # noqa: E731
        self.vecs = stack(lambda g, o: g.vecs)
        self.nbrs = stack(lambda g, o: g.nbrs)
        self.attrs = stack(lambda g, o: g.attrs)
        self.rmq = stack(lambda g, o: g.rmq)
        self.dist_c = stack(lambda g, o: g.dist_c)
        self.order = stack(lambda g, o: o[g.order].astype(np.int32))
        self.rank0 = jnp.asarray(
            np.arange(n_shards, dtype=np.int32)[:, None] * per)   # (S, 1)
        self.build_seconds = sum(g.build_seconds for g, _ in graphs)
        self.async_dispatch = async_dispatch
        self._subs: Optional[list] = None
        self._mesh_sub: Optional[MeshSubstrate] = None
        self._cache: Optional[SearchCache] = None
        self._metrics = None

    @property
    def index_bytes(self) -> int:
        return (self.nbrs.nbytes + self.rmq.nbytes + self.dist_c.nbytes)

    # ------------------------------------------------------------------
    @property
    def substrates(self):
        """One unified search substrate per shard (local execution path)."""
        if self._subs is None:
            self._subs = [
                SearchSubstrate(self.vecs[s], self.nbrs[s], self.rmq[s],
                                self.dist_c[s], np.asarray(self.order[s]),
                                np.asarray(self.attrs[s]),
                                cache=self._cache, cache_ns=s,
                                metrics=self._metrics)
                for s in range(self.n_shards)]
        return self._subs

    @property
    def mesh_substrate(self) -> MeshSubstrate:
        """The shard_map execution path (lazy; requires ``mesh``)."""
        if self._mesh_sub is None:
            assert self.mesh is not None, "mesh execution needs mesh="
            self._mesh_sub = MeshSubstrate(
                self.mesh, self.axis, self.vecs, self.nbrs, self.rmq,
                self.dist_c, self.order, self.rank0, cache=self._cache,
                metrics=self._metrics)
        return self._mesh_sub

    def install_cache(self, cache: Optional[SearchCache]) -> None:
        """Install one shared result cache on every execution path.  On the
        local path each shard substrate keys its own shard-clipped interval,
        so shards share the byte budget without colliding."""
        self._cache = cache
        if self._subs is not None:
            for sub in self._subs:
                sub.cache = cache
        if self._mesh_sub is not None:
            self._mesh_sub.cache = cache

    def install_metrics(self, metrics) -> None:
        """Install (or remove, with ``None``) a ``MetricsRegistry`` on every
        execution path — already-built shard substrates and the mesh
        substrate pick it up immediately, lazy ones at construction."""
        self._metrics = metrics
        if self._subs is not None:
            for sub in self._subs:
                sub.metrics = metrics
        if self._mesh_sub is not None:
            self._mesh_sub.metrics = metrics

    def install_quantized(self, precision: str) -> None:
        """Pre-build the quantized corpus copies on every execution path."""
        if precision == "f32":
            return
        if self.mesh is not None:
            self.mesh_substrate.install_quantized(precision)
        else:
            for sub in self.substrates:
                sub.install_quantized(precision)

    def _search_local(self, qv, lo, hi, *, k: int, ef: int, plan: str,
                      beam_width: int = 1, precision: str = "f32",
                      trace=None, live=None):
        """Per-shard substrate dispatch, merged by the same ``merge_topk``
        the mesh path uses — identical ids by construction.  With
        ``async_dispatch`` every shard's work is enqueued before any block
        (the merge is the single synchronization point); otherwise shards
        run the sequential dispatch+block loop with wall calibration.

        Returns ``(ids, dists, stats)`` — stats aggregate the per-shard
        substrate stats: ``cache_hits`` is total shard hits normalized by
        the shard count (≈ fully-cached queries), ``scan_frac`` the mean
        routed scan fraction across shards."""
        q = len(qv)
        all_i = np.full((self.n_shards, q, k), -1, np.int32)
        all_d = np.full((self.n_shards, q, k), np.inf, np.float32)
        digests = None
        if self._cache is not None and q:       # hash each query ONCE, not
            from repro.search.cache import hash_query     # once per shard
            digests = [hash_query(qv[i]) for i in range(q)]
        pending = []
        for s, sub in enumerate(self.substrates):
            slo, shi = clip_interval(lo, hi, s * self.per, self.per)
            # every shard shares the one trace; its spans are tagged by the
            # substrate with ns=<shard>, and the blocking loop below drains
            # shards sequentially so appends never race
            req = SearchRequest(queries=qv, lo=slo, hi=shi,
                                k=k, ef=ef, strategy=plan,
                                beam_width=beam_width, precision=precision,
                                trace=trace,
                                live=None if live is None
                                else live[s * self.per:(s + 1) * self.per])
            p = sub.dispatch(req, defer=self.async_dispatch,
                             q_digests=digests)
            if not self.async_dispatch:
                p.result()              # block before the next shard starts
            pending.append(p)
        hits = 0
        scan_fracs = []
        for s, p in enumerate(pending):
            res = p.result()
            all_i[s] = res.ids
            all_d[s] = np.where(res.ids >= 0, res.dists, np.inf)
            hits += int(res.stats.get("cache_hits", 0))
            if "scan_frac" in res.stats:
                scan_fracs.append(float(res.stats["scan_frac"]))
        from repro.obs import maybe_span
        with maybe_span(trace, "stitch", ns="merge",
                        n_shards=self.n_shards) as sp:
            ids, dists = merge_topk(jnp.asarray(all_i), jnp.asarray(all_d), k)
            ids, dists = np.asarray(ids), np.asarray(dists)
            sp.attrs["q"] = q
        stats = {}
        if scan_fracs:
            stats["scan_frac"] = float(np.mean(scan_fracs))
        if self._cache is not None:
            stats["cache_hits"] = int(round(hits / self.n_shards))
        return ids, dists, stats

    # ------------------------------------------------------------------
    def rank_range(self, attr_ranges: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """[a_l, a_r] (inclusive) -> *global* rank interval [L, R] over the
        attribute-sorted corpus (host-side resolve; the engine's pipelined
        resolver stage calls this while the previous batch executes)."""
        return rank_interval(self.attrs_sorted,
                             np.asarray(attr_ranges, np.float32))

    def search_ranks(self, queries, lo, hi, *, k: int = 10, ef: int = 64,
                     plan: str = "graph", beam_width: int = 1,
                     precision: str = "f32", trace=None,
                     live=None) -> SearchResult:
        """Rank-space entry point (resolve already done): dispatch on the
        mesh path when a mesh is attached, else the (async) local path.
        ``live`` is the *global* (n,) per-rank liveness mask; the local
        path slices it per shard, the mesh path reshapes it across the
        data axis."""
        qv = np.asarray(queries, np.float32)
        ef = max(ef, k)
        if self.mesh is None:
            ids, dists, stats = self._search_local(qv, lo, hi, k=k, ef=ef,
                                                   plan=plan,
                                                   beam_width=beam_width,
                                                   precision=precision,
                                                   trace=trace, live=live)
            return SearchResult(ids, dists, stats, trace=trace)
        return self.mesh_substrate.run(SearchRequest(
            queries=qv, lo=lo, hi=hi, k=k, ef=ef, strategy=plan,
            beam_width=beam_width, precision=precision, trace=trace,
            live=live))

    def search(self, queries: np.ndarray, attr_ranges: np.ndarray, *,
               k: int = 10, ef: int = 64, plan: str = "graph",
               beam_width: int = 1, precision: str = "f32",
               trace=None, live=None) -> Tuple[np.ndarray, np.ndarray]:
        from repro.obs import maybe_span
        with maybe_span(trace, "resolve") as sp:
            lo, hi = self.rank_range(attr_ranges)
            sp.attrs.update(
                q=len(np.atleast_2d(queries)), n=len(self.attrs_sorted),
                interval_widths=np.clip(
                    np.asarray(hi, np.int64) - np.asarray(lo, np.int64) + 1,
                    0, None) if trace is not None else None)
        res = self.search_ranks(queries, lo, hi, k=k, ef=ef, plan=plan,
                                beam_width=beam_width, precision=precision,
                                trace=trace, live=live)
        return res.ids, res.dists

    # ------------------------------------------------------------------
    def lower_for_dryrun(self, nq: int, d: int, k: int = 10, ef: int = 64,
                         precision: str = "f32"):
        """Compile-only proof that the sharded search lowers on a real mesh."""
        ms = self.mesh_substrate
        fn = ms.graph_fn(k, ef, precision=precision)
        slot = ms._quant_for(precision)
        xq = self.vecs if slot is None else slot["data"]
        scale = ms._ones_scale() if slot is None else slot["scale_pad"]
        live = ms._live_shards(None)        # all-ones dummy (uniform operand)
        args = (self.vecs, self.nbrs, self.rmq, self.dist_c, self.order,
                self.rank0, xq, scale, live,
                jax.ShapeDtypeStruct((nq, d), jnp.float32),
                jax.ShapeDtypeStruct((nq,), jnp.int32),
                jax.ShapeDtypeStruct((nq,), jnp.int32))
        sds = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in args[:9]]
        return jax.jit(fn).lower(*sds, *args[9:])
