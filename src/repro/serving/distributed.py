"""Distributed RFANN serving: range-partitioned shards via ``shard_map``.

The scale-out design falls directly out of Theorem 4.7 (structural heredity):
an attribute-contiguous shard's induced subgraph *is* the RNSG built on that
shard, so

  * shards can be **constructed independently in parallel** (provably
    equivalent to slicing a global build, up to KNN approximation noise), and
  * a query with range ``q.I`` only needs the shards whose attribute span
    intersects ``q.I``; per-shard beam searches are exact RNSG searches on
    their sub-ranges, and a top-k merge of shard results equals the global
    range search.

Execution: one shard per device along the ``data`` axis; queries are
replicated; each device clips the query range to its shard (empty ⇒ the beam
no-ops), runs the batched beam search, and an ``all_gather`` + top-k merge
produces replicated results.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.beam import beam_search_batch
from repro.core.construction import build_rnsg
from repro.core.entry import rmq_query_jax


def _shard_search(vecs, nbrs, attrs, rmq, dist_c, order, qv, ranges, *,
                  k: int, ef: int):
    """Per-device body. Leading shard dim of size 1 (shard_map slice)."""
    vecs, nbrs, attrs = vecs[0], nbrs[0], attrs[0]
    rmq, dist_c, order = rmq[0], dist_c[0], order[0]
    n = attrs.shape[0]
    lo = jnp.searchsorted(attrs, ranges[:, 0], side="left").astype(jnp.int32)
    hi = (jnp.searchsorted(attrs, ranges[:, 1], side="right") - 1).astype(jnp.int32)
    entry = rmq_query_jax(rmq, dist_c, jnp.minimum(lo, n - 1),
                          jnp.clip(hi, 0, n - 1))
    ids, dists, _ = beam_search_batch(vecs, nbrs, qv, lo, hi, entry, k=k, ef=ef)
    orig = jnp.where(ids >= 0, order[jnp.maximum(ids, 0)], -1)
    dists = jnp.where(ids >= 0, dists, jnp.inf)
    return orig[None], dists[None]                       # (1, Q, k)


def _merge_topk(ids, dists, k: int):
    """(S,Q,k) -> (Q,k) global top-k."""
    s, q, kk = ids.shape
    flat_i = jnp.moveaxis(ids, 0, 1).reshape(q, s * kk)
    flat_d = jnp.moveaxis(dists, 0, 1).reshape(q, s * kk)
    nd, sel = jax.lax.top_k(-flat_d, k)
    out_i = jnp.take_along_axis(flat_i, sel, axis=1)
    return jnp.where(jnp.isfinite(-nd), out_i, -1), -nd


class DistributedRFANN:
    """Attribute-range-partitioned RNSG serving across the 'data' mesh axis."""

    def __init__(self, vectors: np.ndarray, attrs: np.ndarray, *,
                 n_shards: int, mesh=None, axis: str = "data", **build_kw):
        order = np.argsort(attrs, kind="stable")
        vs = np.asarray(vectors, np.float32)[order]
        as_ = np.asarray(attrs, np.float32)[order]
        n = len(as_)
        per = n // n_shards
        assert per * n_shards == n, "pad the corpus to a shard multiple"
        self.mesh = mesh
        self.axis = axis
        self.n_shards = n_shards
        graphs = []
        for s in range(n_shards):      # independently buildable (heredity)
            sl = slice(s * per, (s + 1) * per)
            g = build_rnsg(vs[sl], as_[sl], **build_kw)
            graphs.append((g, order[sl]))
        self.shard_span = np.asarray(
            [[g.attrs[0], g.attrs[-1]] for g, _ in graphs], np.float32)
        stack = lambda f: jnp.asarray(np.stack([f(g, o) for g, o in graphs]))  # noqa: E731
        self.vecs = stack(lambda g, o: g.vecs)
        self.nbrs = stack(lambda g, o: g.nbrs)
        self.attrs = stack(lambda g, o: g.attrs)
        self.rmq = stack(lambda g, o: g.rmq)
        self.dist_c = stack(lambda g, o: g.dist_c)
        self.order = stack(lambda g, o: o[g.order].astype(np.int32))
        self.build_seconds = sum(g.build_seconds for g, _ in graphs)

    @property
    def index_bytes(self) -> int:
        return (self.nbrs.nbytes + self.rmq.nbytes + self.dist_c.nbytes)

    # ------------------------------------------------------------------
    def _search_fn(self, k: int, ef: int):
        body = partial(_shard_search, k=k, ef=ef)

        if self.mesh is None:
            def local(vecs, nbrs, attrs, rmq, dist_c, order, qv, ranges):
                outs = [body(vecs[s:s + 1], nbrs[s:s + 1], attrs[s:s + 1],
                             rmq[s:s + 1], dist_c[s:s + 1], order[s:s + 1],
                             qv, ranges) for s in range(self.n_shards)]
                ids = jnp.concatenate([o[0] for o in outs])
                ds = jnp.concatenate([o[1] for o in outs])
                return _merge_topk(ids, ds, k)
            return jax.jit(local)

        ax = self.axis

        def sharded(vecs, nbrs, attrs, rmq, dist_c, order, qv, ranges):
            ids, ds = body(vecs, nbrs, attrs, rmq, dist_c, order, qv, ranges)
            ids = jax.lax.all_gather(ids[0], ax)         # (S, Q, k)
            ds = jax.lax.all_gather(ds[0], ax)
            return _merge_topk(ids, ds, k)

        shard_spec = P(ax)
        rep = P()
        fn = jax.shard_map(
            sharded, mesh=self.mesh,
            in_specs=(shard_spec,) * 6 + (rep, rep),
            out_specs=(rep, rep), check_vma=False)
        return jax.jit(fn)

    def search(self, queries: np.ndarray, attr_ranges: np.ndarray, *,
               k: int = 10, ef: int = 64) -> Tuple[np.ndarray, np.ndarray]:
        fn = self._search_fn(k, max(ef, k))
        ids, dists = fn(self.vecs, self.nbrs, self.attrs, self.rmq,
                        self.dist_c, self.order,
                        jnp.asarray(queries, jnp.float32),
                        jnp.asarray(attr_ranges, jnp.float32))
        return np.asarray(ids), np.asarray(dists)

    # ------------------------------------------------------------------
    def lower_for_dryrun(self, nq: int, d: int, k: int = 10, ef: int = 64):
        """Compile-only proof that the sharded search lowers on a real mesh."""
        fn = self._search_fn(k, ef)
        args = (self.vecs, self.nbrs, self.attrs, self.rmq, self.dist_c,
                self.order,
                jax.ShapeDtypeStruct((nq, d), jnp.float32),
                jax.ShapeDtypeStruct((nq, 2), jnp.float32))
        sds = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in args[:6]]
        return jax.jit(fn).lower(*sds, *args[6:])
