"""Batched RFANN serving engine: dynamic batching over a request queue.

Requests (query vector + attribute range) are coalesced into batches of up to
``max_batch`` or ``max_wait_ms``, executed through the unified search
substrate (``index.search`` returns a ``SearchResult``; under ``plan="auto"``
each dynamic batch is partitioned into fused range-scan and beam-search
dispatches by selectivity — see ``repro.planner``), and resolved through
per-request futures, each carrying its own per-request ``SearchResult``.

If ``calibration_path`` is given, the planner's online-calibrated cost model
is restored from it at startup and persisted (atomically: temp file +
rename) at ``close()`` — a restarted server starts from steady-state
routing instead of the prior, and a crash mid-shutdown can never leave a
truncated file behind.
"""
from __future__ import annotations

import os
import queue
import random
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np


@dataclass
class EngineStats:
    """Bounded: latencies are a fixed-size uniform reservoir (Vitter's
    Algorithm R), so a long-running server keeps O(1) memory while the
    percentile summary stays an unbiased estimate of the full stream."""
    served: int = 0
    batches: int = 0
    scan_routed: int = 0
    reservoir_size: int = 4096
    latencies_ms: List[float] = field(default_factory=list)
    lat_seen: int = 0
    _rng: random.Random = field(default_factory=lambda: random.Random(0),
                                repr=False)

    def record_latency(self, ms: float) -> None:
        self.lat_seen += 1
        if len(self.latencies_ms) < self.reservoir_size:
            self.latencies_ms.append(ms)
        else:
            j = self._rng.randrange(self.lat_seen)
            if j < self.reservoir_size:
                self.latencies_ms[j] = ms

    def summary(self) -> dict:
        lat = np.asarray(self.latencies_ms) if self.latencies_ms else np.zeros(1)
        return dict(served=self.served, batches=self.batches,
                    mean_batch=self.served / max(self.batches, 1),
                    scan_frac=self.scan_routed / max(self.served, 1),
                    p50_ms=float(np.percentile(lat, 50)),
                    p95_ms=float(np.percentile(lat, 95)),
                    p99_ms=float(np.percentile(lat, 99)))


class RFANNEngine:
    def __init__(self, index, *, k: int = 10, ef: int = 64,
                 max_batch: int = 64, max_wait_ms: float = 2.0,
                 plan: str = "auto",
                 calibration_path: Optional[str] = None):
        self.index = index
        self.k, self.ef = k, ef
        self.plan = plan
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1000.0
        self.calibration_path = calibration_path
        if calibration_path and os.path.exists(calibration_path):
            planner = getattr(index, "planner", None)
            if planner is not None:
                try:
                    planner.load_calibration(calibration_path)
                except ValueError as e:     # stale schema / wrong corpus:
                    import warnings         # serve from the prior instead
                    warnings.warn(f"ignoring calibration: {e}")
        self._q: queue.Queue = queue.Queue()
        self._stop = threading.Event()
        self.stats = EngineStats()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    # ------------------------------------------------------------------
    def submit(self, query: np.ndarray, attr_range: Tuple[float, float]) -> Future:
        fut: Future = Future()
        self._q.put((np.asarray(query, np.float32),
                     np.asarray(attr_range, np.float32), time.perf_counter(), fut))
        return fut

    def _loop(self):
        while not self._stop.is_set():
            try:
                first = self._q.get(timeout=0.05)
            except queue.Empty:
                continue
            batch = [first]
            deadline = time.perf_counter() + self.max_wait
            while len(batch) < self.max_batch:
                left = deadline - time.perf_counter()
                if left <= 0:
                    break
                try:
                    batch.append(self._q.get(timeout=left))
                except queue.Empty:
                    break
            qv = np.stack([b[0] for b in batch])
            rg = np.stack([b[1] for b in batch])
            res = self.index.search(qv, rg, k=self.k, ef=self.ef,
                                    plan=self.plan)
            if "strategy" in res.stats:
                from repro.planner import SCAN
                self.stats.scan_routed += int(
                    (np.asarray(res.stats["strategy"]) == SCAN).sum())
            now = time.perf_counter()
            for i, (_, _, t0, fut) in enumerate(batch):
                self.stats.record_latency((now - t0) * 1e3)
                fut.set_result(res.row(i))
            self.stats.served += len(batch)
            self.stats.batches += 1

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
        if self.calibration_path:
            planner = getattr(self.index, "planner", None)
            if planner is not None:
                planner.save_calibration(self.calibration_path)
