"""Batched RFANN serving engine: dynamic batching over a request queue,
with a pipelined resolve/dispatch pair and an optional shared result cache.

Requests (query vector + attribute range) are coalesced into batches of up to
``max_batch`` or ``max_wait_ms`` and flow through a **two-stage pipeline**:

* resolver stage — forms the dynamic batch and runs the host-side resolve
  (attribute ranges -> global rank intervals, a ``searchsorted`` over the
  sorted attribute array) on its own thread;
* dispatch stage — executes the resolved batch through the unified search
  substrate (``index.search_ranks``; under ``plan="auto"`` each batch is
  partitioned into fused range-scan and beam-search dispatches by
  selectivity — see ``repro.planner``) and resolves the per-request futures.

The stages overlap: while batch N occupies the device, batch N+1 is already
batched and resolved, so resolve latency is off the critical path under
load.  A bounded hand-off queue provides backpressure (the resolver stalls
rather than racing ahead of the device).

``cache_bytes > 0`` installs a shared ``SearchCache`` at the substrate choke
point: repeat (query, range, k, ef, strategy) rows are served from memory
with no device work.  ``swap_index`` hot-swaps the served index and
invalidates the cache in the same lock — cached rows reference the old
corpus and must never survive a swap.

If ``calibration_path`` is given, the planner's online-calibrated cost model
is restored from it at startup and persisted (atomically: temp file +
rename) at ``close()`` — a restarted server starts from steady-state
routing instead of the prior, and a crash mid-shutdown can never leave a
truncated file behind.  ``index_path`` does the same for the index itself:
``close()`` writes the served index (graph + quantized corpora + streaming
segment state) to the sharded directory format (``repro.index.io``), which
``launch/serve --index-path`` restores at the next startup instead of
rebuilding.

Observability: the engine owns a ``MetricsRegistry`` (``repro.obs``) —
pass one in to share it, or read the default via :meth:`metrics`.  It is
installed on the index (and re-installed on ``swap_index``) so substrate
counters/histograms land in the same snapshot, and the engine itself
records end-to-end latency/batch-size histograms, queue-depth gauges, and
pull-side producers for the cache, the cost model, and its own summary.
``trace_sample_every=N`` attaches a ``QueryTrace`` to every Nth batch
(resolver times the resolve span, the substrate fills plan/dispatch/stitch)
and parks the finished trace on :attr:`last_trace`; ``log_interval_s > 0``
prints a one-line stats summary from the dispatch thread at that cadence.
"""
from __future__ import annotations

import os
import queue
import random
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.obs import MetricsRegistry, QueryTrace, format_stats_line


@dataclass
class EngineStats:
    """Bounded: latencies are a fixed-size uniform reservoir (Vitter's
    Algorithm R), so a long-running server keeps O(1) memory while the
    percentile summary stays an unbiased estimate of the full stream."""
    served: int = 0
    batches: int = 0
    scan_routed: int = 0
    cache_hits: int = 0
    dedup_hits: int = 0     # intra-batch duplicate rows served by one dispatch
    reservoir_size: int = 4096
    latencies_ms: List[float] = field(default_factory=list)
    lat_seen: int = 0
    _rng: random.Random = field(default_factory=lambda: random.Random(0),
                                repr=False)

    def record_latency(self, ms: float) -> None:
        self.lat_seen += 1
        if len(self.latencies_ms) < self.reservoir_size:
            self.latencies_ms.append(ms)
        else:
            j = self._rng.randrange(self.lat_seen)
            if j < self.reservoir_size:
                self.latencies_ms[j] = ms

    def summary(self) -> dict:
        # percentiles from an EMPTY reservoir are reported as 0.0, not a
        # percentile of a fake zero sample — lat_seen disambiguates
        lat = np.asarray(self.latencies_ms) if self.latencies_ms else np.zeros(1)
        return dict(served=self.served, batches=self.batches,
                    mean_batch=self.served / max(self.batches, 1),
                    scan_frac=self.scan_routed / max(self.served, 1),
                    cache_hit_frac=self.cache_hits / max(self.served, 1),
                    dedup_hits=self.dedup_hits,
                    dedup_frac=self.dedup_hits / max(self.served, 1),
                    lat_seen=self.lat_seen,
                    p50_ms=float(np.percentile(lat, 50)),
                    p90_ms=float(np.percentile(lat, 90)),
                    p95_ms=float(np.percentile(lat, 95)),
                    p99_ms=float(np.percentile(lat, 99)))


class RFANNEngine:
    def __init__(self, index, *, k: int = 10, ef: int = 64,
                 max_batch: int = 64, max_wait_ms: float = 2.0,
                 plan: str = "auto", beam_width: int = 1,
                 precision: str = "f32",
                 calibration_path: Optional[str] = None,
                 cache_bytes: int = 0,
                 pipeline_depth: int = 2,
                 metrics: Optional[MetricsRegistry] = None,
                 log_interval_s: float = 0.0,
                 trace_sample_every: int = 0,
                 max_delta: Optional[int] = None,
                 compact_every: Optional[int] = None,
                 index_path: Optional[str] = None,
                 index_save_shards: int = 1,
                 wal_dir: Optional[str] = None,
                 wal_sync: str = "batch"):
        self.index = index
        self.k, self.ef = k, ef
        self.plan = plan
        self.index_path = index_path
        self.index_save_shards = int(index_save_shards)
        self.beam_width = int(beam_width)
        self.precision = str(precision)
        if self.precision != "f32" and hasattr(index, "install_quantized"):
            index.install_quantized(self.precision)   # pay build cost once
        if ((max_delta is not None or compact_every is not None)
                and hasattr(index, "set_compaction_policy")):
            index.set_compaction_policy(max_delta=max_delta,
                                        compact_every=compact_every)
        if wal_dir and hasattr(index, "attach_wal"):
            # append-before-apply durability for every mutation delegated
            # through insert()/delete(); a no-op when the caller already
            # attached (e.g. StreamingRFANN.recover on the same directory)
            index.attach_wal(wal_dir, sync=wal_sync)
            if index_path and hasattr(index, "set_checkpoint_path"):
                # register (and ensure) the checkpoint the WAL replays onto
                # — compactions auto-checkpoint + GC the log behind it
                index.set_checkpoint_path(index_path,
                                          shards=self.index_save_shards)
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1000.0
        self.calibration_path = calibration_path
        self.registry = metrics if metrics is not None else MetricsRegistry()
        self.log_interval = float(log_interval_s)
        self.trace_sample_every = int(trace_sample_every)
        self.last_trace: Optional[QueryTrace] = None
        self._batch_seq = 0
        self._last_log = time.perf_counter()
        if calibration_path and os.path.exists(calibration_path):
            planner = getattr(index, "planner", None)
            if planner is not None:
                try:
                    planner.load_calibration(calibration_path)
                except ValueError as e:     # stale schema / wrong corpus:
                    import warnings         # serve from the prior instead
                    warnings.warn(f"ignoring calibration: {e}")
        self.cache = None
        if cache_bytes:
            from repro.search import SearchCache
            self.cache = SearchCache(max_bytes=cache_bytes)
            if hasattr(index, "install_cache"):
                index.install_cache(self.cache)
        self._q: queue.Queue = queue.Queue()
        # bounded hand-off between the two stages: the resolver pre-resolves
        # at most `pipeline_depth` batches ahead of the device
        self._dq: queue.Queue = queue.Queue(maxsize=max(pipeline_depth, 1))
        self._stop = threading.Event()
        self._index_lock = threading.Lock()
        self.stats = EngineStats()
        # bound the hot-path metric handles once (get-or-create is locked;
        # the loops below only touch per-metric locks)
        reg = self.registry
        self._m_requests = reg.counter("engine_requests_total",
                                       "requests served end to end")
        self._m_batches = reg.counter("engine_batches_total",
                                      "dynamic batches dispatched")
        self._m_e2e = reg.histogram("engine_e2e_ms",
                                    "submit -> result wall time (ms)")
        self._m_batch_size = reg.histogram("engine_batch_size",
                                           "dynamic batch sizes",
                                           lo=1.0, hi=8192.0, growth=1.25)
        self._m_resolve = reg.histogram("engine_resolve_ms",
                                        "host-side resolve wall time (ms)")
        self._m_qdepth = reg.gauge("engine_queue_depth",
                                   "requests waiting to be batched")
        self._m_hdepth = reg.gauge("engine_handoff_depth",
                                   "resolved batches waiting for dispatch")
        if hasattr(index, "install_metrics"):
            index.install_metrics(reg)
        if self.cache is not None:
            reg.register_producer("cache", self.cache.snapshot)
        reg.register_producer("cost_model", self._cost_snapshot)
        reg.register_producer("engine", self.stats.summary)
        self._resolver = threading.Thread(target=self._resolve_loop,
                                          daemon=True)
        self._dispatcher = threading.Thread(target=self._dispatch_loop,
                                            daemon=True)
        self._resolver.start()
        self._dispatcher.start()

    # ------------------------------------------------------------------
    def _cost_snapshot(self) -> dict:
        """Pull-side cost-model producer — reads the *live* index so a
        ``swap_index`` transparently switches whose calibration is exported."""
        planner = getattr(self.index, "planner", None)
        return planner.cost.snapshot() if planner is not None else {}

    def metrics(self) -> dict:
        """One JSON-able snapshot: every counter/gauge/histogram (with
        p50/p90/p99) plus the pull-side sections (``engine``, ``cache``,
        ``cost_model``).  Prometheus text comes from
        ``repro.obs.to_prometheus(engine.registry)``."""
        return self.registry.snapshot()

    # ------------------------------------------------------------------
    def submit(self, query: np.ndarray, attr_range: Tuple[float, float]) -> Future:
        fut: Future = Future()
        self._q.put((np.asarray(query, np.float32),
                     np.asarray(attr_range, np.float32), time.perf_counter(), fut))
        return fut

    def swap_index(self, new_index, *, segment=None) -> None:
        """Hot-swap the served index.  The result cache is detached from the
        old index, invalidated, and installed on the new one — cached rows
        hold corpus ids of the *old* index and must never be served
        afterwards.  A dispatch already in flight on the old index is fenced
        by the cache's epoch (captured at its hit/miss split, checked under
        the store lock), so its late stores are dropped rather than
        repopulating the cache with old-corpus rows.

        ``segment=<ns>`` scopes the invalidation to one cache namespace
        (``SearchCache.invalidate_segment``): a streaming compaction swaps
        only the base segment, so only base-keyed rows go cold — any other
        namespace sharing the cache keeps its rows."""
        with self._index_lock:
            old = self.index
            if self.cache is not None:
                if old is not new_index and hasattr(old, "install_cache"):
                    old.install_cache(None)     # old index: cache off
                if segment is None:
                    self.cache.invalidate()
                else:
                    self.cache.invalidate_segment(segment)
            self.index = new_index
            if self.cache is not None and hasattr(new_index, "install_cache"):
                new_index.install_cache(self.cache)
            if old is not new_index:
                if hasattr(old, "install_metrics"):
                    old.install_metrics(None)
                if hasattr(new_index, "install_metrics"):
                    new_index.install_metrics(self.registry)

    # ------------------------------------------------- streaming delegation
    def insert(self, vector: np.ndarray, attr: float, ext_id=None) -> int:
        """Delegate one insert to a streaming index (``StreamingRFANN``).
        The index publishes a new snapshot atomically, so in-flight batches
        keep their captured view; no cache action is needed (delta results
        are never cached)."""
        with self._index_lock:
            index = self.index
        return index.insert(vector, attr, ext_id)

    def delete(self, ext_id: int) -> None:
        """Delegate one delete to a streaming index.  The index owns the
        base-segment cache invalidation (per-segment epoch bump)."""
        with self._index_lock:
            index = self.index
        index.delete(ext_id)

    # ------------------------------------------------------- stage 1: batch+resolve
    def _resolve_loop(self):
        while not self._stop.is_set():
            try:
                first = self._q.get(timeout=0.05)
            except queue.Empty:
                continue
            batch = [first]
            deadline = time.perf_counter() + self.max_wait
            while len(batch) < self.max_batch:
                left = deadline - time.perf_counter()
                if left <= 0:
                    break
                try:
                    batch.append(self._q.get(timeout=left))
                except queue.Empty:
                    break
            qv = np.stack([b[0] for b in batch])
            rg = np.stack([b[1] for b in batch])
            self._m_qdepth.set(self._q.qsize())
            with self._index_lock:          # only the reference needs the
                index = self.index          # lock — never resolve under it,
            # the dispatcher takes it per batch and would stall behind us
            self._batch_seq += 1
            trace = (QueryTrace()
                     if self.trace_sample_every
                     and self._batch_seq % self.trace_sample_every == 0
                     else None)
            t_res = time.perf_counter()
            lo, hi = (index.rank_range(rg)
                      if hasattr(index, "rank_range") else (None, None))
            resolve_ms = (time.perf_counter() - t_res) * 1e3
            self._m_resolve.observe(resolve_ms)
            if trace is not None:
                trace.add_span("resolve", wall_ms=resolve_ms, q=len(batch),
                               stage="engine_resolver")
            item = (batch, qv, rg, lo, hi, index, trace)
            enqueued = False
            while not self._stop.is_set():  # bounded queue: backpressure
                try:
                    self._dq.put(item, timeout=0.05)
                    enqueued = True
                    break
                except queue.Full:
                    continue
            if not enqueued:                # shutdown raced the hand-off:
                self._fail_batch(batch)     # never leave futures hanging

    # ------------------------------------------------------- stage 2: dispatch
    def _dispatch_loop(self):
        while not self._stop.is_set() or not self._dq.empty():
            try:
                batch, qv, rg, lo, hi, r_index, trace = \
                    self._dq.get(timeout=0.05)
            except queue.Empty:
                continue
            self._m_hdepth.set(self._dq.qsize())
            with self._index_lock:
                index = self.index
            # beam_width=1 is omitted so indexes predating the batched-
            # expansion API (baselines, external wrappers) keep working
            kw = dict(k=self.k, ef=self.ef, plan=self.plan)
            if self.beam_width != 1:
                kw["beam_width"] = self.beam_width
            if self.precision != "f32":     # same omission back-compat rule
                kw["precision"] = self.precision
            if trace is not None:
                kw["trace"] = trace
            try:
                res = self._run_search(index, qv, rg, lo, hi, r_index, kw)
            except TypeError:
                if "trace" not in kw:       # genuine signature error
                    raise
                kw.pop("trace")             # index predates the trace API
                res = self._run_search(index, qv, rg, lo, hi, r_index, kw)
            if not hasattr(res, "row"):     # tuple-returning index
                from repro.search import SearchResult
                res = SearchResult(np.asarray(res[0]), np.asarray(res[1]), {})
            if "strategy" in res.stats:
                from repro.planner import SCAN
                self.stats.scan_routed += int(
                    (np.asarray(res.stats["strategy"]) == SCAN).sum())
            self.stats.cache_hits += int(res.stats.get("cache_hits", 0))
            self.stats.dedup_hits += int(res.stats.get("batch_dedup", 0))
            now = time.perf_counter()
            lats = [(now - t0) * 1e3 for (_, _, t0, _) in batch]
            # account BEFORE resolving futures: a client that holds its
            # result must see the stats/metrics that include its request
            for ms in lats:
                self.stats.record_latency(ms)
            self.stats.served += len(batch)
            self.stats.batches += 1
            self._m_e2e.observe_many(lats)
            self._m_batch_size.observe(len(batch))
            self._m_requests.inc(len(batch))
            self._m_batches.inc()
            if trace is not None:
                self.last_trace = trace
            for i, (_, _, _, fut) in enumerate(batch):
                fut.set_result(res.row(i))
            if self.log_interval and now - self._last_log >= self.log_interval:
                self._last_log = now
                print(format_stats_line(self.metrics()), flush=True)

    def _run_search(self, index, qv, rg, lo, hi, r_index, kw):
        if index is not r_index or lo is None:
            # swapped between the stages (or no rank-space entry point):
            # re-resolve against the live index
            return index.search(qv, rg, **kw)
        return index.search_ranks(qv, lo, hi, **kw)

    @staticmethod
    def _fail_batch(batch) -> None:
        for _, _, _, fut in batch:
            if not fut.done():
                fut.set_exception(RuntimeError("engine closed before "
                                               "this request was served"))

    def close(self):
        self._stop.set()
        self._resolver.join(timeout=2.0)
        self._dispatcher.join(timeout=2.0)
        # fail anything still queued (a blocked ``Future.result()`` with no
        # timeout must never hang on a closed engine)
        while True:
            try:
                batch, *_ = self._dq.get_nowait()
            except queue.Empty:
                break
            self._fail_batch(batch)
        while True:
            try:
                q_, rg_, t0_, fut = self._q.get_nowait()
            except queue.Empty:
                break
            self._fail_batch([(q_, rg_, t0_, fut)])
        if self.calibration_path:
            planner = getattr(self.index, "planner", None)
            if planner is not None:
                planner.save_calibration(self.calibration_path)
        if self.index_path:
            # persist the served index (sharded directory format) so the
            # next startup restores in seconds instead of rebuilding —
            # save_index snapshots under the index lock, so a streaming
            # index racing mutations/compaction saves a consistent view.
            # A WAL-attached streaming index goes through checkpoint()
            # instead, which also writes the barrier record and GCs log
            # segments the snapshot covers.
            if hasattr(self.index, "checkpoint"):
                self.index.checkpoint(self.index_path,
                                      shards=self.index_save_shards)
            else:
                from repro.index import io
                io.save_index(self.index, self.index_path,
                              shards=self.index_save_shards)
