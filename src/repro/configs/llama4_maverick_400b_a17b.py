"""llama4-maverick-400b-a17b — MoE, early fusion. [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    n_experts=128, moe_top_k=1, moe_every=1,
    rope_theta=500000.0, opt_dtype="bfloat16", remat="full", remat_group=4,
    source="hf:meta-llama/Llama-4-Scout-17B-16E (assignment card)",
)
