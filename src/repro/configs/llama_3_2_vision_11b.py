"""llama-3.2-vision-11b — decoder with image cross-attn every 5th layer; vision frontend stubbed.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

``input_specs()`` delivers precomputed patch embeddings (1600 tokens, d_model).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=128256,
    cross_attn_every=5, n_frontend_tokens=1600, frontend_dim=4096,
    rope_theta=500000.0, remat="full",
    source="hf:meta-llama/Llama-3.2-11B-Vision (assignment card)",
)
