"""seamless-m4t-large-v2 — enc-dec multimodal backbone; audio frontend stubbed. [arXiv:2308.11596; hf]

Backbone only: 24 encoder + 24 decoder layers; ``input_specs()`` delivers precomputed
audio frame embeddings (seq/4 frames, d_model) in place of the w2v-BERT frontend.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=256206,
    mlp_act="gelu", norm="layernorm", frontend_dim=1024,
    rope_theta=10000.0, remat="dots",
    source="arXiv:2308.11596",
)
