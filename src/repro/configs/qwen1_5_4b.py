"""qwen1.5-4b — dense, QKV bias, near-MHA (kv=20). [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20,
    d_ff=6912, vocab_size=151936,
    qkv_bias=True, rope_theta=1000000.0, remat="full", remat_group=2,
    source="hf:Qwen/Qwen1.5-0.5B (assignment card)",
)
