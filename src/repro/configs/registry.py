"""Architecture registry: ``get_config("<arch-id>")`` / ``list_archs()``."""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig, SHAPES, SHAPE_ORDER, ShapeConfig, reduce_for_smoke

_MODULES = {
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "mixtral-8x7b": "mixtral_8x7b",
    "mamba2-780m": "mamba2_780m",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "starcoder2-15b": "starcoder2_15b",
    "llama3-8b": "llama3_8b",
    "qwen1.5-4b": "qwen1_5_4b",
    "qwen2.5-14b": "qwen2_5_14b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
}


def list_archs() -> list[str]:
    return list(_MODULES)


def get_config(arch: str) -> ArchConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ArchConfig:
    return reduce_for_smoke(get_config(arch))


def cells(include_inapplicable: bool = False):
    """All (arch, shape) dry-run cells; skipped cells carry a reason."""
    out = []
    for arch in list_archs():
        cfg = get_config(arch)
        for sname in SHAPE_ORDER:
            shape = SHAPES[sname]
            if shape.applicable(cfg):
                out.append((arch, sname, None))
            elif include_inapplicable:
                out.append((arch, sname, "long_500k requires sub-quadratic attention "
                                         f"({cfg.family} is full-attention)"))
    return out
