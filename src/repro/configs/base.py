"""Architecture / shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; input shapes are
``ShapeConfig`` entries in ``SHAPES``.  ``reduce_for_smoke`` produces the
CPU-runnable reduced config of the same family used by the smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    moe_top_k: int = 0
    moe_every: int = 1              # apply MoE on layers where (layer % moe_every == moe_offset)
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    attn_every: int = 0             # hybrid: one attention layer per `attn_every` layers
    # --- attention details ---
    qkv_bias: bool = False
    rope_theta: float = 500000.0
    sliding_window: int = 0         # 0 = full causal
    # --- enc-dec ---
    enc_layers: int = 0             # >0 -> encoder-decoder model
    # --- vlm ---
    cross_attn_every: int = 0       # insert image cross-attn every k-th layer
    n_frontend_tokens: int = 0      # stub frontend: #precomputed frame/patch embeddings
    frontend_dim: int = 0           # embedding dim delivered by the stub frontend
    # --- misc ---
    mlp_act: str = "swiglu"         # swiglu | gelu
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    opt_dtype: str = "float32"      # AdamW m/v dtype (bf16 for the ~400B archs)
    remat: str = "dots"             # none | dots | full
    remat_group: int = 1            # layers per remat/scan group (carry /= this)
    source: str = ""                # provenance note

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid families)."""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:       # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    def n_params(self) -> int:
        """Total parameter count (approximate, matches the spec builder closely)."""
        from repro.models.params import count_params
        return count_params(self)

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k of n_experts FFN branches)."""
        from repro.models.params import count_params
        return count_params(self, active_only=True)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                       # train | prefill | decode
    seq_len: int
    global_batch: int
    long_context: bool = False      # long_500k: seq-sharded cache, needs sub-quadratic

    def applicable(self, cfg: ArchConfig) -> bool:
        if self.long_context:
            return cfg.sub_quadratic
        return True


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1, long_context=True),
}

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


# ----------------------------------------------------------------------
def reduce_for_smoke(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config: small widths, few experts, tiny vocab."""
    upd = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
        rope_theta=10000.0,
        remat="none",
        opt_dtype="float32",
    )
    if cfg.n_experts:
        # capacity_factor = n_experts ⇒ no token ever dropped (exactness tests)
        upd.update(n_experts=4, moe_top_k=min(cfg.moe_top_k, 2), capacity_factor=4.0)
    if cfg.ssm_state:
        upd.update(ssm_state=16, ssm_head_dim=16)
    if cfg.attn_every:
        # keep the interleave ratio visible but small: 1 attn per 4 layers
        upd.update(attn_every=4, n_layers=8)
    if cfg.enc_layers:
        upd.update(enc_layers=2, n_layers=2)
    if cfg.cross_attn_every:
        upd.update(cross_attn_every=2, n_layers=4, n_frontend_tokens=8, frontend_dim=32)
    if cfg.n_frontend_tokens and not cfg.cross_attn_every:
        upd.update(n_frontend_tokens=8, frontend_dim=32)
    if cfg.sliding_window:
        upd.update(sliding_window=64)
    return replace(cfg, **upd)
