"""jamba-1.5-large-398b — Mamba+attention 1:7 interleave, MoE 16e top-2. [arXiv:2403.19887; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab_size=65536,
    n_experts=16, moe_top_k=2, moe_every=2,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
    attn_every=8, rope_theta=1000000.0,
    opt_dtype="bfloat16", remat="full",
    source="arXiv:2403.19887",
)
