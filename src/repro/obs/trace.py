"""Per-query trace records threaded through the whole query path.

A ``QueryTrace`` rides as the optional ``trace`` field of a
``SearchRequest`` and comes back attached to the ``SearchResult``.  Every
stage that touches the request appends a **span** — a named, wall-timed
segment with free-form attributes:

    resolve   attribute range -> rank interval (interval widths, Q)
    plan      routing decision (strategy vector, predicted costs, beam_width)
    dispatch  device-work enqueue (cache hit/miss/dedup, pad waste,
              kernel vs jnp path, per-shard clip widths on the mesh path)
    stitch    block on device outputs + request-order assembly + id remap

Span attributes hold numpy arrays where the quantity is per-query (e.g.
the strategy vector) and scalars otherwise; ``to_dict()`` converts
everything to plain JSON-able Python for logging.

Tracing is strictly **opt-in per request**: the hot path pays one
``is None`` check when no trace is attached, which is what keeps the
tracing-disabled QPS unchanged (acceptance criterion on
``make bench-substrate``).

A trace is owned by one request as it moves resolver -> dispatcher ->
finalize; stages run sequentially even when they hop threads, so spans are
a plain list (appends are atomic under the GIL).
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

SPAN_NAMES = ("resolve", "plan", "dispatch", "stitch")


@dataclass
class Span:
    name: str
    t0: float
    t1: float = 0.0
    attrs: Dict[str, Any] = field(default_factory=dict)

    @property
    def wall_ms(self) -> float:
        return max(self.t1 - self.t0, 0.0) * 1e3

    def to_dict(self) -> dict:
        return dict(name=self.name, wall_ms=round(self.wall_ms, 4),
                    attrs={k: _plain(v) for k, v in self.attrs.items()})


class QueryTrace:
    """One request's span list plus request-level metadata."""

    def __init__(self, request_id: Optional[str] = None, **meta):
        self.request_id = request_id
        self.meta: Dict[str, Any] = dict(meta)
        self.spans: List[Span] = []

    # -------------------------------------------------------------- record
    @contextmanager
    def span(self, name: str, **attrs):
        """Time a block as one span; mutate ``sp.attrs`` inside the block to
        attach results discovered while it runs."""
        sp = Span(name, time.perf_counter(), attrs=dict(attrs))
        try:
            yield sp
        finally:
            sp.t1 = time.perf_counter()
            self.spans.append(sp)

    def add_span(self, name: str, wall_ms: float = 0.0, **attrs) -> Span:
        """Append a pre-measured (or instantaneous) span."""
        now = time.perf_counter()
        sp = Span(name, now - wall_ms / 1e3, now, dict(attrs))
        self.spans.append(sp)
        return sp

    # ---------------------------------------------------------------- read
    def get(self, name: str) -> Optional[Span]:
        """Last span with this name (stages may repeat, e.g. one dispatch
        span per shard on the distributed local path)."""
        for sp in reversed(self.spans):
            if sp.name == name:
                return sp
        return None

    def all(self, name: str) -> List[Span]:
        return [sp for sp in self.spans if sp.name == name]

    def names(self) -> List[str]:
        return [sp.name for sp in self.spans]

    def wall_ms(self, name: str) -> float:
        return sum(sp.wall_ms for sp in self.spans if sp.name == name)

    def to_dict(self) -> dict:
        return dict(request_id=self.request_id,
                    meta={k: _plain(v) for k, v in self.meta.items()},
                    spans=[sp.to_dict() for sp in self.spans])


@contextmanager
def maybe_span(trace: Optional[QueryTrace], name: str, **attrs):
    """``trace.span`` when a trace rides the request, else a no-op whose
    yielded object swallows attr writes — call sites stay branch-free."""
    if trace is None:
        yield _NULL_SPAN
    else:
        with trace.span(name, **attrs) as sp:
            yield sp


class _NullSpan:
    __slots__ = ("attrs",)

    def __init__(self):
        self.attrs = _NullAttrs()


class _NullAttrs(dict):
    def __setitem__(self, k, v):    # drop writes: tracing is off
        pass

    def update(self, *a, **kw):
        pass


_NULL_SPAN = _NullSpan()


def _plain(v):
    """numpy -> JSON-able Python (arrays to lists, scalars unboxed)."""
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, (np.bool_,)):
        return bool(v)
    return v
