"""``jax.profiler`` integration: host-span annotations + trace capture.

``annotate(name)`` wraps a host-side region in a
``jax.profiler.TraceAnnotation`` so device profiles (captured with
``device_trace`` / ``make profile``) line up with the serve path's own
span names — the kernel dispatch sites in ``repro.search.substrate`` use
``rnsg.scan_dispatch`` / ``rnsg.beam_dispatch`` / ``rnsg.gather`` style
names.  When no profiler session is active a ``TraceAnnotation`` is a few
nanoseconds of overhead, so the annotations stay on unconditionally; if
the running jax build lacks the profiler entirely, everything degrades to
no-ops instead of failing.
"""
from __future__ import annotations

from contextlib import contextmanager, nullcontext

try:                                    # profiler present in jax >= 0.3
    from jax.profiler import TraceAnnotation as _TraceAnnotation
except Exception:                       # pragma: no cover - stub builds
    _TraceAnnotation = None


def annotate(name: str):
    """Context manager marking a host region in the profiler timeline."""
    if _TraceAnnotation is None:        # pragma: no cover - stub builds
        return nullcontext()
    return _TraceAnnotation(name)


@contextmanager
def device_trace(log_dir: str):
    """Capture a ``jax.profiler`` trace (TensorBoard format) around a block.

    No-op (with a warning) when the profiler is unavailable, so callers —
    ``make profile`` / ``tools/profile_capture.py`` — never hard-fail in a
    stripped container."""
    try:
        import jax.profiler as _prof
        _prof.start_trace(log_dir)
        started = True
    except Exception as e:              # pragma: no cover - stub builds
        import warnings
        warnings.warn(f"jax profiler unavailable ({e}); capturing nothing")
        started = False
    try:
        yield
    finally:
        if started:
            import jax.profiler as _prof
            _prof.stop_trace()
