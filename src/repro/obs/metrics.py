"""Lock-cheap metrics registry: counters, gauges, log-scale histograms.

The registry is the process-wide measurement substrate for the serve path.
It is built to be touched from the engine's resolver/dispatcher threads and
from substrate dispatch without contention:

* every metric owns its **own** small lock (no registry-wide lock on the
  hot path — the registry lock is taken only on first get-or-create);
* critical sections are a handful of arithmetic ops;
* histograms accept **batched** observations (``observe_many``) so one
  engine batch costs one lock acquisition, not one per request.

Histograms use **fixed log-scale buckets**: geometric bucket edges between
``lo`` and ``hi`` (values outside clamp into the first / overflow bucket).
Percentiles are extracted by walking the cumulative counts and
geometrically interpolating inside the landing bucket, so ``percentile(p)``
is exact up to one bucket's relative width (``growth - 1``, ~25% by
default) — tight enough for p50/p90/p99 latency reporting at O(1) memory,
and validated against the ``np.percentile`` oracle in ``tests/test_obs.py``.

Pull-style metrics (cache occupancy, cost-model EMAs, …) register a
**producer** callback: a zero-argument callable returning a flat dict of
scalars, invoked only at snapshot/export time — zero hot-path cost.
"""
from __future__ import annotations

import math
import threading
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np


class Counter:
    """Monotonic counter.  ``inc`` takes the metric's own lock so concurrent
    writers (resolver/dispatcher threads, test hammers) never lose updates."""
    __slots__ = ("name", "help", "_v", "_lock")

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._v = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._v += n

    @property
    def value(self) -> int:
        return self._v


class Gauge:
    """Last-write-wins scalar (queue depths, occupancy, EMAs)."""
    __slots__ = ("name", "help", "_v", "_lock")

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._v = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._v = float(v)

    def add(self, v: float) -> None:
        with self._lock:
            self._v += float(v)

    @property
    def value(self) -> float:
        return self._v


class Histogram:
    """Fixed-bucket log-scale histogram with percentile extraction.

    Bucket upper edges grow geometrically from ``lo`` by ``growth`` until
    ``hi``; one overflow bucket catches everything above.  Memory is O(#
    buckets) forever — a long-running server never grows it.  ``sum`` /
    ``min`` / ``max`` are tracked exactly, so the mean is exact and only
    the percentiles carry the bucket-resolution error."""
    __slots__ = ("name", "help", "edges", "_counts", "_sum", "_min", "_max",
                 "_count", "_lock")

    def __init__(self, name: str, help: str = "", *, lo: float = 1e-3,
                 hi: float = 6e4, growth: float = 1.25):
        if not (lo > 0 and hi > lo and growth > 1):
            raise ValueError("need lo > 0, hi > lo, growth > 1")
        self.name, self.help = name, help
        n = int(math.ceil(math.log(hi / lo) / math.log(growth)))
        self.edges = lo * np.power(growth, np.arange(n + 1))  # upper edges
        self._counts = np.zeros(n + 2, np.int64)              # +under/overflow
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._count = 0
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        self.observe_many((v,))

    def observe_many(self, values: Iterable[float]) -> None:
        vals = np.asarray(list(values) if not isinstance(values, np.ndarray)
                          else values, np.float64).ravel()
        if vals.size == 0:
            return
        # digitize(right=True) == first edge >= v: bucket index by upper edge
        idx = np.digitize(vals, self.edges, right=True)
        with self._lock:
            np.add.at(self._counts, idx, 1)
            self._sum += float(vals.sum())
            self._min = min(self._min, float(vals.min()))
            self._max = max(self._max, float(vals.max()))
            self._count += int(vals.size)

    # ----------------------------------------------------------- read side
    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def percentile(self, p: float) -> float:
        """p in [0, 100].  Exact in rank; the returned value geometrically
        interpolates inside the landing bucket (error <= growth - 1
        relative), clamped to the exact observed [min, max]."""
        with self._lock:
            total = self._count
            if total == 0:
                return 0.0
            counts = self._counts.copy()
            vmin, vmax = self._min, self._max
        rank = max(1, int(math.ceil(p / 100.0 * total)))
        cum = np.cumsum(counts)
        b = int(np.digitize(rank, cum, right=True))  # first cum >= rank
        prev = int(cum[b - 1]) if b else 0
        frac = (rank - prev) / max(int(counts[b]), 1)
        if b == 0:                           # below the first edge
            val = self.edges[0] * frac
        elif b > len(self.edges) - 1:        # overflow bucket
            val = vmax
        else:
            lo_e, hi_e = self.edges[b - 1], self.edges[b]
            val = lo_e * (hi_e / lo_e) ** frac   # geometric interpolation
        return float(min(max(val, vmin), vmax))

    def percentiles(self, ps: Sequence[float] = (50, 90, 99)) -> Dict[str, float]:
        return {f"p{g:g}": self.percentile(g) for g in ps}

    def bucket_counts(self) -> Tuple[np.ndarray, np.ndarray]:
        """(upper_edges incl. +inf, cumulative counts) — the Prometheus
        exposition shape.  Bucket ``i`` holds ``v <= edges[i]`` (digitize
        index 0 is already the first ``le`` bucket), the trailing +inf
        bucket the overflow, so the last cumulative count is the total."""
        with self._lock:
            counts = self._counts.copy()
        cum = np.cumsum(counts)
        edges = np.concatenate([self.edges, [np.inf]])
        return edges, cum

    def snapshot(self) -> dict:
        with self._lock:
            count, s = self._count, self._sum
            vmin, vmax = self._min, self._max
        out = dict(count=count, sum=s,
                   mean=s / count if count else 0.0,
                   min=vmin if count else 0.0,
                   max=vmax if count else 0.0)
        out.update(self.percentiles())
        return out


class MetricsRegistry:
    """Named get-or-create home for every metric plus pull-side producers.

    ``counter``/``gauge``/``histogram`` return the existing instance when the
    name is already registered (type-checked), so call sites never need to
    coordinate creation.  ``snapshot()`` returns one JSON-able dict;
    Prometheus text exposition lives in ``repro.obs.export``."""

    def __init__(self):
        self._m: Dict[str, object] = {}
        self._producers: Dict[str, Callable[[], dict]] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------- get-or-create
    def _get(self, name: str, cls, **kw):
        m = self._m.get(name)
        if m is not None:
            if not isinstance(m, cls):
                raise TypeError(f"metric {name!r} is {type(m).__name__}, "
                                f"not {cls.__name__}")
            return m
        with self._lock:
            m = self._m.get(name)
            if m is None:
                m = cls(name, **kw)
                self._m[name] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} is {type(m).__name__}, "
                                f"not {cls.__name__}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, help=help)

    def histogram(self, name: str, help: str = "", **kw) -> Histogram:
        return self._get(name, Histogram, help=help, **kw)

    def register_producer(self, section: str, fn: Callable[[], dict]) -> None:
        """Pull-side metrics: ``fn`` runs only at snapshot/export time and
        returns a flat-ish dict (nested dicts are flattened with ``_``)."""
        with self._lock:
            self._producers[section] = fn

    # ------------------------------------------------------------ read side
    def metrics(self) -> List[object]:
        with self._lock:
            return list(self._m.values())

    def producer_values(self) -> Dict[str, Dict[str, float]]:
        """{section: {flat_key: numeric_value}} — non-numeric values are
        dropped (export formats are numbers-only)."""
        with self._lock:
            producers = dict(self._producers)
        out: Dict[str, Dict[str, float]] = {}
        for section, fn in producers.items():
            try:
                raw = fn()
            except Exception:           # a dead producer never kills export
                continue
            out[section] = _flatten_numeric(raw)
        return out

    def snapshot(self) -> dict:
        counters, gauges, hists = {}, {}, {}
        for m in self.metrics():
            if isinstance(m, Counter):
                counters[m.name] = m.value
            elif isinstance(m, Gauge):
                gauges[m.name] = m.value
            elif isinstance(m, Histogram):
                hists[m.name] = m.snapshot()
        out = dict(counters=counters, gauges=gauges, histograms=hists)
        for section, vals in self.producer_values().items():
            out[section] = vals
        return out


def _flatten_numeric(d: dict, prefix: str = "") -> Dict[str, float]:
    out: Dict[str, float] = {}
    for k, v in d.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(_flatten_numeric(v, f"{key}_"))
        elif isinstance(v, bool):
            out[key] = float(v)
        elif isinstance(v, (int, float, np.integer, np.floating)) \
                and v is not None and math.isfinite(float(v)):
            out[key] = float(v)
    return out


#: process-wide default registry — library call sites that are not handed an
#: explicit registry (``RFANNEngine`` creates its own) may share this one.
DEFAULT_REGISTRY: Optional[MetricsRegistry] = None


def default_registry() -> MetricsRegistry:
    global DEFAULT_REGISTRY
    if DEFAULT_REGISTRY is None:
        DEFAULT_REGISTRY = MetricsRegistry()
    return DEFAULT_REGISTRY
