"""Exporters: Prometheus text format, JSON snapshots, one-line stats logs.

Three consumers of the same ``MetricsRegistry``:

* ``engine.metrics()``      — JSON snapshot (``MetricsRegistry.snapshot``
                              plus engine-level sections);
* ``to_prometheus``         — Prometheus text exposition format 0.0.4
                              (counters, gauges, full cumulative-bucket
                              histograms, producer sections as gauges),
                              written by ``launch/serve --metrics-path``;
* ``format_stats_line``     — the periodic one-line operator log the engine
                              emits under ``log_interval_s``.

``parse_prometheus`` is the matching reader used by the CI smoke step and
the tests to assert the dump round-trips.
"""
from __future__ import annotations

import math
import re
from typing import Dict, Tuple

from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry

_NAME_OK = re.compile(r"[^a-zA-Z0-9_]")
_LINE = re.compile(r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{[^}]*\})?\s+(\S+)$")

#: metric families every serve-path export must contain (asserted by the
#: CI obs-smoke step and tests/test_obs.py)
CORE_FAMILIES = ("rnsg_engine_requests_total", "rnsg_engine_e2e_ms",
                 "rnsg_engine_batch_size", "rnsg_queries_total")


def _san(name: str, prefix: str = "rnsg") -> str:
    return f"{prefix}_{_NAME_OK.sub('_', name)}"


def _fmt(v: float) -> str:
    if isinstance(v, float) and math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(int(v)) if float(v).is_integer() else repr(float(v))


def to_prometheus(reg: MetricsRegistry, prefix: str = "rnsg") -> str:
    """Text exposition format: ``# HELP`` / ``# TYPE`` headers, histograms
    as cumulative ``_bucket{le=...}`` series plus ``_sum`` / ``_count``.
    Histogram values are milliseconds (the ``_ms`` suffix carries the unit,
    diverging from Prometheus' base-seconds convention on purpose — every
    number in this repo's benches and logs is ms)."""
    lines = []
    for m in reg.metrics():
        name = _san(m.name, prefix)
        if isinstance(m, Counter):
            lines += [f"# HELP {name} {m.help}", f"# TYPE {name} counter",
                      f"{name} {_fmt(m.value)}"]
        elif isinstance(m, Gauge):
            lines += [f"# HELP {name} {m.help}", f"# TYPE {name} gauge",
                      f"{name} {_fmt(m.value)}"]
        elif isinstance(m, Histogram):
            lines += [f"# HELP {name} {m.help}", f"# TYPE {name} histogram"]
            edges, cum = m.bucket_counts()
            for e, c in zip(edges, cum):
                lines.append(f'{name}_bucket{{le="{_fmt(float(e))}"}} '
                             f"{_fmt(int(c))}")
            lines.append(f"{name}_sum {_fmt(m.sum)}")
            lines.append(f"{name}_count {_fmt(m.count)}")
    for section, vals in sorted(reg.producer_values().items()):
        for key, v in sorted(vals.items()):
            name = _san(f"{section}_{key}", prefix)
            lines += [f"# TYPE {name} gauge", f"{name} {_fmt(v)}"]
    return "\n".join(lines) + "\n"


def write_prometheus(reg: MetricsRegistry, path: str,
                     prefix: str = "rnsg") -> None:
    with open(path, "w") as f:
        f.write(to_prometheus(reg, prefix))


def parse_prometheus(text: str) -> Dict[Tuple[str, str], float]:
    """{(name, labels): value} for every sample line; raises ``ValueError``
    on a malformed non-comment line — this is the round-trip check the CI
    smoke step runs against the ``--metrics-path`` dump."""
    out: Dict[Tuple[str, str], float] = {}
    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip() or line.startswith("#"):
            continue
        m = _LINE.match(line.strip())
        if m is None:
            raise ValueError(f"malformed prometheus line {ln}: {line!r}")
        name, labels, val = m.group(1), m.group(2) or "", m.group(3)
        out[(name, labels)] = float(val.replace("+Inf", "inf"))
    return out


def format_stats_line(snap: dict) -> str:
    """One-line operator summary from an ``engine.metrics()`` snapshot —
    what the engine logs every ``log_interval_s`` seconds."""
    eng = snap.get("engine", {})
    hists = snap.get("histograms", {})
    lat = hists.get("engine_e2e_ms", {})
    cache = snap.get("cache", {})
    parts = [f"served={int(eng.get('served', 0))}",
             f"batches={int(eng.get('batches', 0))}",
             f"mean_batch={eng.get('mean_batch', 0.0):.1f}",
             f"p50={lat.get('p50', 0.0):.2f}ms",
             f"p90={lat.get('p90', 0.0):.2f}ms",
             f"p99={lat.get('p99', 0.0):.2f}ms",
             f"scan_frac={eng.get('scan_frac', 0.0):.2f}",
             f"cache_hit_frac={eng.get('cache_hit_frac', 0.0):.2f}"]
    if cache:
        parts.append(f"cache_bytes={int(cache.get('bytes', 0))}")
    return "[obs] " + " ".join(parts)
