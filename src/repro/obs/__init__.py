"""End-to-end query observability for the serve path.

Three pieces, wired into every layer of the query path (see
docs/observability.md):

* ``repro.obs.metrics``  — lock-cheap ``MetricsRegistry`` (counters,
  gauges, fixed-bucket log-scale latency histograms with p50/p90/p99
  extraction) usable from the engine's resolver/dispatcher threads;
* ``repro.obs.trace``    — opt-in per-query ``QueryTrace`` records threaded
  through ``SearchRequest``/``SearchResult`` with resolve / plan /
  dispatch / stitch spans;
* ``repro.obs.export``   — JSON snapshot, Prometheus text format, and the
  periodic one-line stats log; ``repro.obs.profiler`` adds
  ``jax.profiler.TraceAnnotation`` spans around kernel dispatch so device
  profiles line up with host spans.
"""
from repro.obs.export import (CORE_FAMILIES, format_stats_line,
                              parse_prometheus, to_prometheus,
                              write_prometheus)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               default_registry)
from repro.obs.profiler import annotate, device_trace
from repro.obs.trace import SPAN_NAMES, QueryTrace, Span, maybe_span

__all__ = ["MetricsRegistry", "Counter", "Gauge", "Histogram",
           "default_registry",
           "QueryTrace", "Span", "maybe_span", "SPAN_NAMES",
           "to_prometheus", "write_prometheus", "parse_prometheus",
           "format_stats_line", "CORE_FAMILIES",
           "annotate", "device_trace"]
