"""Train-step factory: value_and_grad → clip → AdamW, with optional
microbatch gradient accumulation and optional cross-pod gradient compression."""
from __future__ import annotations

from functools import partial
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.training.optim import (adamw_init, adamw_update, clip_by_global_norm,
                                  cosine_schedule)


def init_train_state(model, rng) -> Dict:
    params = model.init(rng)
    return {"params": params, "opt": adamw_init(params, model.cfg.opt_dtype)}


def train_state_shapes(model) -> Dict:
    """Abstract train state for the dry-run (no allocation)."""
    pshapes = model.param_shapes()
    dt = jnp.dtype(model.cfg.opt_dtype)
    mv = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dt), pshapes)
    return {"params": pshapes,
            "opt": {"m": mv, "v": jax.tree.map(lambda s: s, mv),
                    "step": jax.ShapeDtypeStruct((), jnp.int32)}}


def build_train_step(model, *, lr_schedule: Optional[Callable] = None,
                     max_grad_norm: float = 1.0, micro_batches: int = 1,
                     grad_transform: Optional[Callable] = None):
    """Returns train_step(state, batch) -> (state, metrics).

    micro_batches > 1: batch leaves must carry a leading (micro, ...) dim;
    gradients are accumulated with a lax.scan before the optimizer update.
    grad_transform: optional hook (e.g. cross-pod int8 compression)."""
    lr_schedule = lr_schedule or cosine_schedule

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(state, batch):
        params, opt = state["params"], state["opt"]
        if micro_batches > 1:
            def acc_body(carry, mb):
                g_acc, l_acc = carry
                loss, _, grads = grads_of(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / micro_batches,
                    g_acc, grads)
                return (g_acc, l_acc + loss / micro_batches), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(acc_body, (g0, jnp.zeros(())), batch)
            metrics = {"loss": loss, "aux": jnp.zeros(())}
        else:
            loss, metrics, grads = grads_of(params, batch)
        if grad_transform is not None:
            grads = grad_transform(grads)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = lr_schedule(opt["step"])
        new_params, new_opt = adamw_update(params, grads, opt, lr)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr,
                       step=new_opt["step"].astype(jnp.float32))
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step
