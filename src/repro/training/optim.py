"""Handwritten AdamW + schedules + global-norm clipping (no optax)."""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def adamw_init(params, opt_dtype: str = "float32") -> Dict:
    dt = jnp.dtype(opt_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn


def adamw_update(params, grads, opt, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1) -> Tuple[Dict, Dict]:
    step = opt["step"] + 1
    sf = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** sf
    bc2 = 1.0 - b2 ** sf

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        update = (mf / bc1) / (jnp.sqrt(vf / bc2) + eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            update = update + weight_decay * p.astype(jnp.float32)
        pn = p.astype(jnp.float32) - lr * update
        return pn.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt["m"])
    flat_v = jax.tree.leaves(opt["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}


def cosine_schedule(step, *, base_lr=3e-4, warmup=100, total=10000, min_frac=0.1):
    s = step.astype(jnp.float32)
    warm = s / max(warmup, 1)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(math.pi * prog))
    return base_lr * jnp.where(s < warmup, warm, cos)
