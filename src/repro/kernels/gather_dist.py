"""Pallas TPU kernel: fused neighbor gather + squared-L2 distance.

The beam-search expansion hot path: gather M arbitrary rows of X (HBM) and
score them against one query.  The neighbor ids are *scalar-prefetched* so the
BlockSpec index_map can steer each grid step's DMA to the right row of X —
the TPU-native replacement for the CPU pointer-chase.

Grid = (M,); per step: one (1,d) row of X lands in VMEM, the query is resident
(full (1,d) block), the VPU computes Σ(x−q)² into out[i].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, x_ref, q_ref, o_ref):
    diff = x_ref[...].astype(jnp.float32) - q_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.sum(diff * diff, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_dist_pallas(x: jax.Array, ids: jax.Array, q: jax.Array, *,
                       interpret: bool = False) -> jax.Array:
    """x:(N,d); ids:(M,) int32; q:(d,) -> (M,) f32 squared distances.
    Out-of-range/negative ids are clipped (callers mask separately)."""
    n, d = x.shape
    m = ids.shape[0]
    ids_c = jnp.clip(ids, 0, n - 1).astype(jnp.int32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, ids_ref: (ids_ref[i], 0)),
            pl.BlockSpec((1, d), lambda i, ids_ref: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, ids_ref: (i, 0)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=interpret,
    )(ids_c, x, q[None, :])
    return out[:, 0]
