"""Pallas TPU kernels: fused neighbor gather + squared-L2 scoring, blocked.

The beam-search expansion hot path: gather M arbitrary rows of X (HBM) and
score them against one query.  The neighbor ids are *scalar-prefetched* so
the BlockSpec index_map can steer each grid step's DMA to the right row of X
— the TPU-native replacement for the CPU pointer-chase.

Both kernels process the id vector in **row tiles** of T ids: the grid is
``(num_tiles, T)``, the innermost dimension walks the tile (one steered
(1, d) row DMA per step, which Mosaic pipelines across steps), and each
row's Σ(x−q)² lands in a lane of a (1, T) VMEM accumulator.  Work leaves
VMEM once per *tile*, not once per row:

* ``gather_dist_pallas`` — writes the accumulated (1, T) distance block to
  the output on the tile's last step (full (M,) distances, the legacy
  contract: negative/out-of-range ids are clipped, callers mask).
* ``gather_topk_pallas`` — instead folds the masked tile (ids < 0 → +inf)
  into a per-query **running top-k** held in (1, T)-lane output blocks
  (dists + ids), mirroring the ``range_scan`` running-top-k trick: a
  k-step select-min over the 2-block lane union (vector argmin + one-hot
  updates, so it lowers on both Mosaic and interpret backends).  The full
  (M,) distance vector never round-trips to HBM — only the merge
  survivors the batched beam's bounded frontier merge actually consumes.
  Ties break toward the lower input index, matching a stable
  ``jnp.argsort`` over the materialized distances.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _tile(m: int, cap: int = 128) -> int:
    """Row-tile size for an id vector of length m (pow2, ≤ cap)."""
    return int(min(cap, 1 << max(int(m) - 1, 0).bit_length() if m > 1 else 1))


def _row_d2(x_ref, q_ref, scale_ref):
    """Σ(x−q)² of one gathered row, dequantized in VMEM when the corpus is
    int8 (``scale_ref`` holds the (1, d) per-dimension factors)."""
    xf = x_ref[...].astype(jnp.float32)
    if scale_ref is not None:
        xf = xf * scale_ref[...]
    diff = xf - q_ref[...].astype(jnp.float32)
    return jnp.sum(diff * diff)


def _dist_body(ids_ref, x_ref, q_ref, scale_ref, o_ref, acc_ref, *,
               tile: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    d2 = _row_d2(x_ref, q_ref, scale_ref)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, tile), 1) == t
    acc_ref[...] = jnp.where(lane, d2, acc_ref[...])

    @pl.when(t == tile - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def _dist_kernel(ids_ref, x_ref, q_ref, o_ref, acc_ref, **kw):
    _dist_body(ids_ref, x_ref, q_ref, None, o_ref, acc_ref, **kw)


def _dist_kernel_scaled(ids_ref, x_ref, scale_ref, q_ref, o_ref, acc_ref,
                        **kw):
    _dist_body(ids_ref, x_ref, q_ref, scale_ref, o_ref, acc_ref, **kw)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_dist_pallas(x: jax.Array, ids: jax.Array, q: jax.Array, *,
                       interpret: bool = False,
                       scale: jax.Array | None = None) -> jax.Array:
    """x:(N,d); ids:(M,) int32; q:(d,) -> (M,) f32 squared distances.
    Out-of-range/negative ids are clipped (callers mask separately).
    ``x`` may be int8/bf16; ``scale`` ((d,) f32) dequantizes int8 rows."""
    n, d = x.shape
    m = ids.shape[0]
    tile = _tile(m)
    nt = -(-m // tile)
    ids_c = jnp.clip(ids, 0, n - 1).astype(jnp.int32)
    ids_c = jnp.pad(ids_c, (0, nt * tile - m))      # tail rows: row 0, sliced off
    x_spec = pl.BlockSpec((1, d), lambda i, t, ids_ref: (ids_ref[i * tile + t], 0))
    q_spec = pl.BlockSpec((1, d), lambda i, t, ids_ref: (0, 0))
    if scale is None:
        kernel, in_specs, ops = _dist_kernel, [x_spec, q_spec], (x, q[None, :])
    else:
        kernel = _dist_kernel_scaled
        in_specs = [x_spec, q_spec, q_spec]      # scale: one (1, d) block
        ops = (x, scale.astype(jnp.float32)[None, :], q[None, :])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nt, tile),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, tile), lambda i, t, ids_ref: (i, 0)),
        scratch_shapes=[pltpu.VMEM((1, tile), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(kernel, tile=tile),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nt, tile), jnp.float32),
        interpret=interpret,
    )(ids_c, *ops)
    return out.reshape(nt * tile)[:m]


def _fold_topk(acc_ref, idm_ref, od_ref, oi_ref, *, tile: int, k: int):
    """Fold one accumulated (1, tile) distance block into the running top-k
    held in the (1, tile) output lanes (dists + ids).  Shared by the
    single-query ``gather_topk`` and the batched ``gather_rerank``."""
    idv = idm_ref[...]                                   # (1, tile) i32
    d_blk = jnp.where(idv >= 0, acc_ref[...], jnp.inf)
    # union of the running top-k and this tile; tiles arrive in
    # ascending-id-index order and the running half comes first, so the
    # first-occurrence argmin breaks distance ties toward the lower
    # input index (matching a stable argsort of the full vector)
    cd = jnp.concatenate([od_ref[...], d_blk], axis=1)   # (1, 2*tile)
    ci = jnp.concatenate([oi_ref[...], idv], axis=1)
    lane_u = jax.lax.broadcasted_iota(jnp.int32, (1, 2 * tile), 1)
    lane_o = jax.lax.broadcasted_iota(jnp.int32, (1, tile), 1)
    new_d = jnp.full((1, tile), jnp.inf, jnp.float32)
    new_i = jnp.full((1, tile), -1, jnp.int32)
    for s in range(k):            # static unroll: k-step select-min
        mv = jnp.min(cd)
        sel = lane_u == jnp.argmin(cd).astype(jnp.int32)
        idn = jnp.sum(jnp.where(sel, ci, 0)).astype(jnp.int32)
        idn = jnp.where(jnp.isfinite(mv), idn, -1)
        new_d = jnp.where(lane_o == s, mv, new_d)
        new_i = jnp.where(lane_o == s, idn, new_i)
        cd = jnp.where(sel, jnp.inf, cd)
    od_ref[...] = new_d
    oi_ref[...] = new_i


def _topk_body(ids_ref, x_ref, q_ref, scale_ref, idm_ref, od_ref, oi_ref,
               acc_ref, *, tile: int, k: int):
    i = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when((i == 0) & (t == 0))
    def _init_topk():
        od_ref[...] = jnp.full_like(od_ref, jnp.inf)
        oi_ref[...] = jnp.full_like(oi_ref, -1)

    @pl.when(t == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    d2 = _row_d2(x_ref, q_ref, scale_ref)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, tile), 1) == t
    acc_ref[...] = jnp.where(lane, d2, acc_ref[...])

    @pl.when(t == tile - 1)
    def _merge():
        _fold_topk(acc_ref, idm_ref, od_ref, oi_ref, tile=tile, k=k)


def _topk_kernel(ids_ref, x_ref, q_ref, idm_ref, od_ref, oi_ref, acc_ref,
                 **kw):
    _topk_body(ids_ref, x_ref, q_ref, None, idm_ref, od_ref, oi_ref, acc_ref,
               **kw)


def _topk_kernel_scaled(ids_ref, x_ref, scale_ref, q_ref, idm_ref, od_ref,
                        oi_ref, acc_ref, **kw):
    _topk_body(ids_ref, x_ref, q_ref, scale_ref, idm_ref, od_ref, oi_ref,
               acc_ref, **kw)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def gather_topk_pallas(x: jax.Array, ids: jax.Array, q: jax.Array, *,
                       k: int, interpret: bool = False,
                       scale: jax.Array | None = None):
    """x:(N,d); ids:(M,) int32, **negative = masked**; q:(d,).
    Returns (ids:(k,) i32 sorted by ascending distance (-1 pad),
    dists:(k,) f32, +inf pad) — the top-k over the *unmasked* ids only.
    ``x`` may be int8/bf16; ``scale`` ((d,) f32) dequantizes int8 rows.

    Requires ``k ≤ min(next_pow2(M), 128)`` (the running top-k lives in one
    lane row) and raises ``ValueError`` beyond it — callers needing a
    larger k must themselves use ``gather_dist`` + a host sort, as the
    batched beam's ``kernel_topk`` gate in ``core/beam.py`` does."""
    n, d = x.shape
    m = ids.shape[0]
    tile = _tile(max(m, k))             # lane row must hold k survivors
    if k > tile:
        raise ValueError(f"gather_topk: k={k} exceeds the {tile}-lane "
                         f"running top-k row (use gather_dist + sort)")
    nt = -(-m // tile)
    pad = nt * tile - m
    ids_m = jnp.pad(ids.astype(jnp.int32), (0, pad), constant_values=-1)
    ids_c = jnp.clip(ids_m, 0, n - 1)
    x_spec = pl.BlockSpec((1, d), lambda i, t, ids_ref: (ids_ref[i * tile + t], 0))
    q_spec = pl.BlockSpec((1, d), lambda i, t, ids_ref: (0, 0))
    idm_spec = pl.BlockSpec((1, tile), lambda i, t, ids_ref: (0, i))
    if scale is None:
        kernel = _topk_kernel
        in_specs = [x_spec, q_spec, idm_spec]
        ops = (x, q[None, :], ids_m[None, :])
    else:
        kernel = _topk_kernel_scaled
        in_specs = [x_spec, q_spec, q_spec, idm_spec]
        ops = (x, scale.astype(jnp.float32)[None, :], q[None, :],
               ids_m[None, :])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nt, tile),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, tile), lambda i, t, ids_ref: (0, 0)),
            pl.BlockSpec((1, tile), lambda i, t, ids_ref: (0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((1, tile), jnp.float32)],
    )
    od, oi = pl.pallas_call(
        functools.partial(kernel, tile=tile, k=k),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((1, tile), jnp.float32),
                   jax.ShapeDtypeStruct((1, tile), jnp.int32)),
        interpret=interpret,
    )(ids_c, *ops)
    return oi[0, :k], od[0, :k]


# ======================================================================
# Batched rerank: per-query gather + f32 top-k over survivor id lists
# ======================================================================
def _rerank_kernel(ids_ref, x_ref, q_ref, idm_ref, od_ref, oi_ref, acc_ref,
                   *, tile: int, k: int):
    j = pl.program_id(1)          # id tile within this query's list
    t = pl.program_id(2)          # position within the tile

    @pl.when((j == 0) & (t == 0))
    def _init_topk():             # grid is row-major: (i, 0, 0) starts query i
        od_ref[...] = jnp.full_like(od_ref, jnp.inf)
        oi_ref[...] = jnp.full_like(oi_ref, -1)

    @pl.when(t == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    d2 = _row_d2(x_ref, q_ref, None)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, tile), 1) == t
    acc_ref[...] = jnp.where(lane, d2, acc_ref[...])

    @pl.when(t == tile - 1)
    def _merge():
        _fold_topk(acc_ref, idm_ref, od_ref, oi_ref, tile=tile, k=k)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def gather_rerank_pallas(x: jax.Array, ids: jax.Array, q: jax.Array, *,
                         k: int, interpret: bool = False):
    """Batched ``gather_topk``: the f32 rerank stage of the quantized path.

    x:(N,d) f32; ids:(Q,M) int32 survivor ranks per query (**negative =
    masked**, callers pre-sort ascending via ``sort_candidates`` so distance
    ties break toward the lower rank); q:(Q,d).  Returns (ids:(Q,k) i32
    ascending-distance (-1 pad), dists:(Q,k) f32 (+inf pad)).

    One grid, Q running top-k rows: grid = (Q, tiles, tile) with the same
    scalar-prefetched row steering as ``gather_topk`` — the per-(query, t)
    row DMA index comes from the flattened id table.  Requires ``k ≤
    min(next_pow2(M), 128)``."""
    n, d = x.shape
    Q, m = ids.shape
    tile = _tile(max(m, k))
    if k > tile:
        raise ValueError(f"gather_rerank: k={k} exceeds the {tile}-lane "
                         f"running top-k row (use gather_dist + sort)")
    nt = -(-m // tile)
    mp = nt * tile
    ids_m = jnp.pad(ids.astype(jnp.int32), ((0, 0), (0, mp - m)),
                    constant_values=-1)
    ids_c = jnp.clip(ids_m, 0, n - 1).reshape(Q * mp)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Q, nt, tile),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j, t, ids_ref:
                         (ids_ref[i * (nt * tile) + j * tile + t], 0)),
            pl.BlockSpec((1, d), lambda i, j, t, ids_ref: (i, 0)),
            pl.BlockSpec((1, tile), lambda i, j, t, ids_ref: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile), lambda i, j, t, ids_ref: (i, 0)),
            pl.BlockSpec((1, tile), lambda i, j, t, ids_ref: (i, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((1, tile), jnp.float32)],
    )
    od, oi = pl.pallas_call(
        functools.partial(_rerank_kernel, tile=tile, k=k),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((Q, tile), jnp.float32),
                   jax.ShapeDtypeStruct((Q, tile), jnp.int32)),
        interpret=interpret,
    )(ids_c, x, q, ids_m)
    return oi[:, :k], od[:, :k]
