"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def l2dist_ref(q: jax.Array, x: jax.Array) -> jax.Array:
    """(Q,d) × (N,d) -> (Q,N) squared L2, f32 accumulation."""
    qf = q.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    qn = jnp.sum(qf * qf, axis=-1, keepdims=True)
    xn = jnp.sum(xf * xf, axis=-1)
    return jnp.maximum(qn - 2.0 * (qf @ xf.T) + xn[None, :], 0.0)


def gather_dist_ref(x: jax.Array, ids: jax.Array, q: jax.Array) -> jax.Array:
    """x:(N,d); ids:(M,) int32 (clipped to range); q:(d,) -> (M,) sq dists."""
    rows = x[jnp.clip(ids, 0, x.shape[0] - 1)].astype(jnp.float32)
    diff = rows - q.astype(jnp.float32)[None, :]
    return jnp.sum(diff * diff, axis=-1)


def flash_attention_ref(q, k, v, causal: bool = True):
    """(B,S,H,hd) GQA-free reference attention, f32 softmax."""
    b, s, h, d = q.shape
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / (d ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)
