"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def l2dist_ref(q: jax.Array, x: jax.Array) -> jax.Array:
    """(Q,d) × (N,d) -> (Q,N) squared L2, f32 accumulation."""
    qf = q.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    qn = jnp.sum(qf * qf, axis=-1, keepdims=True)
    xn = jnp.sum(xf * xf, axis=-1)
    return jnp.maximum(qn - 2.0 * (qf @ xf.T) + xn[None, :], 0.0)


def gather_dist_ref(x: jax.Array, ids: jax.Array, q: jax.Array,
                    scale: jax.Array | None = None) -> jax.Array:
    """x:(N,d); ids:(M,) int32 (clipped to range); q:(d,) -> (M,) sq dists.
    ``scale`` ((d,) f32) dequantizes int8 rows, matching the kernels."""
    rows = x[jnp.clip(ids, 0, x.shape[0] - 1)].astype(jnp.float32)
    if scale is not None:
        rows = rows * scale[None, :]
    diff = rows - q.astype(jnp.float32)[None, :]
    return jnp.sum(diff * diff, axis=-1)


def gather_topk_ref(x: jax.Array, ids: jax.Array, q: jax.Array, *, k: int,
                    scale: jax.Array | None = None):
    """Oracle for ``gather_topk_pallas``: negative ids are masked (never
    enter the top-k); returns (ids:(k,) i32 ascending-distance (-1 pad),
    dists:(k,) f32 (+inf pad)).  ``lax.top_k`` breaks distance ties toward
    the lower input index — the kernel's select-min matches."""
    d = jnp.where(ids >= 0, gather_dist_ref(x, ids, q, scale), jnp.inf)
    d = jnp.pad(d, (0, max(k - d.shape[0], 0)), constant_values=jnp.inf)
    idp = jnp.pad(ids.astype(jnp.int32), (0, max(k - ids.shape[0], 0)),
                  constant_values=-1)
    neg, sel = jax.lax.top_k(-d, k)
    out_ids = jnp.where(jnp.isfinite(neg), idp[sel], -1)
    return out_ids, -neg


def gather_rerank_ref(x: jax.Array, ids: jax.Array, q: jax.Array, *, k: int):
    """Oracle for ``gather_rerank_pallas``: per-query ``gather_topk_ref``
    over (Q, M) survivor lists against (Q, d) queries."""
    return jax.vmap(lambda i, qq: gather_topk_ref(x, i, qq, k=k))(ids, q)


def range_scan_ref(x: jax.Array, starts: jax.Array, lens: jax.Array,
                   q: jax.Array, *, bucket: int, k: int, tb: int = 128,
                   n_valid: int = 0, scale: jax.Array | None = None,
                   live: jax.Array | None = None):
    """Oracle for ``range_scan_pallas``: same window/alignment/n_valid
    contract.  x:(n_pad,d); starts/lens:(Q,); q:(Q,d) -> (ids, dists).
    ``scale`` ((d,) f32) dequantizes int8 rows, matching the kernel.
    ``live`` ((n_pad,) i32/bool) masks tombstoned rows out of the top-k."""
    from repro.kernels.range_scan import window_rows
    n_pad = x.shape[0]
    n_valid = int(n_valid) or n_pad
    w = window_rows(bucket, tb)
    base = (starts.astype(jnp.int32) // tb) * tb
    rank = base[:, None] + jnp.arange(w, dtype=jnp.int32)[None, :]   # (Q, w)
    rows = x[jnp.clip(rank, 0, n_pad - 1)].astype(jnp.float32)       # (Q, w, d)
    if scale is not None:
        rows = rows * scale[None, None, :]
    diff = rows - q.astype(jnp.float32)[:, None, :]
    d2 = jnp.sum(diff * diff, axis=-1)
    valid = ((rank >= starts[:, None]) & (rank < (starts + lens)[:, None])
             & (rank < n_valid))
    if live is not None:
        valid &= live[jnp.clip(rank, 0, n_pad - 1)] != 0
    d2 = jnp.where(valid, d2, jnp.inf)
    neg, idx = jax.lax.top_k(-d2, k)
    ids = jnp.where(jnp.isfinite(neg), base[:, None] + idx, -1)
    return ids.astype(jnp.int32), -neg


def flash_attention_ref(q, k, v, causal: bool = True):
    """(B,S,H,hd) GQA-free reference attention, f32 softmax."""
    b, s, h, d = q.shape
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / (d ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)
