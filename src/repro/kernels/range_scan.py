"""Pallas TPU kernel: fused brute-force scan over a contiguous rank slice.

The planner's exact strategy for highly selective ranges: ids are attribute
ranks, so the candidate set of a range query is the contiguous slice
``x[L : R+1]`` and an exact masked L2 scan + top-k beats graph traversal when
the slice is small.

Each query carries its own ``(start, len)``; the per-query window start is
*scalar-prefetched* so the BlockSpec index_map steers each grid step's DMA to
the right row-block of X.  Window starts are aligned down to the row-tile
(``tb``) boundary and one extra row-block is appended, so a bucket of length B
is served by ``ceil(B/tb)+1`` fixed-shape blocks regardless of alignment;
positions outside ``[start, start+len)`` are masked to +inf by absolute rank.

Grid = (Q, row-blocks, d-chunks); the d-axis is the innermost "arbitrary"
dimension accumulating qn − 2·qᵀx + xn into a (1, tb) VMEM *scratch* block
(same scheme as ``l2dist``).  On the last d-step the block's masked distances
are folded into a per-query running top-k held in the (1, tb)-lane output
blocks (dists + rank ids), so the full (Q, W) distance matrix is **never
materialized** — the kernel's output is (Q, tb) regardless of window size.
The merge is a k-step select-min over the 2·tb-lane union of the running
top-k and the new block (vector argmin + one-hot updates only, so it lowers
on both the Mosaic and interpret backends); ties break toward lower rank,
matching ``jax.lax.top_k`` on the materialized matrix.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def window_rows(bucket: int, tb: int = 128) -> int:
    """Rows actually scanned for a bucket: ceil(bucket/tb) blocks plus one
    extra block so any start alignment is covered (single source of truth —
    the kernel, its jnp oracle, and the planner cost model all use this)."""
    return (-(-bucket // tb) + 1) * tb


def _body(starts_ref, lens_ref, x_ref, scale_ref, live_ref, q_ref, od_ref,
          oi_ref, acc_ref, *, nd: int, tb: int, k: int, n_valid: int):
    i = pl.program_id(0)          # query
    j = pl.program_id(1)          # row block within the window
    kd = pl.program_id(2)         # d-chunk

    @pl.when((j == 0) & (kd == 0))
    def _init_topk():
        od_ref[...] = jnp.full_like(od_ref, jnp.inf)
        oi_ref[...] = jnp.full_like(oi_ref, -1)

    @pl.when(kd == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)            # (tb, td)
    if scale_ref is not None:                     # int8: dequant in VMEM
        x = x * scale_ref[...]                    # (1, td) broadcast
    q = q_ref[...].astype(jnp.float32)            # (1, td)
    dot = jax.lax.dot_general(q, x, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    acc_ref[...] += -2.0 * dot
    acc_ref[...] += jnp.sum(q * q, axis=1, keepdims=True)
    acc_ref[...] += jnp.sum(x * x, axis=1)[None, :]

    @pl.when(kd == nd - 1)
    def _merge():
        start = starts_ref[i]
        ln = lens_ref[i]
        base = (start // tb) * tb
        rank = base + j * tb + jax.lax.broadcasted_iota(jnp.int32, (1, tb), 1)
        valid = (rank >= start) & (rank < start + ln) & (rank < n_valid)
        if live_ref is not None:              # per-row tombstone mask
            valid &= live_ref[...] != 0       # (1, tb), same row block as x
        d_blk = jnp.where(valid, jnp.maximum(acc_ref[...], 0.0), jnp.inf)
        # union of the running top-k and this block; blocks arrive in
        # ascending-rank order and the running half comes first, so the
        # first-occurrence argmin breaks distance ties toward lower rank
        cd = jnp.concatenate([od_ref[...], d_blk], axis=1)      # (1, 2*tb)
        ci = jnp.concatenate([oi_ref[...], rank], axis=1)
        lane_u = jax.lax.broadcasted_iota(jnp.int32, (1, 2 * tb), 1)
        lane_o = jax.lax.broadcasted_iota(jnp.int32, (1, tb), 1)
        new_d = jnp.full((1, tb), jnp.inf, jnp.float32)
        new_i = jnp.full((1, tb), -1, jnp.int32)
        for t in range(k):            # static unroll: k-step select-min
            m = jnp.min(cd)
            sel = lane_u == jnp.argmin(cd).astype(jnp.int32)
            idv = jnp.sum(jnp.where(sel, ci, 0)).astype(jnp.int32)
            idv = jnp.where(jnp.isfinite(m), idv, -1)
            new_d = jnp.where(lane_o == t, m, new_d)
            new_i = jnp.where(lane_o == t, idv, new_i)
            cd = jnp.where(sel, jnp.inf, cd)
        od_ref[...] = new_d
        oi_ref[...] = new_i


def _make_kernel(has_scale: bool, has_live: bool):
    """Kernel entry point for one (scale, live) operand combination; the
    optional refs arrive positionally between x and q in operand order."""
    def kernel(starts_ref, lens_ref, x_ref, *rest, **kw):
        rest = list(rest)
        scale_ref = rest.pop(0) if has_scale else None
        live_ref = rest.pop(0) if has_live else None
        q_ref, od_ref, oi_ref, acc_ref = rest
        _body(starts_ref, lens_ref, x_ref, scale_ref, live_ref, q_ref,
              od_ref, oi_ref, acc_ref, **kw)
    return kernel


_KERNELS = {(s, lv): _make_kernel(s, lv)
            for s in (False, True) for lv in (False, True)}


@functools.partial(jax.jit,
                   static_argnames=("bucket", "k", "tb", "td", "interpret",
                                    "n_valid"))
def range_scan_pallas(x: jax.Array, starts: jax.Array, lens: jax.Array,
                      q: jax.Array, *, bucket: int, k: int, tb: int = 128,
                      td: int = 512, interpret: bool = False,
                      n_valid: int = 0, scale: jax.Array | None = None,
                      live: jax.Array | None = None):
    """x:(n_pad,d_pad) rank-ordered, n_pad % tb == 0, d_pad % 128 == 0;
    starts/lens:(Q,) i32 per-query rank windows (len ≤ bucket); q:(Q,d_pad).
    Returns (ids:(Q,k) i32 absolute ranks (-1 pad), dists:(Q,k) f32).

    ``x`` may be a quantized corpus copy (int8/bf16): the block is upcast to
    f32 in VMEM right after the narrow DMA, and an optional ``scale``
    ((d_pad,) f32 per-dimension dequant factors, int8 mode) multiplies it
    before scoring — the accumulation/top-k machinery is dtype-agnostic.

    ``n_valid`` (0 = n_pad): ranks ≥ n_valid never enter the top-k, even when
    a window nominally covers them.  Shard-local dispatch (the mesh substrate
    traces this kernel per shard with windows clipped to the shard's rank
    slice) passes the shard's true row count so the zero rows padding the
    corpus to a row-tile multiple can never win.

    ``live`` ((1, n_pad) i32, optional) is the per-row generalization of
    ``n_valid``: rows whose lane is 0 never enter the top-k.  The streaming
    layer threads tombstone masks through it (base segment: deleted ranks;
    delta segment: the pad tail beyond the current row count) — being an
    operand rather than a static arg, mask churn never retraces."""
    n_pad, d_pad = x.shape
    Q = q.shape[0]
    n_valid = int(n_valid) or n_pad
    if k > tb:
        # running top-k lives in one tb-lane register row; beyond that fall
        # back to the materializing oracle (rare: k > 128)
        from repro.kernels.ref import range_scan_ref
        return range_scan_ref(x, starts, lens, q, bucket=bucket, k=k, tb=tb,
                              n_valid=n_valid, scale=scale,
                              live=None if live is None else live[0])
    td = d_pad if d_pad <= td else 128
    nd = d_pad // td
    w = window_rows(bucket, tb)
    nb = w // tb
    max_blk = n_pad // tb - 1
    starts = starts.astype(jnp.int32)
    lens = lens.astype(jnp.int32)

    x_spec = pl.BlockSpec((tb, td),
                          lambda i, j, kd, s_ref, l_ref:
                          (jnp.minimum(s_ref[i] // tb + j, max_blk), kd))
    q_spec = pl.BlockSpec((1, td), lambda i, j, kd, s_ref, l_ref: (i, kd))
    kernel = _KERNELS[(scale is not None, live is not None)]
    in_specs, ops = [x_spec], [x]
    if scale is not None:
        in_specs.append(pl.BlockSpec((1, td),
                                     lambda i, j, kd, s_ref, l_ref: (0, kd)))
        ops.append(scale.astype(jnp.float32)[None, :])
    if live is not None:
        # same row block as x: lanes line up with the ranks scored there
        in_specs.append(pl.BlockSpec(
            (1, tb), lambda i, j, kd, s_ref, l_ref:
            (0, jnp.minimum(s_ref[i] // tb + j, max_blk))))
        ops.append(live.astype(jnp.int32))
    in_specs.append(q_spec)
    ops.append(q)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(Q, nb, nd),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, tb), lambda i, j, kd, s_ref, l_ref: (i, 0)),
            pl.BlockSpec((1, tb), lambda i, j, kd, s_ref, l_ref: (i, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((1, tb), jnp.float32)],
    )
    dists, ids = pl.pallas_call(
        functools.partial(kernel, nd=nd, tb=tb, k=k, n_valid=n_valid),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((Q, tb), jnp.float32),
                   jax.ShapeDtypeStruct((Q, tb), jnp.int32)),
        interpret=interpret,
    )(starts, lens, *ops)

    return ids[:, :k], dists[:, :k]
