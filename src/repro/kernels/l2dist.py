"""Pallas TPU kernel: tiled batched squared-L2 distance.

Computes D[i,j] = ‖q_i − x_j‖² as qn_i − 2·q_iᵀx_j + xn_j so the dominant term
is an MXU matmul.  3-D grid (Q-tiles × N-tiles × d-chunks): the d-axis is the
innermost "arbitrary" dimension accumulating partial dot products into the
output tile living in VMEM; norms are folded in on the last d-step.

VMEM budget per step: q tile (TQ×TD) + x tile (TN×TD) + out tile (TQ×TN),
all f32 → with TQ=TN=128, TD=512 this is 128·512·4·2 + 128·128·4 ≈ 590 KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, x_ref, o_ref, *, nd: int):
    kd = pl.program_id(2)

    @pl.when(kd == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...].astype(jnp.float32)            # (TQ, TD)
    x = x_ref[...].astype(jnp.float32)            # (TN, TD)
    partial_dot = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    o_ref[...] += -2.0 * partial_dot
    o_ref[...] += jnp.sum(q * q, axis=1, keepdims=True)
    o_ref[...] += jnp.sum(x * x, axis=1)[None, :]

    @pl.when(kd == nd - 1)
    def _fin():
        o_ref[...] = jnp.maximum(o_ref[...], 0.0)


@functools.partial(jax.jit, static_argnames=("tq", "tn", "td", "interpret"))
def l2dist_pallas(q: jax.Array, x: jax.Array, *, tq: int = 128, tn: int = 128,
                  td: int = 512, interpret: bool = False) -> jax.Array:
    """q:(Q,d), x:(N,d) -> (Q,N) f32. Q,N,d padded to tile multiples."""
    Q, d = q.shape
    N = x.shape[0]
    tq, tn, td = min(tq, max(Q, 8)), min(tn, max(N, 128)), min(td, max(d, 128))
    pq, pn, pd = (-Q) % tq, (-N) % tn, (-d) % td
    qp = jnp.pad(q, ((0, pq), (0, pd)))
    xp = jnp.pad(x, ((0, pn), (0, pd)))
    nd = (d + pd) // td
    grid = ((Q + pq) // tq, (N + pn) // tn, nd)
    out = pl.pallas_call(
        functools.partial(_kernel, nd=nd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tq, td), lambda i, j, k: (i, k)),
            pl.BlockSpec((tn, td), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((tq, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Q + pq, N + pn), jnp.float32),
        interpret=interpret,
    )(qp, xp)
    return out[:Q, :N]
