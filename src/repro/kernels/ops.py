"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode — the
kernel body runs verbatim for correctness; on TPU the same call sites compile
to Mosaic.  Backend selection is automatic.
"""
from __future__ import annotations

import jax

from repro.kernels.gather_dist import (gather_dist_pallas,
                                       gather_rerank_pallas,
                                       gather_topk_pallas)
from repro.kernels.l2dist import l2dist_pallas
from repro.kernels.range_scan import range_scan_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def l2dist(q: jax.Array, x: jax.Array, **kw) -> jax.Array:
    """(Q,d) × (N,d) -> (Q,N) squared-L2 distance matrix."""
    return l2dist_pallas(q, x, interpret=_interpret(), **kw)


def gather_dist(x: jax.Array, ids: jax.Array, q: jax.Array,
                scale: jax.Array | None = None) -> jax.Array:
    """Fused gather+score of M neighbor rows against one query.  ``x`` may
    be a quantized corpus; ``scale`` dequantizes int8 rows in VMEM."""
    return gather_dist_pallas(x, ids, q, scale=scale, interpret=_interpret())


def gather_topk(x: jax.Array, ids: jax.Array, q: jax.Array, *, k: int,
                scale: jax.Array | None = None):
    """Fused gather+score+top-k: the batched beam's frontier feed.  Negative
    ids are masked; only the k merge survivors leave the kernel."""
    return gather_topk_pallas(x, ids, q, k=k, scale=scale,
                              interpret=_interpret())


def gather_rerank(x: jax.Array, ids: jax.Array, q: jax.Array, *, k: int):
    """Batched f32 rescore of (Q, M) quantized-pass survivor ids against
    (Q, d) queries — the exactness-restoring stage of the quantized path."""
    return gather_rerank_pallas(x, ids, q, k=k, interpret=_interpret())


def range_scan(x: jax.Array, starts: jax.Array, lens: jax.Array,
               q: jax.Array, *, bucket: int, k: int, n_valid: int = 0,
               scale: jax.Array | None = None,
               live: jax.Array | None = None):
    """Per-query masked scan + top-k over contiguous rank slices of x.
    ``n_valid`` masks the zero rows padding x to a row-tile multiple
    (0 = trust the window contract, i.e. all of x is real).  ``x`` may be
    a quantized corpus copy; ``scale`` dequantizes int8 rows in VMEM.
    ``live`` ((1, n_pad) i32) masks tombstoned rows (streaming deletes)."""
    return range_scan_pallas(x, starts, lens, q, bucket=bucket, k=k,
                             n_valid=n_valid, scale=scale, live=live,
                             interpret=_interpret())
