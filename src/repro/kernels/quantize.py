"""Quantized corpus artifacts for the int8/bf16 scoring paths.

The corpus is stored **once per precision** in the same rank-sorted order as
the f32 vectors, so interval slicing (``x[L : R+1]``), neighbor gathers, and
the scan kernel's window arithmetic are unchanged — only the bytes moved per
scored row shrink (4x for int8, 2x for bf16).

* ``int8`` — per-dimension symmetric quantization: ``scale[j] =
  max|x[:, j]| / 127`` and ``data = round(x / scale)`` clipped to ±127.
  Kernels dequantize in VMEM (``data.astype(f32) * scale``) right after the
  narrow DMA, so the MXU matmul stays f32 and HBM bandwidth is the win.
* ``bf16`` — a plain downcast; no scale (the kernels' existing
  ``astype(f32)`` upcast covers it).

Quantized scoring alone is *approximate*; exactness of the final top-k is
restored by the f32 rerank stage: the quantized pass over-fetches
``rerank_depth(k, ef)`` survivors, a second f32 gather+top-k rescores only
those ids, and the reranked result is what merge/stitch consume.  Survivor
ids are sorted ascending (``sort_candidates``) before the rerank so its
stable tie-breaking (toward the lower input index) equals the f32 oracle's
tie-toward-lower-rank — bit-compatible id sets, asserted in tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

PRECISIONS = ("f32", "int8", "bf16")

#: the scan kernel's running top-k lives in one (1, 128) lane row, so the
#: quantized over-fetch is capped there; larger k falls through to the
#: materializing oracle which has no such bound.
RERANK_CAP = 128


def rerank_depth(k: int, ef: int, cap: int = RERANK_CAP) -> int:
    """Quantized-pass over-fetch: ~4*ef survivors, clamped to [k, cap]."""
    return int(min(max(4 * int(ef), int(k)), max(int(cap), int(k))))


@dataclass(frozen=True)
class QuantizedCorpus:
    """One rank-ordered quantized corpus copy.

    data  : (n, d) int8 or bfloat16, same row order as the f32 vectors.
    scale : (d,) f32 per-dimension dequant factors (int8 only; None for
            bf16 — the downcast needs no scale).
    """
    precision: str
    data: jax.Array
    scale: Optional[jax.Array]

    @property
    def bytes_per_vector(self) -> int:
        return int(self.data.shape[1]) * self.data.dtype.itemsize


def quantize_corpus(vecs: jax.Array, precision: str) -> QuantizedCorpus:
    """Build the quantized copy of a rank-ordered (n, d) f32 corpus."""
    x = jnp.asarray(vecs, jnp.float32)
    if precision == "bf16":
        return QuantizedCorpus("bf16", x.astype(jnp.bfloat16), None)
    if precision != "int8":
        raise ValueError(f"quantize_corpus: invalid precision {precision!r} "
                         f"(expected one of {PRECISIONS[1:]})")
    abs_max = jnp.max(jnp.abs(x), axis=0)
    # an all-zero dimension would divide by zero; its rows are all zero
    # anyway, so any positive scale round-trips them exactly
    scale = jnp.where(abs_max > 0, abs_max / 127.0, 1.0).astype(jnp.float32)
    data = jnp.clip(jnp.round(x / scale[None, :]), -127, 127).astype(jnp.int8)
    return QuantizedCorpus("int8", data, scale)


def dequantize(qc: QuantizedCorpus) -> jax.Array:
    """f32 view of the quantized corpus — what the kernels score against
    (the oracle target for the quantized-parity tests)."""
    x = qc.data.astype(jnp.float32)
    if qc.scale is not None:
        x = x * qc.scale[None, :]
    return x


def sort_candidates(ids: jax.Array) -> jax.Array:
    """Sort candidate rank ids ascending along the last axis, -1 pads last.

    Rerank inputs must arrive in ascending-rank order: the f32 rescore
    breaks distance ties toward the lower *input index*, so pre-sorting by
    rank makes that identical to the exact path's tie-toward-lower-rank."""
    big = jnp.int32(jnp.iinfo(jnp.int32).max)
    s = jnp.sort(jnp.where(ids >= 0, ids.astype(jnp.int32), big), axis=-1)
    return jnp.where(s == big, -1, s)
