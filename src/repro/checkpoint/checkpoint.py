"""Fault-tolerant checkpointing.

* Atomic: write to ``<dir>/tmp.<step>`` then ``os.replace`` — a crash mid-save
  never corrupts the latest checkpoint.
* Async: the device→host transfer happens synchronously (cheap), the disk
  write runs on a background thread so the train loop keeps stepping.
* Mesh-agnostic / elastic: arrays are stored unsharded with their tree paths;
  ``restore`` re-shards onto whatever mesh the resumed job has — resuming on a
  different device count (elastic scaling) is just a different ``device_put``.
* Journaled: ``latest_step`` scans the directory, so restart-after-preemption
  needs no external coordinator state.
"""
from __future__ import annotations

import json
import os
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _open_npz(path: Path, step: int):
    """np.load with truncation/corruption rewritten into a clear error
    naming the checkpoint file and step (raw zipfile/zlib errors say
    nothing about *which* checkpoint died)."""
    import zipfile
    import zlib as _zlib
    try:
        return np.load(path)
    except FileNotFoundError:
        raise
    except (zipfile.BadZipFile, _zlib.error, ValueError, OSError,
            EOFError) as e:
        from repro.index.io import IndexCorruptionError
        raise IndexCorruptionError(
            f"checkpoint step {step} ({path}) is truncated or corrupt: "
            f"{e}") from e


def _read_member(z, key: str, path: Path, step: int) -> np.ndarray:
    """Read one npz member; a bad per-member CRC only surfaces at read
    time, so wrap that too."""
    import zipfile
    import zlib as _zlib
    try:
        return z[key]
    except (zipfile.BadZipFile, _zlib.error, ValueError, OSError,
            EOFError) as e:
        from repro.index.io import IndexCorruptionError
        raise IndexCorruptionError(
            f"checkpoint step {step} ({path}): member {key!r} is "
            f"truncated or corrupt: {e}") from e


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        a = np.asarray(leaf)
        if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
            a = a.astype(np.float32)      # exact upcast; restore re-narrows
        flat[key] = a
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def _path(self, step: int) -> Path:
        return self.dir / f"step_{step:010d}.npz"

    def all_steps(self):
        return sorted(int(p.stem.split("_")[1]) for p in self.dir.glob("step_*.npz"))

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------
    def save(self, step: int, state, blocking: bool = False,
             extra: Optional[Dict[str, Any]] = None) -> None:
        """Device→host copy now; disk write async unless blocking=True."""
        self.wait()                                   # one in-flight save max
        flat = _flatten(state)                        # host copies
        meta = json.dumps(dict(step=step, time=time.time(), **(extra or {})))

        def write():
            try:
                tmp = self.dir / f"tmp.{step}.npz"
                np.savez(tmp, __meta__=np.frombuffer(meta.encode(), np.uint8),
                         **flat)
                os.replace(tmp, self._path(step))
                self._gc()
            except BaseException as e:               # surfaced on next wait()
                self._last_error = e

        if blocking:
            write()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise RuntimeError("async checkpoint write failed") from err

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            self._path(s).unlink(missing_ok=True)

    # ------------------------------------------------------------------
    def restore(self, state_like, step: Optional[int] = None,
                shardings=None) -> Any:
        """Rebuild the pytree of ``state_like`` (same structure; arrays may be
        abstract). ``shardings``: optional matching tree of NamedShardings —
        this is the elastic-resume path (different mesh than the saver's)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        leaves_with_path = jax.tree_util.tree_leaves_with_path(state_like)
        flat_keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                             for p in path) for path, _ in leaves_with_path]
        arrays = []
        # context-manage the npz: np.load keeps the zip member file open
        # until closed, so a bare handle leaks one fd per restore
        with _open_npz(self._path(step), step) as z:
            for key, (path, leaf) in zip(flat_keys, leaves_with_path):
                if key not in z.files:
                    raise KeyError(
                        f"checkpoint step {step} ({self._path(step)}) has no "
                        f"entry for tree path {key!r}; the restore template "
                        f"does not match the saved state (saved keys: "
                        f"{sorted(k for k in z.files if k != '__meta__')})")
                a = _read_member(z, key, self._path(step), step)
                want = getattr(leaf, "dtype", None)
                if want is not None and str(a.dtype) != str(want):
                    a = a.astype(want)
                arrays.append(a)
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(state_like), arrays)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree

    def meta(self, step: Optional[int] = None) -> Dict:
        step = step if step is not None else self.latest_step()
        with _open_npz(self._path(step), step) as z:
            if "__meta__" not in z.files:
                raise KeyError(f"checkpoint step {step} ({self._path(step)}) "
                               f"has no __meta__ entry")
            return json.loads(bytes(
                _read_member(z, "__meta__", self._path(step), step)).decode())

    def restore_flat(self, step: Optional[int] = None) -> Dict[str, np.ndarray]:
        """Every saved array keyed by tree path — the template-free restore
        used by :meth:`restore_index` (the saved manifest, not the caller,
        knows the tree shape).  A truncated or checksum-mangled member
        raises ``IndexCorruptionError`` naming the file and step."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        with _open_npz(self._path(step), step) as z:
            return {k: _read_member(z, k, self._path(step), step)
                    for k in z.files if k != "__meta__"}

    # ------------------------------------------------------------------
    # Index checkpointing: RNSGGraph / RNSGIndex (incl. installed quantized
    # corpora) and StreamingRFANN delta/tombstone state ride through the
    # same atomic-npz step machinery as model state.  The array tree and
    # its manifest come from ``repro.index.io``; the heavy sharded on-disk
    # format (mmap/parallel restore) lives there too — this path is the
    # single-file "checkpoint step" flavor.
    def save_index(self, step: int, index, *, blocking: bool = True,
                   extra: Optional[Dict[str, Any]] = None) -> None:
        from repro.index.io import index_state
        flat, manifest = index_state(index)
        self.save(step, flat, blocking=blocking,
                  extra=dict(extra or {}, index=manifest))

    def restore_index(self, step: Optional[int] = None):
        from repro.index.io import index_from_state
        meta = self.meta(step)
        if "index" not in meta:
            raise KeyError(f"checkpoint step "
                           f"{step if step is not None else self.latest_step()}"
                           f" was not written by save_index (no index "
                           f"manifest in __meta__)")
        return index_from_state(self.restore_flat(step), meta["index"])
