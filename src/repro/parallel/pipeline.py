"""GPipe-style pipeline parallelism via ``shard_map`` + ``ppermute``.

Stage s of S holds its own slice of the layer stack (params stacked on a
leading stage dim, sharded over the pipeline mesh axis). Microbatches stream
through the classic GPipe schedule: T = M + S - 1 ticks; each tick every
stage computes its current microbatch and ``ppermute``s the activation to its
successor. Fixed shapes throughout; reverse-mode AD works (the transpose of a
ppermute is the reverse permute), so the same schedule backpropagates.

This is the optional PP axis for depth-dominant models; the frameworks'
default strategies (FSDP for train, TP/replica for serve) cover the assigned
mesh, and PP composes with them by dedicating the `pod` axis to stages.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import shard_map_compat


def gpipe(stage_fn: Callable, mesh, axis: str, n_stages: int, n_micro: int):
    """Returns pipelined(params_stacked, x_micro) -> y_micro.

    stage_fn(stage_params, x) -> y        (same shape in/out)
    params_stacked: leaves with leading dim n_stages (sharded over `axis`)
    x_micro: (n_micro, ...) microbatches (replicated; only stage 0 consumes)
    """
    assert mesh.shape[axis] == n_stages

    def body(params, xs):
        params = jax.tree.map(lambda a: a[0], params)      # this stage's slice
        stage = jax.lax.axis_index(axis)
        ticks = n_micro + n_stages - 1
        fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        buf = jnp.zeros_like(xs[0])                        # inbound activation
        outs = jnp.zeros_like(xs)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 injects microbatch t; others consume the inbound buffer
            mb = jnp.clip(t, 0, n_micro - 1)
            x_in = jnp.where(stage == 0, xs[mb], buf)
            active = (t >= stage) & (t - stage < n_micro)
            y = stage_fn(params, x_in)
            y = jnp.where(active, y, buf)
            # last stage records its finished microbatch (t - (S-1))
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            record = active & (stage == n_stages - 1)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(record, y, outs[out_idx]), out_idx, 0)
            buf = jax.lax.ppermute(y, axis, fwd)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
        # every stage holds zeros except the last; sum-gather the real outputs
        outs = jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    def pipelined(params_stacked, x_micro):
        in_specs = (jax.tree.map(lambda _: P(axis), params_stacked), P())
        return shard_map_compat(body, mesh, in_specs=in_specs,
                                out_specs=P())(params_stacked, x_micro)

    return pipelined
