"""Divisibility-aware logical-axis → PartitionSpec resolver.

Every tensor carries a tuple of *logical* axis names (one per dim); the
resolver maps them onto mesh axes, dropping or shrinking the mapping whenever
the dim is not divisible by the mesh-axis product or the mesh axis was already
consumed by an earlier dim of the same tensor.  This single mechanism handles
all ten architectures (e.g. mixtral's 8 experts on a 16-way model axis simply
fall through to TP-sharding of d_ff).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh-axis alternatives.  Each value is a tuple of
# ALTERNATIVE tuples tried in order (first divisible wins); a plain tuple of
# strings is treated as a single alternative whose prefixes may shrink.
#
# DEFAULT_RULES = the TP strategy (serving, and huge-model training):
#   batch over (pod, data); weights FSDP(data) × TP(model).
DEFAULT_RULES: Dict[Optional[str], Tuple] = {
    "batch": ("pod", "data"),
    "fsdp": ("data",),
    "fsdp+": ("pod", "data"),     # ZeRO-1-across-pods (optimizer state)
    "tp": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "seq": ("data",),
    "sp": ("model",),             # Megatron-style sequence parallelism
    "layer": (),
    None: (),
}

# FSDP strategy (training of the ≤15B dense archs and MoE training): no
# tensor parallelism — batch is sharded over every mesh axis (falling back to
# (data, model) when the pod axis does not divide), weights are ZeRO-3 over
# (data, model); experts stay on 'model' (the MoE shard_map does EP inside).
FSDP_RULES: Dict[Optional[str], Tuple] = {
    "batch": (("pod", "data", "model"), ("data", "model"), ("pod", "data"),
              ("data",)),
    "fsdp": (("data", "model"), ("data",)),
    "fsdp+": (("pod", "data", "model"), ("pod", "data"), ("data", "model"),
              ("data",)),
    "tp": (),
    "vocab": ("model",),
    "expert": ("model",),
    "seq": ("data",),
    "sp": (),
    "layer": (),
    None: (),
}

# Replica strategy (serving of sub-chip-scale models, e.g. mamba2-780m):
# weights fully replicated, batch over (pod, data); the model axis holds
# independent serving replicas — zero collectives on the critical path.
REPLICA_RULES: Dict[Optional[str], Tuple] = {
    "batch": ("pod", "data"),
    "fsdp": (),
    "fsdp+": (),
    "tp": (),
    "vocab": (),
    "expert": (),
    "seq": ("data",),
    "sp": (),
    "layer": (),
    None: (),
}

STRATEGIES = {"tp": DEFAULT_RULES, "fsdp": FSDP_RULES, "replica": REPLICA_RULES}


def make_mesh_compat(shape: Sequence[int], axis_names: Sequence[str]) -> Mesh:
    """Version-portable device-mesh builder: jax ≥ 0.5 accepts
    ``axis_types=(AxisType.Auto, ...)`` (and some versions require it for the
    implicit-mesh machinery), jax 0.4.x has neither ``AxisType`` nor the
    kwarg.  Auto axis types match 0.4.x semantics exactly, so behavior is
    identical on both sides."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(tuple(shape), tuple(axis_names),
                             axis_types=(AxisType.Auto,) * len(axis_names))
    except (ImportError, TypeError):
        return jax.make_mesh(tuple(shape), tuple(axis_names))


def set_mesh_compat(mesh: Mesh):
    """Version-portable ``with jax.set_mesh(mesh): ...`` context: jax ≥ 0.6
    exposes ``jax.set_mesh`` (usable as a context manager), jax 0.4.x spells
    the same thing as entering the ``Mesh`` itself (the resource-env context
    ``with mesh:``).  Callers must use this as a context manager only."""
    sm = getattr(jax, "set_mesh", None)
    if sm is not None:
        return sm(mesh)
    return mesh                     # Mesh is a context manager on jax 0.4.x


def abstract_mesh(axis_sizes: Sequence[int], axis_names: Sequence[str]):
    """Version-portable AbstractMesh: jax ≤ 0.4.x takes one tuple of
    (name, size) pairs, jax ≥ 0.5 takes (axis_sizes, axis_names)."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))
    except TypeError:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))


def shard_map_compat(f, mesh: Mesh, *, in_specs, out_specs):
    """Version-portable ``shard_map``: jax ≥ 0.5 exposes ``jax.shard_map``
    (replication checking via ``check_vma``), jax 0.4.x ships it as
    ``jax.experimental.shard_map.shard_map`` (``check_rep``).  Replication
    checking is disabled on both — callers (the RFANN mesh substrate, the
    pipeline) end their bodies in explicitly replicated ``all_gather``
    merges, which the static checker cannot always prove."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm_old
        return sm_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
    err = None
    for kw in ({"check_vma": False}, {"check_rep": False}):
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)
        except TypeError as e:                  # other flag spelling
            err = e
    # no flagless fallback: it would silently re-enable the replication
    # check this wrapper exists to disable — fail loudly instead
    raise TypeError("jax.shard_map accepts neither check_vma nor "
                    "check_rep; extend shard_map_compat for this jax "
                    "version") from err


def _alternatives(entry) -> Tuple[Tuple[str, ...], ...]:
    if not entry:
        return ()
    if isinstance(entry[0], str):   # plain tuple -> its prefixes
        return tuple(tuple(entry[:k]) for k in range(len(entry), 0, -1))
    return tuple(tuple(alt) for alt in entry)   # explicit alternatives, as-is


def spec_for_logical(logical: Sequence[Optional[str]],
                     shape: Sequence[int],
                     mesh: Mesh,
                     rules: Optional[Dict] = None) -> P:
    rules = rules or DEFAULT_RULES
    used: set = set()
    parts = []
    for dim, name in zip(shape, logical):
        chosen: Tuple[str, ...] = ()
        for alt in _alternatives(rules.get(name, ())):
            sub = tuple(a for a in alt if a in mesh.shape and a not in used)
            if len(sub) != len(alt):
                continue
            size = math.prod(mesh.shape[a] for a in sub)
            if size > 1 and dim % size == 0:
                chosen = sub
                break
        if chosen:
            used.update(chosen)
            parts.append(chosen if len(chosen) > 1 else chosen[0])
        else:
            parts.append(None)
    return P(*parts)


def tree_shardings(logical_tree, shape_tree, mesh: Mesh, rules=None):
    """Map matching pytrees of logical tuples + shaped values -> NamedShardings."""
    return jax.tree.map(
        lambda lg, sh: NamedSharding(
            mesh, spec_for_logical(lg, sh.shape, mesh, rules)),
        logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def make_act_sharder(mesh: Optional[Mesh], rules=None):
    """Returns hook(x, logical) applying a with_sharding_constraint (no-op off-mesh)."""
    if mesh is None:
        return lambda x, logical: x

    def hook(x, logical):
        spec = spec_for_logical(logical, x.shape, mesh, rules)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return hook


# ----------------------------------------------------------------------
def batch_logical(cfg, shape_kind: str, long_context: bool = False) -> Dict:
    """Logical axes for each input-batch leaf."""
    out = {}
    if shape_kind == "train":
        out["tokens"] = ("batch", None)
        out["labels"] = ("batch", None)
    elif shape_kind == "prefill":
        out["tokens"] = ("batch", None)
    elif shape_kind == "decode":
        out["token"] = ("batch",)
    if cfg.family == "encdec" and shape_kind in ("train", "prefill"):
        out["frames"] = ("batch", None, None)
    if cfg.family == "vlm" and shape_kind in ("train", "prefill"):
        out["patches"] = ("batch", None, None)
    return out


def cache_logical(cfg, long_context: bool = False) -> Dict:
    """Logical axes for the decode-cache leaves (KV seq-sharded in long mode)."""
    seq_ax = "seq" if long_context else None
    out = {}
    if cfg.family in ("dense", "moe", "encdec"):
        out["k"] = ("layer", "batch", seq_ax, "tp", None)
        out["v"] = ("layer", "batch", seq_ax, "tp", None)
    if cfg.family == "vlm":
        out["k"] = ("layer", None, "batch", seq_ax, "tp", None)
        out["v"] = ("layer", None, "batch", seq_ax, "tp", None)
    if cfg.family == "ssm":
        out["state"] = ("layer", "batch", "tp", None, None)
        out["conv"] = ("layer", "batch", None, "tp")
    if cfg.family == "hybrid":
        out["k"] = ("layer", "batch", seq_ax, "tp", None)
        out["v"] = ("layer", "batch", seq_ax, "tp", None)
        out["state"] = ("layer", None, "batch", "tp", None, None)
        out["conv"] = ("layer", None, "batch", None, "tp")
    if cfg.family == "encdec":
        out["ck"] = ("layer", "batch", None, "tp", None)
        out["cv"] = ("layer", "batch", None, "tp", None)
    if cfg.family == "vlm":
        out["ck"] = ("layer", "batch", None, "tp", None)
        out["cv"] = ("layer", "batch", None, "tp", None)
    return out
