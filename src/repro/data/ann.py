"""Synthetic ANN datasets, attribute generators, selectivity-controlled query
ranges (the paper's 2^-i protocol), and brute-force ground truth."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.knn import sq_dists


def make_vectors(n: int, d: int, seed: int = 0, kind: str = "mixture",
                 n_clusters: int = 32) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if kind == "uniform":
        return rng.random((n, d)).astype(np.float32)
    centers = rng.standard_normal((n_clusters, d)).astype(np.float32) * 4.0
    assign = rng.integers(0, n_clusters, n)
    return (centers[assign] +
            rng.standard_normal((n, d)).astype(np.float32)).astype(np.float32)


def make_attrs(n: int, seed: int = 0, kind: str = "uniform") -> np.ndarray:
    rng = np.random.default_rng(seed + 7)
    if kind == "zipf":
        a = rng.zipf(1.5, n).astype(np.float32) + rng.random(n).astype(np.float32)
    elif kind == "normal":
        a = rng.standard_normal(n).astype(np.float32)
    else:
        a = rng.random(n).astype(np.float32)
    # enforce distinct values (paper's tie-break assumption)
    a = a + np.arange(n) * 1e-9
    return a.astype(np.float32)


def selectivity_ranges(attrs: np.ndarray, nq: int, frac: float,
                       seed: int = 0) -> np.ndarray:
    """Random attribute windows covering ~frac·n points each."""
    rng = np.random.default_rng(seed + 13)
    s = np.sort(attrs)
    n = len(s)
    w = max(1, int(round(frac * n)))
    lo_idx = rng.integers(0, n - w + 1, nq)
    out = np.stack([s[lo_idx], s[lo_idx + w - 1]], axis=1)
    return out.astype(np.float32)


def mixed_workload(attrs: np.ndarray, nq: int, seed: int = 0,
                   levels: int = 10) -> Tuple[np.ndarray, np.ndarray]:
    """Paper Exp-1: query set split evenly over selectivities 2^0 .. 2^-(levels-1).
    Returns (ranges (nq,2), level index per query)."""
    per = max(nq // levels, 1)
    ranges, lvl = [], []
    for i in range(levels):
        r = selectivity_ranges(attrs, per, 2.0 ** (-i), seed=seed * levels + i)
        ranges.append(r)
        lvl.extend([i] * per)
    rem = nq - per * levels
    if rem > 0:          # top up with full-range queries so len == nq
        ranges.append(selectivity_ranges(attrs, rem, 1.0, seed=seed * levels - 1))
        lvl.extend([0] * rem)
    out = np.concatenate(ranges)[:nq]
    return out, np.asarray(lvl[:nq])


def ground_truth(vectors: np.ndarray, attrs: np.ndarray, queries: np.ndarray,
                 ranges: np.ndarray, k: int, block: int = 256):
    """Exact range-filtered KNN (the pre-filter/linear-scan baseline)."""
    v = jnp.asarray(vectors, jnp.float32)
    a = jnp.asarray(attrs, jnp.float32)
    ids_out, d_out = [], []
    for i in range(0, len(queries), block):
        q = jnp.asarray(queries[i:i + block], jnp.float32)
        r = jnp.asarray(ranges[i:i + block], jnp.float32)
        d = sq_dists(q, v)
        ok = (a[None, :] >= r[:, :1]) & (a[None, :] <= r[:, 1:2])
        d = jnp.where(ok, d, jnp.inf)
        nd, ni = jax.lax.top_k(-d, k)
        ids_out.append(np.asarray(jnp.where(jnp.isfinite(nd), ni, -1)))
        d_out.append(np.asarray(-nd))
    return np.concatenate(ids_out), np.concatenate(d_out)


def recall_at_k(found: np.ndarray, gt: np.ndarray) -> float:
    """recall@k = |found ∩ gt| / |gt-valid| averaged over queries."""
    tot, hit = 0, 0
    for f, g in zip(found, gt):
        gs = set(int(x) for x in g if x >= 0)
        if not gs:
            continue
        hit += len(gs & set(int(x) for x in f if x >= 0))
        tot += len(gs)
    return hit / max(tot, 1)
