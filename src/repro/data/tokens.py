"""Deterministic, host-shardable synthetic token pipeline with background
prefetch — the training-data substrate.

Design mirrors a production index-based loader: sample `i` of epoch `e` is a
pure function of (seed, e, i), so any host can compute exactly its shard
(host_id, n_hosts) without coordination, restarts are reproducible from the
step counter alone, and straggler re-balancing is just a different
(host_id → index-range) assignment.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass
class TokenStreamConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    n_hosts: int = 1
    host_id: int = 0
    seed: int = 0
    markov_order: bool = True     # structured (learnable) stream vs uniform


class SyntheticTokenStream:
    """Markov-chain token stream: learnable structure so smoke-training loss
    actually decreases; ~uniform fallback for pure-throughput tests."""

    def __init__(self, cfg: TokenStreamConfig):
        assert cfg.global_batch % cfg.n_hosts == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_hosts
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        k = min(v, 64)
        # sparse-ish transition structure shared by all hosts
        self._next = rng.integers(0, v, (v, k)).astype(np.int32)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, step, host) — restart == replay."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + cfg.host_id)
        b, s, v = self.local_batch, cfg.seq_len, cfg.vocab_size
        if not cfg.markov_order:
            toks = rng.integers(0, v, (b, s + 1)).astype(np.int32)
        else:
            toks = np.empty((b, s + 1), np.int32)
            toks[:, 0] = rng.integers(0, v, b)
            choice = rng.integers(0, self._next.shape[1], (b, s))
            for t in range(s):
                toks[:, t + 1] = self._next[toks[:, t], choice[:, t]]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self.iter_from(0)

    def iter_from(self, step0: int) -> Iterator[Dict[str, np.ndarray]]:
        """Resume-aware iteration: restart-from-checkpoint must seek here."""
        step = step0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch (depth-bounded queue) over any iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._err: Optional[BaseException] = None

        def run():
            try:
                for item in it:
                    self._q.put(item)
            except BaseException as e:
                self._err = e
            finally:
                self._q.put(None)

        self._t = threading.Thread(target=run, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            if self._err:
                raise self._err
            raise StopIteration
        return item
