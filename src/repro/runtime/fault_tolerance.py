"""Fault-tolerance runtime: straggler detection, preemption handling,
heartbeat simulation, and cross-pod gradient compression.

On real multi-host TPU jobs these hook into the cluster scheduler; here the
mechanisms are fully implemented and exercised by tests with simulated hosts /
injected delays.
"""
from __future__ import annotations

import signal
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


# ----------------------------------------------------------------------
@dataclass
class StragglerMonitor:
    """EMA step-time outlier detection across (simulated) hosts.

    A host whose per-step EMA exceeds ``threshold`` × the fleet median is
    flagged; the launcher's mitigation is (1) exclude its data shard from the
    next epoch's assignment (work re-balancing) and (2) if it persists for
    ``evict_after`` flags, request checkpoint-and-restart without it
    (elastic downscale — checkpoints are mesh-agnostic)."""
    n_hosts: int
    alpha: float = 0.2
    threshold: float = 1.8
    evict_after: int = 5
    ema: np.ndarray = field(init=False)
    flags: np.ndarray = field(init=False)
    history: deque = field(init=False)

    def __post_init__(self):
        self.ema = np.zeros(self.n_hosts)
        self.flags = np.zeros(self.n_hosts, np.int64)
        self.history = deque(maxlen=512)

    def record(self, host_step_seconds: np.ndarray) -> Dict:
        t = np.asarray(host_step_seconds, float)
        self.ema = np.where(self.ema == 0, t,
                            self.alpha * t + (1 - self.alpha) * self.ema)
        med = float(np.median(self.ema))
        stragglers = np.flatnonzero(self.ema > self.threshold * med)
        self.flags[stragglers] += 1
        self.flags[np.setdiff1d(np.arange(self.n_hosts), stragglers)] = 0
        evict = np.flatnonzero(self.flags >= self.evict_after)
        self.history.append(dict(median=med, stragglers=stragglers.tolist()))
        return dict(median_s=med, stragglers=stragglers.tolist(),
                    evict=evict.tolist())


# ----------------------------------------------------------------------
class PreemptionHandler:
    """SIGTERM → finish the current step, checkpoint, exit cleanly."""

    def __init__(self):
        self.requested = threading.Event()
        self._prev = None

    def install(self):
        self._prev = signal.signal(signal.SIGTERM, self._on_signal)
        return self

    def _on_signal(self, signum, frame):
        self.requested.set()

    def should_stop(self) -> bool:
        return self.requested.is_set()


# ----------------------------------------------------------------------
class Heartbeat:
    """Simulated multi-host liveness: hosts post beats; the coordinator calls
    ``dead_hosts`` to find members silent for > timeout (triggers the elastic
    restart path in the launcher)."""

    def __init__(self, n_hosts: int, timeout: float = 30.0):
        self.last = {h: time.monotonic() for h in range(n_hosts)}
        self.timeout = timeout
        self._lock = threading.Lock()

    def beat(self, host: int, at: Optional[float] = None):
        with self._lock:
            self.last[host] = at if at is not None else time.monotonic()

    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        now = now if now is not None else time.monotonic()
        with self._lock:
            return [h for h, t in self.last.items() if now - t > self.timeout]


# ----------------------------------------------------------------------
def int8_compress_decompress(g: jax.Array) -> jax.Array:
    """Per-tensor symmetric int8 quantize→dequantize (the wire format of the
    cross-pod gradient all-reduce; 4×/2× volume reduction vs f32/bf16).
    Applied as a grad_transform: XLA then all-reduces the (dequantized)
    tensor — bytes accounting for the compressed variant is reported in
    EXPERIMENTS.md §Perf."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return (q.astype(jnp.float32) * scale).astype(g.dtype)


def make_compressed_grad_transform():
    def transform(grads):
        return jax.tree.map(int8_compress_decompress, grads)
    return transform
