"""Segmented, checksummed write-ahead log for the streaming index.

Every mutation of a ``StreamingRFANN`` (insert / delete) is appended here
*before* it is applied in memory, so a crashed server replays the
uncompacted tail instead of silently dropping it.  Design points:

* **Record format** — length-prefixed binary records, each protected by a
  CRC32 over its payload::

      u32 payload_len | u32 crc32(payload) | payload
      payload = u64 lsn | u8 op | op body

  Ops: ``INSERT`` (ext id + attr + f32 vector), ``DELETE`` (ext id),
  ``BARRIER`` (checkpoint generation + the LSN watermark that checkpoint
  covers) and ``SEAL`` (clean shutdown marker).  LSNs are assigned by the
  log, start at 1, and increase by exactly 1 per record — the recovery
  watermark (``manifest["streaming"]["wal_lsn"]``) makes replay
  idempotent: a record with ``lsn <= watermark`` is already inside the
  restored checkpoint and is skipped.

* **Segments** — the log is a directory of ``wal-<seq>.log`` files, each
  opened ``O_APPEND`` and rotated once it exceeds ``segment_bytes``.  The
  parent directory is fsynced on every segment create/rotate, so the
  *names* are as durable as the bytes (a rename/create that is never
  fsynced into its directory can vanish on power loss).  Sealed segments
  entirely behind a barrier's watermark are garbage-collected by
  :meth:`WriteAheadLog.gc`.

* **Sync policy** — ``sync="always"`` fsyncs every append (an
  acknowledged mutation is durable, full stop); ``sync="batch"`` group
  commits: an append fsyncs when ``fsync_every_n`` appends have
  accumulated or ``fsync_interval_s`` seconds have passed since the last
  fsync.  The interval is evaluated lazily, on the *next* append — there
  is no background timer — so when traffic pauses, up to
  ``fsync_every_n - 1`` acknowledged mutations can sit unsynced until
  traffic resumes; callers that pause (or shut down) should call
  :meth:`WriteAheadLog.flush` to close the window.  Crash window = the
  unsynced tail of acknowledged mutations.  ``sync="none"`` never fsyncs
  on the hot path (OS page cache only — crash window unbounded, for
  benchmarking).

* **Torn tails** — :func:`replay` verifies every record's length prefix
  and CRC.  A short read or checksum mismatch marks the *torn point*:
  replay stops there, and :meth:`WriteAheadLog.open_for_append` /
  :func:`replay` with ``truncate=True`` physically truncates the segment
  at the last good record so new appends never interleave with garbage.
  Anything after a tear (including later segments) is discarded — records
  are only meaningful in LSN order.

* **Fault injection** — every durability-relevant syscall goes through an
  injectable :class:`FileOps` layer.  The crash harness
  (``tests/test_wal.py``, ``tools/wal_smoke.py``) swaps in a
  :class:`CrashOps` that dies at the N-th operation, sweeping N across
  the whole insert/delete/compact/checkpoint lifecycle and asserting the
  recovered index is bit-identical to a never-crashed oracle.
"""
from __future__ import annotations

import os
import struct
import threading
import time
import zlib
from dataclasses import dataclass
from pathlib import Path
from typing import Iterator, List, Optional, Tuple

import numpy as np

# record op codes (u8 on the wire)
OP_INSERT = 1
OP_DELETE = 2
OP_BARRIER = 3
OP_SEAL = 4

_HDR = struct.Struct("<II")         # payload_len, crc32(payload)
_LSN_OP = struct.Struct("<QB")      # lsn, op
_INSERT_HDR = struct.Struct("<qfI")  # ext_id, attr, dim
_DELETE_BODY = struct.Struct("<q")   # ext_id
_BARRIER_BODY = struct.Struct("<qQ")  # generation, watermark lsn

SEGMENT_PREFIX = "wal-"
SEGMENT_SUFFIX = ".log"

SYNC_POLICIES = ("always", "batch", "none")


class WALError(RuntimeError):
    """Raised when an append cannot be made durable (disk full, fd gone,
    injected fault, ...).  The streaming layer catches this and degrades
    to read-only serving instead of acknowledging a mutation it cannot
    recover."""


class InjectedCrash(BaseException):
    """Raised by :class:`CrashOps` at its trigger point.  Derives from
    ``BaseException`` so ordinary ``except Exception`` recovery/degrade
    paths in the code under test cannot swallow the simulated crash."""


# --------------------------------------------------------------- file ops
class FileOps:
    """Every syscall the WAL's durability story depends on, in one
    swappable object.  The default is a thin veneer over ``os``; the fault
    harness subclasses it to crash at a chosen operation index."""

    def open_append(self, path: str) -> int:
        return os.open(path, os.O_CREAT | os.O_WRONLY | os.O_APPEND, 0o644)

    def write(self, fd: int, data: bytes) -> int:
        return os.write(fd, data)

    def fsync(self, fd: int) -> None:
        os.fsync(fd)

    def close(self, fd: int) -> None:
        os.close(fd)

    def fsync_dir(self, path: str) -> None:
        from repro.index.io import fsync_dir
        fsync_dir(path)

    def truncate(self, path: str, length: int) -> None:
        with open(path, "r+b") as f:
            f.truncate(length)
            f.flush()
            os.fsync(f.fileno())

    def unlink(self, path: str) -> None:
        os.unlink(path)


class CrashOps(FileOps):
    """Fault-injection layer: counts durability-relevant operations and
    "crashes" (raises :class:`InjectedCrash`, or SIGKILLs the whole
    process when ``hard=True``) once the counter reaches ``crash_at``.

    ``crash_at < 0`` never fires — useful for counting how many ops a
    scenario performs before sweeping ``crash_at`` over that range.
    """

    #: operations that count toward the crash point
    COUNTED = ("write", "fsync", "fsync_dir", "truncate", "unlink",
               "open_append")

    def __init__(self, crash_at: int = -1, *, hard: bool = False):
        self.crash_at = int(crash_at)
        self.hard = bool(hard)
        self.ops = 0
        self.log: List[str] = []

    def _tick(self, name: str) -> None:
        self.ops += 1
        self.log.append(name)
        if 0 <= self.crash_at < self.ops:
            if self.hard:       # a real process death: SIGKILL ourselves
                import signal
                os.kill(os.getpid(), signal.SIGKILL)
            raise InjectedCrash(f"injected crash at op {self.ops} ({name})")

    def open_append(self, path):
        self._tick("open_append")
        return super().open_append(path)

    def write(self, fd, data):
        self._tick("write")
        return super().write(fd, data)

    def fsync(self, fd):
        self._tick("fsync")
        return super().fsync(fd)

    def fsync_dir(self, path):
        self._tick("fsync_dir")
        return super().fsync_dir(path)

    def truncate(self, path, length):
        self._tick("truncate")
        return super().truncate(path, length)

    def unlink(self, path):
        self._tick("unlink")
        return super().unlink(path)


# ---------------------------------------------------------------- records
@dataclass
class WalRecord:
    lsn: int
    op: int
    ext_id: int = -1
    attr: float = 0.0
    vector: Optional[np.ndarray] = None
    generation: int = -1
    watermark: int = 0

    @property
    def op_name(self) -> str:
        return {OP_INSERT: "insert", OP_DELETE: "delete",
                OP_BARRIER: "barrier", OP_SEAL: "seal"}.get(self.op,
                                                            f"op{self.op}")


def _encode(rec: WalRecord) -> bytes:
    body = _LSN_OP.pack(rec.lsn, rec.op)
    if rec.op == OP_INSERT:
        vec = np.ascontiguousarray(rec.vector, np.float32)
        body += _INSERT_HDR.pack(int(rec.ext_id), float(rec.attr), vec.size)
        body += vec.tobytes()
    elif rec.op == OP_DELETE:
        body += _DELETE_BODY.pack(int(rec.ext_id))
    elif rec.op == OP_BARRIER:
        body += _BARRIER_BODY.pack(int(rec.generation), int(rec.watermark))
    elif rec.op != OP_SEAL:
        raise ValueError(f"unknown WAL op {rec.op}")
    return _HDR.pack(len(body), zlib.crc32(body)) + body


def _decode(payload: bytes) -> WalRecord:
    lsn, op = _LSN_OP.unpack_from(payload, 0)
    off = _LSN_OP.size
    rec = WalRecord(lsn=lsn, op=op)
    if op == OP_INSERT:
        ext_id, attr, dim = _INSERT_HDR.unpack_from(payload, off)
        off += _INSERT_HDR.size
        vec = np.frombuffer(payload, np.float32, count=dim, offset=off)
        rec.ext_id, rec.attr, rec.vector = ext_id, attr, vec.copy()
    elif op == OP_DELETE:
        (rec.ext_id,) = _DELETE_BODY.unpack_from(payload, off)
    elif op == OP_BARRIER:
        rec.generation, rec.watermark = _BARRIER_BODY.unpack_from(payload,
                                                                  off)
    elif op != OP_SEAL:
        raise ValueError(f"unknown WAL op {op} at lsn {lsn}")
    return rec


# --------------------------------------------------------------- segments
def _segment_path(d: Path, seq: int) -> Path:
    return d / f"{SEGMENT_PREFIX}{seq:08d}{SEGMENT_SUFFIX}"


def _segment_seq(p: Path) -> int:
    return int(p.name[len(SEGMENT_PREFIX):-len(SEGMENT_SUFFIX)])


def list_segments(wal_dir) -> List[Path]:
    d = Path(wal_dir)
    if not d.is_dir():
        return []
    segs = [p for p in d.iterdir()
            if p.name.startswith(SEGMENT_PREFIX)
            and p.name.endswith(SEGMENT_SUFFIX)]
    return sorted(segs, key=_segment_seq)


def _scan_segment(path: Path) -> Tuple[List[WalRecord], int, bool]:
    """(records, clean_byte_length, torn) for one segment file.  ``torn``
    is True when the file ends in a short/corrupt record — everything up
    to ``clean_byte_length`` parsed fine."""
    recs: List[WalRecord] = []
    data = path.read_bytes()
    off = 0
    n = len(data)
    while off < n:
        if off + _HDR.size > n:
            return recs, off, True                      # short header
        length, crc = _HDR.unpack_from(data, off)
        start = off + _HDR.size
        end = start + length
        if length < _LSN_OP.size or end > n:
            return recs, off, True                      # short payload
        payload = data[start:end]
        if zlib.crc32(payload) != crc:
            return recs, off, True                      # checksum mismatch
        try:
            recs.append(_decode(payload))
        except (ValueError, struct.error):
            return recs, off, True                      # undecodable body
        off = end
    return recs, off, False


def replay(wal_dir, *, truncate: bool = False,
           ops: Optional[FileOps] = None) -> Iterator[WalRecord]:
    """Yield every intact record in LSN order.  A torn record (bad CRC /
    short read) ends the replay at that point; with ``truncate=True`` the
    torn segment is physically truncated at the last good byte and any
    later segments are removed — the log then ends exactly where replay
    ended, so a reopened WAL appends from the torn point."""
    ops = ops or FileOps()
    segs = list_segments(wal_dir)
    for i, seg in enumerate(segs):
        recs, clean_len, torn = _scan_segment(seg)
        yield from recs
        if torn:
            if truncate:
                ops.truncate(str(seg), clean_len)
                for later in segs[i + 1:]:
                    ops.unlink(str(later))
                ops.fsync_dir(str(wal_dir))
            return


def last_lsn(wal_dir) -> int:
    """Highest intact LSN in the log (0 when empty)."""
    lsn = 0
    for rec in replay(wal_dir):
        lsn = max(lsn, rec.lsn)
    return lsn


# -------------------------------------------------------------------- WAL
class WriteAheadLog:
    """Appender half of the log.  One writer per directory; thread-safe
    (appends from the mutation path and barriers from the compaction
    worker share ``_lock``)."""

    def __init__(self, wal_dir, *, sync: str = "batch",
                 fsync_every_n: int = 64, fsync_interval_s: float = 0.05,
                 segment_bytes: int = 4 << 20,
                 ops: Optional[FileOps] = None):
        if sync not in SYNC_POLICIES:
            raise ValueError(f"WriteAheadLog: invalid sync={sync!r} "
                             f"(expected one of {SYNC_POLICIES})")
        if int(fsync_every_n) <= 0:
            raise ValueError(f"WriteAheadLog: invalid "
                             f"fsync_every_n={fsync_every_n} "
                             f"(must be a positive int)")
        self.dir = Path(wal_dir)
        self.sync = sync
        self.fsync_every_n = int(fsync_every_n)
        self.fsync_interval_s = float(fsync_interval_s)
        self.segment_bytes = int(segment_bytes)
        self.ops = ops or FileOps()
        self._lock = threading.Lock()
        self._fd: Optional[int] = None
        self._seg_len = 0
        self._unsynced = 0
        self._last_fsync = time.monotonic()
        self.appends = 0
        self.fsyncs = 0
        self.bytes_written = 0

        created = not self.dir.is_dir()
        self.dir.mkdir(parents=True, exist_ok=True)
        if created:
            parent = self.dir.resolve().parent
            self.ops.fsync_dir(str(parent))     # the dir itself must survive
        # resume after the existing intact tail (truncating any torn one)
        self.next_lsn = 1
        for rec in replay(self.dir, truncate=True, ops=self.ops):
            self.next_lsn = rec.lsn + 1
        segs = list_segments(self.dir)
        self._seq = _segment_seq(segs[-1]) if segs else 0
        if segs:
            self._fd = self.ops.open_append(str(segs[-1]))
            self._seg_len = segs[-1].stat().st_size
        else:
            self._open_segment(0)

    # ------------------------------------------------------------- plumbing
    def _open_segment(self, seq: int) -> None:
        if self._fd is not None:
            self.ops.fsync(self._fd)
            self.ops.close(self._fd)
        self._seq = seq
        self._fd = self.ops.open_append(str(_segment_path(self.dir, seq)))
        self._seg_len = 0
        # a created file name is only durable once its directory is synced
        self.ops.fsync_dir(str(self.dir))

    def _append(self, rec: WalRecord, *, force_sync: bool = False) -> int:
        with self._lock:
            if self._fd is None:
                raise WALError("WriteAheadLog is closed")
            # LSN assignment must share the lock with the write: mutation
            # appends and compaction-thread barriers would otherwise race,
            # producing duplicate LSNs or LSNs out of file order — and
            # replay (file order, skip lsn <= watermark) silently drops a
            # record written after a higher LSN.
            rec.lsn = self.next_lsn
            self.next_lsn += 1
            blob = _encode(rec)
            if self._seg_len and self._seg_len + len(blob) > self.segment_bytes:
                self._open_segment(self._seq + 1)
            try:
                off = 0
                while off < len(blob):
                    n = self.ops.write(self._fd, blob[off:])
                    if n is None or n <= 0:
                        raise WALError(
                            f"WAL short write on segment {self._seq}: "
                            f"{off}/{len(blob)} bytes written")
                    off += n
            except OSError as e:
                raise WALError(f"WAL append failed on segment "
                               f"{self._seq}: {e}") from e
            self._seg_len += len(blob)
            self.appends += 1
            self.bytes_written += len(blob)
            self._unsynced += 1
            now = time.monotonic()
            due = (force_sync or self.sync == "always"
                   or (self.sync == "batch"
                       and (self._unsynced >= self.fsync_every_n
                            or now - self._last_fsync
                            >= self.fsync_interval_s)))
            if due and self.sync != "none":
                try:
                    self.ops.fsync(self._fd)
                except OSError as e:
                    raise WALError(f"WAL fsync failed on segment "
                                   f"{self._seq}: {e}") from e
                self.fsyncs += 1
                self._unsynced = 0
                self._last_fsync = now
            return rec.lsn

    # -------------------------------------------------------------- appends
    def append_insert(self, ext_id: int, attr: float,
                      vector: np.ndarray) -> int:
        return self._append(WalRecord(lsn=0, op=OP_INSERT, ext_id=ext_id,
                                      attr=attr, vector=vector))

    def append_delete(self, ext_id: int) -> int:
        return self._append(WalRecord(lsn=0, op=OP_DELETE, ext_id=ext_id))

    def append_barrier(self, generation: int, watermark: int) -> int:
        """A checkpoint at ``generation`` covers every record with
        ``lsn <= watermark`` — appended *after* the checkpoint's
        manifest-last commit, always fsynced (a barrier that is not
        durable must not authorize garbage collection)."""
        return self._append(WalRecord(lsn=0, op=OP_BARRIER,
                                      generation=generation,
                                      watermark=watermark),
                            force_sync=True)

    def flush(self) -> None:
        """Force the group-commit window closed (fsync pending appends)."""
        with self._lock:
            if self._fd is not None and self._unsynced:
                self.ops.fsync(self._fd)
                self.fsyncs += 1
                self._unsynced = 0
                self._last_fsync = time.monotonic()

    def seal(self) -> None:
        """Clean-shutdown marker: append SEAL, fsync, rotate nothing.
        Idempotent; the log can still be appended to afterwards (the
        marker only tells recovery the previous run exited cleanly)."""
        if self._fd is None:
            return
        self._append(WalRecord(lsn=0, op=OP_SEAL), force_sync=True)

    def rotate(self) -> None:
        """Start a new segment (used by gc tests and the compaction path
        so old segments become collectable)."""
        with self._lock:
            self._open_segment(self._seq + 1)

    def gc(self, watermark: int) -> int:
        """Remove whole segments whose every record is covered by a
        durable checkpoint (``lsn <= watermark``).  The live tail segment
        is never removed.  Returns the number of segments collected."""
        removed = 0
        with self._lock:
            for seg in list_segments(self.dir)[:-1]:    # never the tail
                recs, _, torn = _scan_segment(seg)
                if torn:
                    break                   # tears only happen at the end
                if recs and max(r.lsn for r in recs) > watermark:
                    break                   # first uncovered segment: stop
                self.ops.unlink(str(seg))
                removed += 1
            if removed:
                self.ops.fsync_dir(str(self.dir))
        return removed

    @property
    def segment_count(self) -> int:
        return len(list_segments(self.dir))

    def close(self) -> None:
        with self._lock:
            if self._fd is not None:
                try:
                    self.ops.fsync(self._fd)
                finally:
                    self.ops.close(self._fd)
                    self._fd = None

    def stats(self) -> dict:
        return dict(next_lsn=self.next_lsn, appends=self.appends,
                    fsyncs=self.fsyncs, bytes_written=self.bytes_written,
                    segments=self.segment_count, sync=self.sync)


def describe(wal_dir) -> dict:
    """Human-oriented summary of a log directory (used by tools/tests)."""
    counts = {"insert": 0, "delete": 0, "barrier": 0, "seal": 0}
    lo = hi = 0
    barrier_watermark = 0
    for rec in replay(wal_dir):
        counts[rec.op_name] = counts.get(rec.op_name, 0) + 1
        lo = lo or rec.lsn
        hi = rec.lsn
        if rec.op == OP_BARRIER:
            barrier_watermark = max(barrier_watermark, rec.watermark)
    return dict(first_lsn=lo, last_lsn=hi, counts=counts,
                barrier_watermark=barrier_watermark,
                segments=len(list_segments(wal_dir)))
