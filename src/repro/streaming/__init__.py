"""Streaming ingest over the RNSG index: delta segment + tombstones +
background compaction.  See docs/streaming.md."""
from repro.streaming.delta import DeltaView
from repro.streaming.streaming import BASE_NS, SegmentView, StreamingRFANN

__all__ = ["BASE_NS", "DeltaView", "SegmentView", "StreamingRFANN"]
