"""Streaming ingest over the RNSG index: delta segment + tombstones +
background compaction, made durable by a checksummed write-ahead log.
See docs/streaming.md and docs/durability.md."""
from repro.streaming.delta import DeltaView
from repro.streaming.streaming import (BASE_NS, ReadOnlyIndexError,
                                       SegmentView, StreamingRFANN)
from repro.streaming.wal import (CrashOps, FileOps, InjectedCrash, WALError,
                                 WalRecord, WriteAheadLog)

__all__ = ["BASE_NS", "CrashOps", "DeltaView", "FileOps", "InjectedCrash",
           "ReadOnlyIndexError", "SegmentView", "StreamingRFANN",
           "WALError", "WalRecord", "WriteAheadLog"]
