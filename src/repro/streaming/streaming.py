"""Streaming RFANN: a mutable delta segment layered over the immutable
attribute-sorted RNSG base, with tombstoned deletes and background
compaction.

Segment lifecycle (FreshDiskANN-style window-to-window):

* **base** — an RNSG graph over a frozen snapshot, served through the
  unified ``SearchSubstrate``.  Deletes of base points flip a per-rank
  ``live`` bit (copy-on-write mask, threaded into the kernels as an
  operand): dead nodes remain *traversable* routing nodes for the beam —
  the graph stays navigable — but never leave a search.
* **delta** — a brute-force attribute-sorted buffer (``DeltaView``)
  absorbing inserts, searched exactly via the ``range_scan`` kernel.
  Delta deletes remove the row physically.
* **compaction** — when the delta or the tombstone count outgrows policy,
  a worker thread rebuilds the base from the live set (``build_rnsg`` is
  deterministic: stable attribute argsort over ``live_items()`` order), and
  a short locked swap publishes it.  Mutations that landed during the
  rebuild survive: inserts stay in a residual delta, deletes become
  tombstones on the new base.

Consistency: every search captures one immutable ``SegmentView`` — base
substrate, live mask, delta snapshot — so queries racing mutations or the
compaction swap see a point-in-time corpus, never a torn one.  Per-query
results from both segments combine through the shared ``merge_topk``.

Cache invariant: the live mask is **corpus state, not cache-key state**.
The streaming layer owns a ``SearchCache`` segment (namespace ``"base"``)
and bumps its per-segment epoch (``invalidate_segment``) on every
base-tombstone change and on every compaction; delta results are never
cached.  A compaction therefore invalidates *only* base-keyed rows — other
namespaces sharing the cache (e.g. a co-served static index) keep theirs.

Durability (``docs/durability.md``): with ``wal_dir`` set (constructor
kwarg or :meth:`attach_wal`) every mutation is appended to a checksummed
write-ahead log *before* it is applied, so
:meth:`StreamingRFANN.recover` can restore the last checkpoint
(``repro.index.io``) and replay the uncompacted tail after a crash.
:meth:`checkpoint` persists a snapshot, writes a ``BARRIER`` record after
the manifest-last commit, and garbage-collects WAL segments the
checkpoint covers; a WAL append failure flips the index to **read-only**
(mutations raise :class:`ReadOnlyIndexError`, the ``stream_read_only``
gauge goes to 1) instead of acknowledging writes it cannot recover.
"""
from __future__ import annotations

import threading
import time
import warnings
from pathlib import Path
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.construction import build_rnsg
from repro.search import (SearchRequest, SearchResult, SearchSubstrate,
                          merge_topk)
from repro.streaming import wal as walmod
from repro.streaming.delta import DeltaView
from repro.streaming.wal import WALError, WriteAheadLog

BASE_NS = "base"        # the cache namespace every base dispatch keys under


class ReadOnlyIndexError(RuntimeError):
    """A mutation was rejected because the index degraded to read-only
    serving (its WAL could no longer make writes durable).  Searches keep
    working; the serve loop reports the error instead of crashing."""


class SegmentView:
    """One immutable published snapshot of the two-segment corpus."""

    __slots__ = ("sub", "base_vecs", "base_attrs", "base_ids", "base_live",
                 "n_tombstones", "delta", "version")

    def __init__(self, sub: SearchSubstrate, base_vecs, base_attrs, base_ids,
                 base_live, n_tombstones: int, delta: DeltaView,
                 version: int):
        self.sub = sub
        self.base_vecs = base_vecs      # (nb, d) f32, rank order
        self.base_attrs = base_attrs    # (nb,) f32 ascending
        self.base_ids = base_ids        # (nb,) int32 external ids
        self.base_live = base_live      # (nb,) bool — False = tombstoned
        self.n_tombstones = n_tombstones
        self.delta = delta
        self.version = version

    @property
    def n_live(self) -> int:
        return int(len(self.base_ids)) - self.n_tombstones + self.delta.count


class StreamingRFANN:
    """Streaming wrapper: RNSG base + brute-force delta + compaction.

    Deliberately exposes **no** ``rank_range`` — ranks shift with every
    mutation, so the engine's pipelined resolver must not resolve ahead of
    the snapshot; ``RFANNEngine`` detects this and falls back to
    ``search(queries, attr_ranges)``, which resolves both segments
    atomically under one captured view.
    """

    def __init__(self, vectors: np.ndarray, attrs: np.ndarray, *,
                 ids: Optional[np.ndarray] = None,
                 max_delta: int = 1024, compact_every: int = 0,
                 wal_dir: Optional[str] = None, wal_sync: str = "batch",
                 wal_fsync_every_n: int = 64,
                 wal_fsync_interval_s: float = 0.05,
                 **build_kw):
        vectors = np.asarray(vectors, np.float32)
        attrs = np.asarray(attrs, np.float32)
        n, d = vectors.shape
        ext = (np.arange(n, dtype=np.int32) if ids is None
               else np.asarray(ids, np.int32))
        self.d = d
        self._build_kw = dict(build_kw)
        self._lock = threading.RLock()
        self._cache = None
        self._metrics = None
        self._precisions: set = set()
        self._init_mutable_defaults()
        self.set_compaction_policy(max_delta=max_delta,
                                   compact_every=compact_every)
        self._next_id = int(ext.max()) + 1 if n else 0
        self._view = self._build_view(vectors, attrs, ext,
                                      DeltaView.empty(d), version=0)
        self._id_loc: Dict[int, int] = {}   # ext id -> base rank | -1 (delta)
        self._reindex(self._view)
        if wal_dir is not None:
            self.attach_wal(wal_dir, sync=wal_sync,
                            fsync_every_n=wal_fsync_every_n,
                            fsync_interval_s=wal_fsync_interval_s)

    def _init_mutable_defaults(self) -> None:
        """State shared by ``__init__`` and ``from_state``."""
        self.max_delta = 1024
        self.compact_every = 0
        self._ops_since_compact = 0
        self._compacting = threading.Event()
        self._worker: Optional[threading.Thread] = None
        self.compactions = 0
        self.build_seconds = 0.0
        self._wal: Optional[WriteAheadLog] = None
        self._ckpt_path: Optional[str] = None
        self._ckpt_shards = 1
        self.applied_lsn = 0        # checkpoint watermark: highest applied
        self.read_only = False
        self.read_only_reason = ""
        self._replaying = False

    # ------------------------------------------------------------ restore
    @classmethod
    def from_state(cls, *, base_vecs, base_attrs, base_ids, base_live,
                   base_nbrs, base_rmq, base_dist_c,
                   delta_vecs, delta_attrs, delta_ids,
                   next_id: int, max_delta: int = 1024,
                   compact_every: int = 0, precisions=(),
                   build_kw=None, wal_lsn: int = 0) -> "StreamingRFANN":
        """Rehydrate from checkpointed segment state (``repro.index.io``)
        **without rebuilding the base graph** — the saved adjacency / RMQ /
        entry arrays go straight into a fresh ``SearchSubstrate``, so
        restore cost is array upload, not O(n²) construction.

        ``precisions`` are recorded for compaction re-install; the caller
        preloads saved quantized corpora via ``sub.preload_quantized`` (or
        first quantized use lazily rebuilds them — identical either way,
        quantization is deterministic in the base vectors).  Tombstones and
        the delta snapshot resume exactly; compaction counters restart at
        zero (they are run-scoped observability, not corpus state)."""
        base_vecs = np.asarray(base_vecs, np.float32)
        self = cls.__new__(cls)
        self.d = int(base_vecs.shape[1])
        self._build_kw = dict(build_kw or {})
        self._lock = threading.RLock()
        self._cache = None
        self._metrics = None
        self._precisions = set(precisions)
        self._init_mutable_defaults()
        self.set_compaction_policy(max_delta=max_delta,
                                   compact_every=compact_every)
        self.applied_lsn = int(wal_lsn)
        base_ids = np.asarray(base_ids, np.int32)
        sub = SearchSubstrate(base_vecs, base_nbrs, base_rmq, base_dist_c,
                              order=base_ids, attrs=base_attrs,
                              cache=None, cache_ns=BASE_NS, metrics=None)
        delta = DeltaView(np.asarray(delta_vecs, np.float32),
                          np.asarray(delta_attrs, np.float32),
                          np.asarray(delta_ids, np.int32))
        live = np.asarray(base_live, bool)
        self._view = SegmentView(sub, base_vecs,
                                 np.asarray(base_attrs, np.float32),
                                 base_ids, live, int((~live).sum()),
                                 delta, version=0)
        self._next_id = int(next_id)
        self._id_loc = {}
        self._reindex(self._view)
        return self

    # ------------------------------------------------------------ builders
    def _build_view(self, vectors, attrs, ext_ids, delta: DeltaView, *,
                    version: int, old_sub: Optional[SearchSubstrate] = None,
                    base_live: Optional[np.ndarray] = None) -> SegmentView:
        """Build an RNSG base over (vectors, attrs) and wrap it in a view.
        ``build_rnsg`` stable-sorts by attribute, so the result — and every
        search over it — is a deterministic function of the input order."""
        g = build_rnsg(vectors, attrs, **self._build_kw)
        self.build_seconds += g.build_seconds
        base_ids = np.asarray(ext_ids, np.int32)[g.order]
        sub = SearchSubstrate(g.vecs, g.nbrs, g.rmq, g.dist_c,
                              order=base_ids, attrs=g.attrs,
                              cache=self._cache, cache_ns=BASE_NS,
                              metrics=self._metrics)
        if old_sub is not None:     # carry the calibrated cost model across
            sub.planner.cost = old_sub.planner.cost
            sub.planner.calibration_epoch = old_sub.planner.calibration_epoch
        for prec in self._precisions:
            sub.install_quantized(prec)
        if base_live is None:
            base_live = np.ones(len(base_ids), bool)
        return SegmentView(sub, g.vecs, g.attrs, base_ids, base_live,
                           int((~base_live).sum()), delta, version)

    def _reindex(self, v: SegmentView) -> None:
        loc = {int(e): r for r, e in enumerate(v.base_ids)
               if v.base_live[r]}
        for e in v.delta.ids:
            loc[int(e)] = -1
        self._id_loc = loc

    # ----------------------------------------------------------- plumbing
    @property
    def planner(self):
        return self._view.sub.planner

    def install_cache(self, cache) -> None:
        with self._lock:
            self._cache = cache
            self._view.sub.cache = cache

    def install_metrics(self, metrics) -> None:
        with self._lock:
            self._metrics = metrics
            self._view.sub.metrics = metrics
            if metrics is not None:
                m = metrics
                self._m_ins = m.counter("stream_inserts_total",
                                        "streaming inserts")
                self._m_del = m.counter("stream_deletes_total",
                                        "streaming deletes")
                self._m_comp = m.counter("stream_compactions_total",
                                         "delta->base compactions")
                self._m_dsize = m.gauge("stream_delta_size",
                                        "rows in the delta segment")
                self._m_tomb = m.gauge("stream_tombstones",
                                       "tombstoned base rows")
                self._m_dfrac = m.histogram(
                    "stream_delta_frac",
                    "delta fraction of the live corpus at search time",
                    lo=1e-4, hi=1.0, growth=1.5)
                self._m_pause = m.histogram(
                    "stream_compaction_pause_ms",
                    "locked swap pause per compaction (ms)")
                self._m_build = m.histogram(
                    "stream_compaction_build_ms",
                    "off-lock rebuild wall per compaction (ms)")
                self._m_ro = m.gauge(
                    "stream_read_only",
                    "1 when mutations are rejected (WAL append failed)")
                self._m_ro.set(1 if self.read_only else 0)
                m.register_producer("streaming", self.stats)
                if self._wal is not None:
                    m.register_producer("wal", self._wal.stats)

    def install_quantized(self, precision: str) -> None:
        """Record the precision (compaction re-installs it on every rebuilt
        base) and build the quantized corpus on the current base."""
        if precision == "f32":
            return
        with self._lock:
            self._precisions.add(precision)
            self._view.sub.install_quantized(precision)

    def set_compaction_policy(self, max_delta: Optional[int] = None,
                              compact_every: Optional[int] = None) -> None:
        """Validated: ``max_delta`` must be a positive int (a value <= 0
        would make every insert immediately compaction-due, wedging
        ``_maybe_compact`` into a compact-per-op loop) and
        ``compact_every`` must be >= 0 (0 disables the every-N-ops
        trigger)."""
        if max_delta is not None:
            max_delta = int(max_delta)
            if max_delta <= 0:
                raise ValueError(f"set_compaction_policy: invalid "
                                 f"max_delta={max_delta} (must be a "
                                 f"positive int)")
            self.max_delta = max_delta
        if compact_every is not None:
            compact_every = int(compact_every)
            if compact_every < 0:
                raise ValueError(f"set_compaction_policy: invalid "
                                 f"compact_every={compact_every} (must be "
                                 f">= 0; 0 disables the every-N trigger)")
            self.compact_every = compact_every

    # ------------------------------------------------------------ WAL
    def attach_wal(self, wal_dir, *, sync: str = "batch",
                   fsync_every_n: int = 64, fsync_interval_s: float = 0.05,
                   segment_bytes: int = 4 << 20, ops=None) -> None:
        """Open (or resume) the write-ahead log at ``wal_dir``.  From this
        point every mutation is appended — and made durable per the sync
        policy — *before* it is applied in memory.  Attaching the same
        directory twice is a no-op; attaching a different one while a WAL
        is open is an error (two logs cannot both be the truth)."""
        with self._lock:
            if self._wal is not None:
                if Path(wal_dir).resolve() == self._wal.dir.resolve():
                    return
                raise ValueError(f"attach_wal: a WAL is already attached "
                                 f"at {self._wal.dir}; refusing to switch "
                                 f"to {wal_dir}")
            w = WriteAheadLog(wal_dir, sync=sync,
                              fsync_every_n=fsync_every_n,
                              fsync_interval_s=fsync_interval_s,
                              segment_bytes=segment_bytes, ops=ops)
            # an attach over an existing log resumes after its tail: the
            # caller is expected to have replayed it (recover); appending
            # below the tail would fork LSN history
            if w.next_lsn - 1 > self.applied_lsn and self._id_loc:
                warnings.warn(
                    f"attach_wal: {wal_dir} already holds records up to "
                    f"lsn {w.next_lsn - 1} but only {self.applied_lsn} "
                    f"were applied — did you mean StreamingRFANN.recover?")
            self._wal = w
            self.applied_lsn = max(self.applied_lsn, w.next_lsn - 1)
        if self._metrics is not None:
            self._metrics.register_producer("wal", self._wal.stats)

    def set_checkpoint_path(self, path, *, shards: int = 1,
                            ensure: bool = True) -> None:
        """Register where :meth:`checkpoint` (and the automatic one after
        every compaction) persists the index.  With ``ensure=True`` a
        baseline checkpoint is written immediately when none exists yet —
        recovery needs *some* checkpoint to replay the WAL onto, so a
        crash before the first compaction/shutdown must still find one."""
        from repro.index import io
        self._ckpt_path = str(path)
        self._ckpt_shards = int(shards)
        if ensure and not io.is_index_dir(self._ckpt_path):
            self.checkpoint()

    def checkpoint(self, path=None, *, shards: Optional[int] = None) -> dict:
        """Persist a crash-consistent snapshot and advance the WAL.

        Order matters and is the whole point:

        1. ``save_index`` — array files first, ``manifest.json`` last
           (the atomic commit point), every rename fsynced into its
           directory.  The manifest carries the snapshot's WAL watermark.
        2. ``BARRIER(generation, watermark)`` appended (fsynced) — only a
           *committed* checkpoint may authorize dropping log history.
        3. WAL segments entirely at or below the watermark are
           garbage-collected.

        A crash between any two steps is safe: recovery either replays a
        longer tail onto the previous checkpoint (idempotent via the
        watermark) or finds the new checkpoint with a tail that is merely
        shorter than the log's retained history."""
        path = path if path is not None else self._ckpt_path
        if path is None:
            raise ValueError("checkpoint: no path given and no "
                             "set_checkpoint_path registered")
        shards = int(shards) if shards is not None else self._ckpt_shards
        from repro.index import io
        man = io.save_index(self, path, shards=shards)
        wal = self._wal
        if wal is not None:
            watermark = int(man["index"]["streaming"]["wal_lsn"])
            wal.rotate()        # seal the tail so covered segments free up
            wal.append_barrier(int(man.get("gen", 0)), watermark)
            wal.gc(watermark)
        return man

    @classmethod
    def recover(cls, index_path, wal_dir, *, sync: str = "batch",
                fsync_every_n: int = 64, fsync_interval_s: float = 0.05,
                ops=None, attach: bool = True,
                **load_kw) -> "StreamingRFANN":
        """Crash-consistent restart: restore the checkpoint at
        ``index_path`` (``repro.index.io`` directory format), replay the
        WAL tail past the checkpoint's watermark (idempotently — records
        at or below it are skipped; a torn tail record truncates the log
        there), then re-attach the WAL so serving continues appending
        where the crashed process stopped."""
        from repro.index import io
        idx = io.load_index(index_path, **load_kw)
        if not isinstance(idx, cls):
            raise TypeError(f"recover: index at {index_path} is "
                            f"{type(idx).__name__}, not StreamingRFANN — "
                            f"only streaming indexes have a WAL to replay")
        idx.replay_wal(wal_dir, ops=ops)
        if attach:
            idx.attach_wal(wal_dir, sync=sync, fsync_every_n=fsync_every_n,
                           fsync_interval_s=fsync_interval_s, ops=ops)
            idx._ckpt_path = str(index_path)
        return idx

    def replay_wal(self, wal_dir, *, ops=None) -> int:
        """Apply every intact WAL record with ``lsn > applied_lsn``;
        returns the number of mutations applied.  Idempotent on top of
        the watermark too (an insert whose id is already live / a delete
        of a non-live id is skipped, so a double replay cannot corrupt).
        Torn tail records truncate the log at the last good byte.
        Compaction is suppressed during replay and re-evaluated once at
        the end — replay is state reconstruction, not load."""
        applied = 0
        with self._lock:
            self._replaying = True
            try:
                for rec in walmod.replay(wal_dir, truncate=True, ops=ops):
                    if rec.lsn <= self.applied_lsn:
                        continue            # already inside the checkpoint
                    if rec.op == walmod.OP_INSERT:
                        ext = int(rec.ext_id)
                        # next_id must advance even over skipped records:
                        # the original run acknowledged this id
                        self._next_id = max(self._next_id, ext + 1)
                        if ext not in self._id_loc:
                            self._apply_insert(rec.vector, float(rec.attr),
                                               ext)
                        applied += 1
                    elif rec.op == walmod.OP_DELETE:
                        ext = int(rec.ext_id)
                        if ext in self._id_loc:
                            self._apply_delete(ext)
                        applied += 1
                    # BARRIER / SEAL: bookkeeping only
                    self.applied_lsn = rec.lsn
            finally:
                self._replaying = False
        self._maybe_compact()
        return applied

    def _wal_append(self, append_fn) -> None:
        """Append one mutation record (called under the index lock, so
        LSN order == apply order — replay reproduces the live sequence
        exactly).  A failed append flips the index read-only *before*
        raising: a mutation that cannot be made recoverable must never be
        acknowledged."""
        if self._wal is None or self._replaying:
            return
        try:
            lsn = append_fn()
        except WALError as e:
            self._enter_read_only(str(e))
            raise ReadOnlyIndexError(
                f"index is read-only: WAL append failed ({e}); serving "
                f"continues, mutations are rejected") from e
        self.applied_lsn = lsn

    def _enter_read_only(self, reason: str) -> None:
        self.read_only = True
        self.read_only_reason = reason
        if self._metrics is not None:
            self._m_ro.set(1)
        warnings.warn(f"StreamingRFANN degraded to read-only: {reason}")

    def _check_writable(self) -> None:
        if self.read_only:
            raise ReadOnlyIndexError(
                f"index is read-only ({self.read_only_reason}); mutations "
                f"are rejected until the WAL is writable again")

    # ---------------------------------------------------------- mutations
    def insert(self, vector: np.ndarray, attr: float,
               ext_id: Optional[int] = None) -> int:
        """Append one point to the delta segment; returns its external id.
        O(delta) host work (stable re-sort); no base cache invalidation —
        delta results are never cached.  With a WAL attached the record is
        logged *before* the in-memory apply — returning from this method
        means the insert is recoverable (to the attached sync policy)."""
        with self._lock:
            self._check_writable()
            if ext_id is None:
                ext_id = self._next_id
            ext_id = int(ext_id)
            if ext_id in self._id_loc:
                raise ValueError(f"id {ext_id} is already live")
            vec = np.asarray(vector, np.float32)
            self._wal_append(lambda: self._wal.append_insert(
                ext_id, float(attr), vec))
            self._next_id = max(self._next_id, ext_id + 1)
            self._apply_insert(vec, float(attr), ext_id)
        self._maybe_compact()
        return ext_id

    def delete(self, ext_id: int) -> None:
        """Remove one live point.  Base points tombstone (the node stays a
        routing node until the next compaction) and invalidate the base
        cache segment; delta points vanish physically.  WAL-logged before
        apply, like :meth:`insert`."""
        with self._lock:
            self._check_writable()
            ext_id = int(ext_id)
            if ext_id not in self._id_loc:
                raise KeyError(f"id {ext_id} is not live")
            self._wal_append(lambda: self._wal.append_delete(ext_id))
            self._apply_delete(ext_id)
        self._maybe_compact()

    def _apply_insert(self, vector: np.ndarray, attr: float,
                      ext_id: int) -> None:
        """In-memory half of an insert — shared by the live path and WAL
        replay (replay must mutate state identically, minus re-logging).
        Caller holds the lock and has validated/logged."""
        v = self._view
        delta = v.delta.with_inserted(np.asarray(vector, np.float32),
                                      float(attr), ext_id)
        self._view = SegmentView(v.sub, v.base_vecs, v.base_attrs,
                                 v.base_ids, v.base_live,
                                 v.n_tombstones, delta, v.version + 1)
        self._id_loc[ext_id] = -1
        self._ops_since_compact += 1
        if self._metrics is not None:
            self._m_ins.inc()
            self._m_dsize.set(delta.count)

    def _apply_delete(self, ext_id: int) -> None:
        """In-memory half of a delete — shared by live path and replay."""
        loc = self._id_loc.pop(ext_id)
        v = self._view
        if loc < 0:             # delta row: physical remove
            delta = v.delta.without(ext_id)
            self._view = SegmentView(v.sub, v.base_vecs, v.base_attrs,
                                     v.base_ids, v.base_live,
                                     v.n_tombstones, delta,
                                     v.version + 1)
            if self._metrics is not None:
                self._m_dsize.set(delta.count)
        else:                   # base rank: copy-on-write tombstone
            live = v.base_live.copy()
            live[loc] = False
            self._view = SegmentView(v.sub, v.base_vecs, v.base_attrs,
                                     v.base_ids, live,
                                     v.n_tombstones + 1, v.delta,
                                     v.version + 1)
            if self._cache is not None:
                self._cache.invalidate_segment(BASE_NS)
            if self._metrics is not None:
                self._m_tomb.set(v.n_tombstones + 1)
        self._ops_since_compact += 1
        if self._metrics is not None:
            self._m_del.inc()

    # ------------------------------------------------------------- search
    def search(self, queries: np.ndarray, attr_ranges: np.ndarray, *,
               k: int = 10, ef: int = 64, plan: str = "auto",
               beam_width: int = 1, precision: str = "f32",
               use_kernel: bool = False, trace=None) -> SearchResult:
        """Range-filtered kNN over base ∪ delta at one captured snapshot.
        Returns external ids.  Resolve happens per segment *inside* the
        snapshot (this is why there is no ``rank_range``)."""
        v = self._view                      # lock-free snapshot capture
        qv = np.atleast_2d(np.asarray(queries, np.float32))
        ar = np.atleast_2d(np.asarray(attr_ranges, np.float32))
        ef = max(ef, k)
        lo, hi = v.sub.resolve(ar)
        req = SearchRequest(
            queries=qv, lo=lo, hi=hi, k=k, ef=ef, strategy=plan,
            use_kernel=use_kernel, beam_width=beam_width,
            precision=precision, trace=trace,
            live=v.base_live if v.n_tombstones else None)
        pending = v.sub.dispatch(req, defer=True)
        delta_res = v.delta.search(qv, ar, k)
        base = pending.result()
        if self._metrics is not None and v.n_live:
            self._m_dfrac.observe(v.delta.count / v.n_live)
        stats = dict(base.stats)
        stats.update(delta_size=v.delta.count, tombstones=v.n_tombstones,
                     version=v.version)
        if delta_res is None:
            return SearchResult(base.ids, base.dists, stats,
                                trace=base.trace)
        di, dd = delta_res
        all_i = np.stack([np.asarray(base.ids, np.int32), di])
        all_d = np.stack([np.where(base.ids >= 0, base.dists, np.inf), dd])
        ids, dists = merge_topk(jnp.asarray(all_i), jnp.asarray(all_d), k)
        return SearchResult(np.asarray(ids), np.asarray(dists), stats,
                            trace=base.trace)

    # --------------------------------------------------------- compaction
    def _maybe_compact(self) -> None:
        if self._compacting.is_set():
            return
        v = self._view
        due = (v.delta.count >= self.max_delta
               or (self.compact_every
                   and self._ops_since_compact >= self.compact_every))
        if due:
            self.compact(wait=False)

    def compact(self, wait: bool = True) -> bool:
        """Rebuild the base from the live set on a worker thread and
        hot-swap it.  Returns False when a compaction is already running
        or there is nothing to fold in."""
        with self._lock:
            if self._compacting.is_set():
                if wait and self._worker is not None:
                    w = self._worker
                else:
                    return False
            else:
                v = self._view
                if v.delta.count == 0 and v.n_tombstones == 0:
                    return False
                if v.n_live < 8:    # tombstone masks stay correct; a graph
                    return False    # over <8 points is not worth building
                self._compacting.set()
                self._ops_since_compact = 0
                w = threading.Thread(target=self._compact_run, args=(v,),
                                     daemon=True)
                self._worker = w
                w.start()
        if wait:
            w.join()
        return True

    def _compact_run(self, v0: SegmentView) -> None:
        try:
            t0 = time.perf_counter()
            keep = v0.base_live
            cat_vecs = np.concatenate([v0.base_vecs[keep], v0.delta.vecs])
            cat_attrs = np.concatenate([v0.base_attrs[keep],
                                        v0.delta.attrs])
            cat_ids = np.concatenate([v0.base_ids[keep], v0.delta.ids])
            # slow part — entirely off-lock; mutations keep landing on the
            # published view and are reconciled at the swap below
            new = self._build_view(cat_vecs, cat_attrs, cat_ids,
                                   DeltaView.empty(self.d),
                                   version=0, old_sub=v0.sub)
            build_ms = (time.perf_counter() - t0) * 1e3
            t1 = time.perf_counter()
            with self._lock:
                cur = self._view
                # ids live *now* (deletes during the rebuild win)
                live_now = np.concatenate(
                    [cur.base_ids[cur.base_live], cur.delta.ids])
                base_live = np.isin(new.base_ids, live_now)
                # inserts during the rebuild stay as the residual delta
                folded = np.isin(cur.delta.ids, cat_ids)
                residual = cur.delta.subset(~folded)
                swapped = SegmentView(new.sub, new.base_vecs,
                                      new.base_attrs, new.base_ids,
                                      base_live, int((~base_live).sum()),
                                      residual, cur.version + 1)
                v0.sub.cache = None     # old segment: no new lookups;
                if self._cache is not None:     # late stores are fenced by
                    self._cache.invalidate_segment(BASE_NS)  # the epoch bump
                self._view = swapped
                self._reindex(swapped)
                self.compactions += 1
            pause_ms = (time.perf_counter() - t1) * 1e3
            if self._metrics is not None:
                self._m_comp.inc()
                self._m_pause.observe(pause_ms)
                self._m_build.observe(build_ms)
                self._m_dsize.set(residual.count)
                self._m_tomb.set(swapped.n_tombstones)
            # checkpoint-after-compaction: the folded state is exactly what
            # the WAL no longer needs to retain, so persist it and let
            # checkpoint() write the barrier + GC covered segments.  A
            # failed checkpoint is not fatal — writes stayed durable in the
            # WAL, the log just keeps more history until the next success.
            if self._ckpt_path is not None and self._wal is not None:
                try:
                    self.checkpoint()
                except Exception as e:      # noqa: BLE001 — degrade, log
                    warnings.warn(f"post-compaction checkpoint to "
                                  f"{self._ckpt_path} failed: {e}")
        finally:
            self._compacting.clear()

    def close(self) -> None:
        """Wait out any in-flight compaction, then seal and close the WAL
        (tests and serve teardown).  The SEAL record marks a clean
        shutdown; recovery treats its absence as a crash (which is also
        fine — that is the whole design — it just replays more carefully
        truncating any torn tail)."""
        w = self._worker
        if w is not None and w.is_alive():
            w.join(timeout=30.0)
        with self._lock:
            if self._wal is not None:
                try:
                    self._wal.seal()
                except WALError:
                    pass        # a dead disk at shutdown changes nothing
                self._wal.close()
                self._wal = None

    # ------------------------------------------------------------- export
    def live_items(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(vecs, attrs, ids) of every live point, in exactly the order a
        compaction would feed ``build_rnsg`` — a fresh offline build on
        this tuple is bit-identical to the post-compaction base."""
        v = self._view
        keep = v.base_live
        return (np.concatenate([v.base_vecs[keep], v.delta.vecs]),
                np.concatenate([v.base_attrs[keep], v.delta.attrs]),
                np.concatenate([v.base_ids[keep], v.delta.ids]))

    def stats(self) -> dict:
        v = self._view
        nb = len(v.base_ids)
        return dict(n_base=nb, n_delta=v.delta.count,
                    tombstones=v.n_tombstones, n_live=v.n_live,
                    delta_frac=v.delta.count / max(v.n_live, 1),
                    version=v.version, compactions=self.compactions,
                    build_seconds=self.build_seconds,
                    wal_lsn=int(self.applied_lsn),
                    read_only=int(self.read_only))

    @property
    def index_bytes(self) -> int:
        v = self._view
        sub = v.sub
        return int(sub._nbrs.nbytes + sub._rmq.nbytes + sub._dist_c.nbytes
                   + v.delta.vecs.nbytes)
