"""Streaming RFANN: a mutable delta segment layered over the immutable
attribute-sorted RNSG base, with tombstoned deletes and background
compaction.

Segment lifecycle (FreshDiskANN-style window-to-window):

* **base** — an RNSG graph over a frozen snapshot, served through the
  unified ``SearchSubstrate``.  Deletes of base points flip a per-rank
  ``live`` bit (copy-on-write mask, threaded into the kernels as an
  operand): dead nodes remain *traversable* routing nodes for the beam —
  the graph stays navigable — but never leave a search.
* **delta** — a brute-force attribute-sorted buffer (``DeltaView``)
  absorbing inserts, searched exactly via the ``range_scan`` kernel.
  Delta deletes remove the row physically.
* **compaction** — when the delta or the tombstone count outgrows policy,
  a worker thread rebuilds the base from the live set (``build_rnsg`` is
  deterministic: stable attribute argsort over ``live_items()`` order), and
  a short locked swap publishes it.  Mutations that landed during the
  rebuild survive: inserts stay in a residual delta, deletes become
  tombstones on the new base.

Consistency: every search captures one immutable ``SegmentView`` — base
substrate, live mask, delta snapshot — so queries racing mutations or the
compaction swap see a point-in-time corpus, never a torn one.  Per-query
results from both segments combine through the shared ``merge_topk``.

Cache invariant: the live mask is **corpus state, not cache-key state**.
The streaming layer owns a ``SearchCache`` segment (namespace ``"base"``)
and bumps its per-segment epoch (``invalidate_segment``) on every
base-tombstone change and on every compaction; delta results are never
cached.  A compaction therefore invalidates *only* base-keyed rows — other
namespaces sharing the cache (e.g. a co-served static index) keep theirs.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.construction import build_rnsg
from repro.search import (SearchRequest, SearchResult, SearchSubstrate,
                          merge_topk)
from repro.streaming.delta import DeltaView

BASE_NS = "base"        # the cache namespace every base dispatch keys under


class SegmentView:
    """One immutable published snapshot of the two-segment corpus."""

    __slots__ = ("sub", "base_vecs", "base_attrs", "base_ids", "base_live",
                 "n_tombstones", "delta", "version")

    def __init__(self, sub: SearchSubstrate, base_vecs, base_attrs, base_ids,
                 base_live, n_tombstones: int, delta: DeltaView,
                 version: int):
        self.sub = sub
        self.base_vecs = base_vecs      # (nb, d) f32, rank order
        self.base_attrs = base_attrs    # (nb,) f32 ascending
        self.base_ids = base_ids        # (nb,) int32 external ids
        self.base_live = base_live      # (nb,) bool — False = tombstoned
        self.n_tombstones = n_tombstones
        self.delta = delta
        self.version = version

    @property
    def n_live(self) -> int:
        return int(len(self.base_ids)) - self.n_tombstones + self.delta.count


class StreamingRFANN:
    """Streaming wrapper: RNSG base + brute-force delta + compaction.

    Deliberately exposes **no** ``rank_range`` — ranks shift with every
    mutation, so the engine's pipelined resolver must not resolve ahead of
    the snapshot; ``RFANNEngine`` detects this and falls back to
    ``search(queries, attr_ranges)``, which resolves both segments
    atomically under one captured view.
    """

    def __init__(self, vectors: np.ndarray, attrs: np.ndarray, *,
                 ids: Optional[np.ndarray] = None,
                 max_delta: int = 1024, compact_every: int = 0,
                 **build_kw):
        vectors = np.asarray(vectors, np.float32)
        attrs = np.asarray(attrs, np.float32)
        n, d = vectors.shape
        ext = (np.arange(n, dtype=np.int32) if ids is None
               else np.asarray(ids, np.int32))
        self.d = d
        self._build_kw = dict(build_kw)
        self._lock = threading.RLock()
        self._cache = None
        self._metrics = None
        self._precisions: set = set()
        self.max_delta = int(max_delta)
        self.compact_every = int(compact_every)
        self._ops_since_compact = 0
        self._next_id = int(ext.max()) + 1 if n else 0
        self._compacting = threading.Event()
        self._worker: Optional[threading.Thread] = None
        self.compactions = 0
        self.build_seconds = 0.0
        self._view = self._build_view(vectors, attrs, ext,
                                      DeltaView.empty(d), version=0)
        self._id_loc: Dict[int, int] = {}   # ext id -> base rank | -1 (delta)
        self._reindex(self._view)

    # ------------------------------------------------------------ restore
    @classmethod
    def from_state(cls, *, base_vecs, base_attrs, base_ids, base_live,
                   base_nbrs, base_rmq, base_dist_c,
                   delta_vecs, delta_attrs, delta_ids,
                   next_id: int, max_delta: int = 1024,
                   compact_every: int = 0, precisions=(),
                   build_kw=None) -> "StreamingRFANN":
        """Rehydrate from checkpointed segment state (``repro.index.io``)
        **without rebuilding the base graph** — the saved adjacency / RMQ /
        entry arrays go straight into a fresh ``SearchSubstrate``, so
        restore cost is array upload, not O(n²) construction.

        ``precisions`` are recorded for compaction re-install; the caller
        preloads saved quantized corpora via ``sub.preload_quantized`` (or
        first quantized use lazily rebuilds them — identical either way,
        quantization is deterministic in the base vectors).  Tombstones and
        the delta snapshot resume exactly; compaction counters restart at
        zero (they are run-scoped observability, not corpus state)."""
        base_vecs = np.asarray(base_vecs, np.float32)
        self = cls.__new__(cls)
        self.d = int(base_vecs.shape[1])
        self._build_kw = dict(build_kw or {})
        self._lock = threading.RLock()
        self._cache = None
        self._metrics = None
        self._precisions = set(precisions)
        self.max_delta = int(max_delta)
        self.compact_every = int(compact_every)
        self._ops_since_compact = 0
        self._compacting = threading.Event()
        self._worker = None
        self.compactions = 0
        self.build_seconds = 0.0
        base_ids = np.asarray(base_ids, np.int32)
        sub = SearchSubstrate(base_vecs, base_nbrs, base_rmq, base_dist_c,
                              order=base_ids, attrs=base_attrs,
                              cache=None, cache_ns=BASE_NS, metrics=None)
        delta = DeltaView(np.asarray(delta_vecs, np.float32),
                          np.asarray(delta_attrs, np.float32),
                          np.asarray(delta_ids, np.int32))
        live = np.asarray(base_live, bool)
        self._view = SegmentView(sub, base_vecs,
                                 np.asarray(base_attrs, np.float32),
                                 base_ids, live, int((~live).sum()),
                                 delta, version=0)
        self._next_id = int(next_id)
        self._id_loc = {}
        self._reindex(self._view)
        return self

    # ------------------------------------------------------------ builders
    def _build_view(self, vectors, attrs, ext_ids, delta: DeltaView, *,
                    version: int, old_sub: Optional[SearchSubstrate] = None,
                    base_live: Optional[np.ndarray] = None) -> SegmentView:
        """Build an RNSG base over (vectors, attrs) and wrap it in a view.
        ``build_rnsg`` stable-sorts by attribute, so the result — and every
        search over it — is a deterministic function of the input order."""
        g = build_rnsg(vectors, attrs, **self._build_kw)
        self.build_seconds += g.build_seconds
        base_ids = np.asarray(ext_ids, np.int32)[g.order]
        sub = SearchSubstrate(g.vecs, g.nbrs, g.rmq, g.dist_c,
                              order=base_ids, attrs=g.attrs,
                              cache=self._cache, cache_ns=BASE_NS,
                              metrics=self._metrics)
        if old_sub is not None:     # carry the calibrated cost model across
            sub.planner.cost = old_sub.planner.cost
            sub.planner.calibration_epoch = old_sub.planner.calibration_epoch
        for prec in self._precisions:
            sub.install_quantized(prec)
        if base_live is None:
            base_live = np.ones(len(base_ids), bool)
        return SegmentView(sub, g.vecs, g.attrs, base_ids, base_live,
                           int((~base_live).sum()), delta, version)

    def _reindex(self, v: SegmentView) -> None:
        loc = {int(e): r for r, e in enumerate(v.base_ids)
               if v.base_live[r]}
        for e in v.delta.ids:
            loc[int(e)] = -1
        self._id_loc = loc

    # ----------------------------------------------------------- plumbing
    @property
    def planner(self):
        return self._view.sub.planner

    def install_cache(self, cache) -> None:
        with self._lock:
            self._cache = cache
            self._view.sub.cache = cache

    def install_metrics(self, metrics) -> None:
        with self._lock:
            self._metrics = metrics
            self._view.sub.metrics = metrics
            if metrics is not None:
                m = metrics
                self._m_ins = m.counter("stream_inserts_total",
                                        "streaming inserts")
                self._m_del = m.counter("stream_deletes_total",
                                        "streaming deletes")
                self._m_comp = m.counter("stream_compactions_total",
                                         "delta->base compactions")
                self._m_dsize = m.gauge("stream_delta_size",
                                        "rows in the delta segment")
                self._m_tomb = m.gauge("stream_tombstones",
                                       "tombstoned base rows")
                self._m_dfrac = m.histogram(
                    "stream_delta_frac",
                    "delta fraction of the live corpus at search time",
                    lo=1e-4, hi=1.0, growth=1.5)
                self._m_pause = m.histogram(
                    "stream_compaction_pause_ms",
                    "locked swap pause per compaction (ms)")
                self._m_build = m.histogram(
                    "stream_compaction_build_ms",
                    "off-lock rebuild wall per compaction (ms)")
                m.register_producer("streaming", self.stats)

    def install_quantized(self, precision: str) -> None:
        """Record the precision (compaction re-installs it on every rebuilt
        base) and build the quantized corpus on the current base."""
        if precision == "f32":
            return
        with self._lock:
            self._precisions.add(precision)
            self._view.sub.install_quantized(precision)

    def set_compaction_policy(self, max_delta: Optional[int] = None,
                              compact_every: Optional[int] = None) -> None:
        if max_delta is not None:
            self.max_delta = int(max_delta)
        if compact_every is not None:
            self.compact_every = int(compact_every)

    # ---------------------------------------------------------- mutations
    def insert(self, vector: np.ndarray, attr: float,
               ext_id: Optional[int] = None) -> int:
        """Append one point to the delta segment; returns its external id.
        O(delta) host work (stable re-sort); no base cache invalidation —
        delta results are never cached."""
        with self._lock:
            if ext_id is None:
                ext_id = self._next_id
            ext_id = int(ext_id)
            if ext_id in self._id_loc:
                raise ValueError(f"id {ext_id} is already live")
            self._next_id = max(self._next_id, ext_id + 1)
            v = self._view
            delta = v.delta.with_inserted(np.asarray(vector, np.float32),
                                          float(attr), ext_id)
            self._view = SegmentView(v.sub, v.base_vecs, v.base_attrs,
                                     v.base_ids, v.base_live,
                                     v.n_tombstones, delta, v.version + 1)
            self._id_loc[ext_id] = -1
            self._ops_since_compact += 1
            if self._metrics is not None:
                self._m_ins.inc()
                self._m_dsize.set(delta.count)
        self._maybe_compact()
        return ext_id

    def delete(self, ext_id: int) -> None:
        """Remove one live point.  Base points tombstone (the node stays a
        routing node until the next compaction) and invalidate the base
        cache segment; delta points vanish physically."""
        with self._lock:
            ext_id = int(ext_id)
            loc = self._id_loc.pop(ext_id, None)
            if loc is None:
                raise KeyError(f"id {ext_id} is not live")
            v = self._view
            if loc < 0:             # delta row: physical remove
                delta = v.delta.without(ext_id)
                self._view = SegmentView(v.sub, v.base_vecs, v.base_attrs,
                                         v.base_ids, v.base_live,
                                         v.n_tombstones, delta,
                                         v.version + 1)
                if self._metrics is not None:
                    self._m_dsize.set(delta.count)
            else:                   # base rank: copy-on-write tombstone
                live = v.base_live.copy()
                live[loc] = False
                self._view = SegmentView(v.sub, v.base_vecs, v.base_attrs,
                                         v.base_ids, live,
                                         v.n_tombstones + 1, v.delta,
                                         v.version + 1)
                if self._cache is not None:
                    self._cache.invalidate_segment(BASE_NS)
                if self._metrics is not None:
                    self._m_tomb.set(v.n_tombstones + 1)
            self._ops_since_compact += 1
            if self._metrics is not None:
                self._m_del.inc()
        self._maybe_compact()

    # ------------------------------------------------------------- search
    def search(self, queries: np.ndarray, attr_ranges: np.ndarray, *,
               k: int = 10, ef: int = 64, plan: str = "auto",
               beam_width: int = 1, precision: str = "f32",
               use_kernel: bool = False, trace=None) -> SearchResult:
        """Range-filtered kNN over base ∪ delta at one captured snapshot.
        Returns external ids.  Resolve happens per segment *inside* the
        snapshot (this is why there is no ``rank_range``)."""
        v = self._view                      # lock-free snapshot capture
        qv = np.atleast_2d(np.asarray(queries, np.float32))
        ar = np.atleast_2d(np.asarray(attr_ranges, np.float32))
        ef = max(ef, k)
        lo, hi = v.sub.resolve(ar)
        req = SearchRequest(
            queries=qv, lo=lo, hi=hi, k=k, ef=ef, strategy=plan,
            use_kernel=use_kernel, beam_width=beam_width,
            precision=precision, trace=trace,
            live=v.base_live if v.n_tombstones else None)
        pending = v.sub.dispatch(req, defer=True)
        delta_res = v.delta.search(qv, ar, k)
        base = pending.result()
        if self._metrics is not None and v.n_live:
            self._m_dfrac.observe(v.delta.count / v.n_live)
        stats = dict(base.stats)
        stats.update(delta_size=v.delta.count, tombstones=v.n_tombstones,
                     version=v.version)
        if delta_res is None:
            return SearchResult(base.ids, base.dists, stats,
                                trace=base.trace)
        di, dd = delta_res
        all_i = np.stack([np.asarray(base.ids, np.int32), di])
        all_d = np.stack([np.where(base.ids >= 0, base.dists, np.inf), dd])
        ids, dists = merge_topk(jnp.asarray(all_i), jnp.asarray(all_d), k)
        return SearchResult(np.asarray(ids), np.asarray(dists), stats,
                            trace=base.trace)

    # --------------------------------------------------------- compaction
    def _maybe_compact(self) -> None:
        if self._compacting.is_set():
            return
        v = self._view
        due = (v.delta.count >= self.max_delta
               or (self.compact_every
                   and self._ops_since_compact >= self.compact_every))
        if due:
            self.compact(wait=False)

    def compact(self, wait: bool = True) -> bool:
        """Rebuild the base from the live set on a worker thread and
        hot-swap it.  Returns False when a compaction is already running
        or there is nothing to fold in."""
        with self._lock:
            if self._compacting.is_set():
                if wait and self._worker is not None:
                    w = self._worker
                else:
                    return False
            else:
                v = self._view
                if v.delta.count == 0 and v.n_tombstones == 0:
                    return False
                if v.n_live < 8:    # tombstone masks stay correct; a graph
                    return False    # over <8 points is not worth building
                self._compacting.set()
                self._ops_since_compact = 0
                w = threading.Thread(target=self._compact_run, args=(v,),
                                     daemon=True)
                self._worker = w
                w.start()
        if wait:
            w.join()
        return True

    def _compact_run(self, v0: SegmentView) -> None:
        try:
            t0 = time.perf_counter()
            keep = v0.base_live
            cat_vecs = np.concatenate([v0.base_vecs[keep], v0.delta.vecs])
            cat_attrs = np.concatenate([v0.base_attrs[keep],
                                        v0.delta.attrs])
            cat_ids = np.concatenate([v0.base_ids[keep], v0.delta.ids])
            # slow part — entirely off-lock; mutations keep landing on the
            # published view and are reconciled at the swap below
            new = self._build_view(cat_vecs, cat_attrs, cat_ids,
                                   DeltaView.empty(self.d),
                                   version=0, old_sub=v0.sub)
            build_ms = (time.perf_counter() - t0) * 1e3
            t1 = time.perf_counter()
            with self._lock:
                cur = self._view
                # ids live *now* (deletes during the rebuild win)
                live_now = np.concatenate(
                    [cur.base_ids[cur.base_live], cur.delta.ids])
                base_live = np.isin(new.base_ids, live_now)
                # inserts during the rebuild stay as the residual delta
                folded = np.isin(cur.delta.ids, cat_ids)
                residual = cur.delta.subset(~folded)
                swapped = SegmentView(new.sub, new.base_vecs,
                                      new.base_attrs, new.base_ids,
                                      base_live, int((~base_live).sum()),
                                      residual, cur.version + 1)
                v0.sub.cache = None     # old segment: no new lookups;
                if self._cache is not None:     # late stores are fenced by
                    self._cache.invalidate_segment(BASE_NS)  # the epoch bump
                self._view = swapped
                self._reindex(swapped)
                self.compactions += 1
            pause_ms = (time.perf_counter() - t1) * 1e3
            if self._metrics is not None:
                self._m_comp.inc()
                self._m_pause.observe(pause_ms)
                self._m_build.observe(build_ms)
                self._m_dsize.set(residual.count)
                self._m_tomb.set(swapped.n_tombstones)
        finally:
            self._compacting.clear()

    def close(self) -> None:
        """Wait out any in-flight compaction (tests and serve teardown)."""
        w = self._worker
        if w is not None and w.is_alive():
            w.join(timeout=30.0)

    # ------------------------------------------------------------- export
    def live_items(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(vecs, attrs, ids) of every live point, in exactly the order a
        compaction would feed ``build_rnsg`` — a fresh offline build on
        this tuple is bit-identical to the post-compaction base."""
        v = self._view
        keep = v.base_live
        return (np.concatenate([v.base_vecs[keep], v.delta.vecs]),
                np.concatenate([v.base_attrs[keep], v.delta.attrs]),
                np.concatenate([v.base_ids[keep], v.delta.ids]))

    def stats(self) -> dict:
        v = self._view
        nb = len(v.base_ids)
        return dict(n_base=nb, n_delta=v.delta.count,
                    tombstones=v.n_tombstones, n_live=v.n_live,
                    delta_frac=v.delta.count / max(v.n_live, 1),
                    version=v.version, compactions=self.compactions,
                    build_seconds=self.build_seconds)

    @property
    def index_bytes(self) -> int:
        v = self._view
        sub = v.sub
        return int(sub._nbrs.nbytes + sub._rmq.nbytes + sub._dist_c.nbytes
                   + v.delta.vecs.nbytes)
