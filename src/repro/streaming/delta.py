"""Mutable-delta segment of the streaming index: an attribute-sorted
brute-force buffer searched exactly through the ``range_scan`` kernel.

A ``DeltaView`` is an **immutable snapshot** — every insert/delete produces
a new view (the arrays of the old one are never written), so readers that
captured a view race nothing.  Rows stay attribute-sorted (stable re-sort
on insert: equal attributes keep insertion order, matching the stable
argsort ``build_rnsg`` uses, which is what makes a compacted index
bit-identical to a fresh offline build on the same live set).

Device residency: the padded corpus copy is built lazily per view and
memoized on it.  Capacity pads to the next power of two (≥ one row tile),
so the scan's jit signature changes O(log capacity) times over the life of
a delta, not once per insert; the pad tail is masked by the kernel's
``live`` row operand (an operand, not a static — masking costs no
retrace).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import range_scan
from repro.search import rank_interval

_ROW_TILE = 128         # must match repro.kernels.range_scan.ROW_TILE


def _next_pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 1).bit_length()


class DeltaView:
    """One immutable snapshot of the delta segment.

    vecs : (m, d) f32, attribute-sorted.
    attrs: (m,) f32 ascending.
    ids  : (m,) int32 external ids (the streaming layer's stable ids).
    """

    __slots__ = ("vecs", "attrs", "ids", "_dev")

    def __init__(self, vecs: np.ndarray, attrs: np.ndarray, ids: np.ndarray):
        self.vecs = np.asarray(vecs, np.float32)
        self.attrs = np.asarray(attrs, np.float32)
        self.ids = np.asarray(ids, np.int32)
        self._dev = None            # lazy (x_pad, live_row, cap, d_pad)

    # ------------------------------------------------------------ factory
    @classmethod
    def empty(cls, d: int) -> "DeltaView":
        return cls(np.zeros((0, d), np.float32), np.zeros(0, np.float32),
                   np.zeros(0, np.int32))

    @property
    def count(self) -> int:
        return len(self.ids)

    # ------------------------------------------------- derived snapshots
    def with_inserted(self, vec: np.ndarray, attr: float,
                      ext_id: int) -> "DeltaView":
        """New view with one row appended (stable attribute re-sort)."""
        vecs = np.concatenate([self.vecs,
                               np.asarray(vec, np.float32)[None, :]])
        attrs = np.concatenate([self.attrs,
                                np.asarray([attr], np.float32)])
        ids = np.concatenate([self.ids, np.asarray([ext_id], np.int32)])
        o = np.argsort(attrs, kind="stable")
        return DeltaView(vecs[o], attrs[o], ids[o])

    def without(self, ext_id: int) -> "DeltaView":
        """New view with one row physically removed (delta deletes need no
        tombstone — nothing references delta rows by position)."""
        keep = self.ids != np.int32(ext_id)
        return DeltaView(self.vecs[keep], self.attrs[keep], self.ids[keep])

    def subset(self, keep: np.ndarray) -> "DeltaView":
        """New view of the rows selected by a boolean mask (compaction's
        residual: rows inserted while the rebuild ran)."""
        return DeltaView(self.vecs[keep], self.attrs[keep], self.ids[keep])

    # ------------------------------------------------------------- search
    def _device(self):
        if self._dev is None:
            m, d = self.vecs.shape
            cap = _next_pow2(max(m, _ROW_TILE))
            d_pad = -(-d // 128) * 128
            x = np.zeros((cap, d_pad), np.float32)
            x[:m, :d] = self.vecs
            live = np.zeros((1, cap), np.int32)
            live[0, :m] = 1         # pad-tail mask (operand, never retraces)
            self._dev = (jnp.asarray(x), jnp.asarray(live), cap, d_pad)
        return self._dev

    def search(self, qv: np.ndarray, attr_ranges: np.ndarray,
               k: int) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Exact per-query range top-k over the delta rows.

        qv: (Q, d); attr_ranges: (Q, 2) inclusive attribute values.
        Returns (ids (Q, k) int32 **external** ids (-1 pad),
        dists (Q, k) f32 squared L2 (+inf pad)), or ``None`` when the
        delta is empty (callers skip the merge entirely — keeps the
        compacted index's results bit-identical to a base-only search).
        """
        m = self.count
        if m == 0:
            return None
        lo, hi = rank_interval(self.attrs, attr_ranges)
        x_pad, live_row, cap, d_pad = self._device()
        nq = len(qv)
        pad_q = _next_pow2(max(nq, 1))
        starts = np.zeros(pad_q, np.int32)
        lens = np.zeros(pad_q, np.int32)
        starts[:nq] = lo
        lens[:nq] = np.clip(hi.astype(np.int64) - lo + 1, 0, cap)
        qp = np.zeros((pad_q, d_pad), np.float32)
        qp[:nq, :qv.shape[1]] = qv
        ids_r, dists = range_scan(x_pad, jnp.asarray(starts),
                                  jnp.asarray(lens), jnp.asarray(qp),
                                  bucket=cap, k=k, live=live_row)
        ids_r = np.asarray(ids_r)[:nq]
        dists = np.asarray(dists)[:nq]
        ext = np.where(ids_r >= 0, self.ids[np.maximum(ids_r, 0)], -1)
        return ext.astype(np.int32), np.where(ids_r >= 0, dists, np.inf)
