"""Sharded on-disk index format + index-state flattening.

Two layers:

* **State flattening** — ``index_state`` / ``index_from_state`` turn an
  index object (``RNSGGraph`` / ``RNSGIndex`` incl. installed quantized
  corpora / ``StreamingRFANN`` incl. tombstone + delta segment state) into
  a flat ``{key: ndarray}`` tree plus a JSON-able manifest, and back.
  ``CheckpointManager.save_index`` rides this through the existing atomic
  npz checkpoint-step machinery; the directory format below uses the same
  flattening, so both flavors restore through one code path.
* **Directory format** — ``save_index`` / ``load_index``: one ``.npy``
  file per array (row-sharded into ``shards`` pieces for the big
  row-dimension arrays), plus ``manifest.json``.  Restore mmaps
  single-file arrays and fills sharded ones with parallel reads, so
  serving a prebuilt index starts in seconds instead of an O(n²) rebuild.

Crash safety: every array file is written tmp→fsync→``os.replace``, and
``manifest.json`` is written **last** (same atomic idiom) — a reader sees
either the previous complete generation or the new one, never a torn mix.
Array files carry a generation counter in their names so an interrupted
save can never overwrite files the current manifest still references;
superseded generations are garbage-collected after the manifest commits.

bf16 quantized corpora are stored as their exact f32 upcast (the same
convention as ``checkpoint._flatten``) and re-narrowed on restore —
bf16→f32→bf16 round-trips bit-exactly.
"""
from __future__ import annotations

import json
import os
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path
from typing import Dict, Tuple

import numpy as np

MANIFEST = "manifest.json"
SCHEMA = 1


# ----------------------------------------------------------------- state
def _quant_entries(sub) -> Tuple[Dict[str, np.ndarray], Dict[str, dict]]:
    """Flatten a substrate's installed quantized slots (nothing if the
    substrate was never forced)."""
    flat: Dict[str, np.ndarray] = {}
    man: Dict[str, dict] = {}
    for prec, slot in sub._quant.items():
        data = np.asarray(slot["data"])
        dtype = str(data.dtype)
        if dtype == "bfloat16":
            data = data.astype(np.float32)      # exact upcast; see module doc
        flat[f"quant/{prec}/data"] = data
        has_scale = slot["scale"] is not None
        if has_scale:
            flat[f"quant/{prec}/scale"] = np.asarray(slot["scale"],
                                                     np.float32)
        man[prec] = dict(dtype=dtype, has_scale=has_scale)
    return flat, man


def index_state(index) -> Tuple[Dict[str, np.ndarray], dict]:
    """(flat array tree, JSON-able manifest) for one index object.

    Accepts ``RNSGGraph``, ``RNSGIndex`` (quantized corpora installed on
    its substrate ride along), or ``StreamingRFANN`` (base graph arrays +
    external ids + tombstone mask + delta snapshot + id counter)."""
    from repro.core.construction import RNSGGraph
    from repro.core.rfann import RNSGIndex
    from repro.streaming.streaming import StreamingRFANN

    if isinstance(index, StreamingRFANN):
        with index._lock:
            v = index._view
        sub = v.sub
        flat = {"graph/vecs": np.asarray(v.base_vecs, np.float32),
                "graph/attrs": np.asarray(v.base_attrs, np.float32),
                "graph/nbrs": np.asarray(sub._nbrs),
                "graph/rmq": np.asarray(sub._rmq),
                "graph/dist_c": np.asarray(sub._dist_c),
                "graph/order": np.asarray(v.base_ids, np.int32),
                "stream/base_live": np.asarray(v.base_live, bool),
                "stream/delta_vecs": np.asarray(v.delta.vecs, np.float32),
                "stream/delta_attrs": np.asarray(v.delta.attrs, np.float32),
                "stream/delta_ids": np.asarray(v.delta.ids, np.int32)}
        qflat, qman = _quant_entries(sub)
        flat.update(qflat)
        manifest = dict(
            kind="streaming", n=int(len(v.base_ids)),
            d=int(v.base_vecs.shape[1]), quant=qman,
            streaming=dict(next_id=int(index._next_id),
                           max_delta=int(index.max_delta),
                           compact_every=int(index.compact_every),
                           n_delta=int(v.delta.count),
                           n_tombstones=int(v.n_tombstones),
                           precisions=sorted(index._precisions),
                           build_kw=dict(index._build_kw)))
        return flat, manifest

    if isinstance(index, RNSGIndex):
        g, sub = index.g, index._substrate
    elif isinstance(index, RNSGGraph):
        g, sub = index, None
    else:
        raise TypeError(f"index_state: cannot flatten {type(index).__name__}"
                        " (expected RNSGGraph, RNSGIndex or StreamingRFANN)")
    flat = {"graph/vecs": np.asarray(g.vecs, np.float32),
            "graph/attrs": np.asarray(g.attrs, np.float32),
            "graph/nbrs": np.asarray(g.nbrs),
            "graph/rmq": np.asarray(g.rmq),
            "graph/dist_c": np.asarray(g.dist_c),
            "graph/order": np.asarray(g.order, np.int32),
            "graph/centroid": np.asarray(g.centroid, np.float32)}
    qman: Dict[str, dict] = {}
    if sub is not None:
        qflat, qman = _quant_entries(sub)
        flat.update(qflat)
    manifest = dict(kind="rnsg", n=int(g.n), d=int(g.vecs.shape[1]),
                    build_seconds=float(g.build_seconds),
                    meta=dict(g.meta), quant=qman)
    return flat, manifest


def index_from_state(flat: Dict[str, np.ndarray], manifest: dict):
    """Inverse of :func:`index_state`.  Returns an ``RNSGIndex`` for kind
    ``rnsg`` (``.g`` exposes the graph) or a ``StreamingRFANN`` for kind
    ``streaming``; saved quantized corpora are preloaded onto the
    substrate so the first quantized request pays no re-quantize."""
    kind = manifest.get("kind")
    if kind == "rnsg":
        from repro.core.construction import RNSGGraph
        from repro.core.rfann import RNSGIndex
        g = RNSGGraph(vecs=np.asarray(flat["graph/vecs"], np.float32),
                      attrs=np.asarray(flat["graph/attrs"], np.float32),
                      nbrs=np.asarray(flat["graph/nbrs"], np.int32),
                      order=np.asarray(flat["graph/order"], np.int32),
                      centroid=np.asarray(flat["graph/centroid"], np.float32),
                      dist_c=np.asarray(flat["graph/dist_c"], np.float32),
                      rmq=np.asarray(flat["graph/rmq"], np.int32),
                      build_seconds=float(manifest.get("build_seconds", 0.0)),
                      meta=dict(manifest.get("meta", {})))
        idx = RNSGIndex(g)
        _preload_quant(idx.substrate, flat, manifest)
        return idx
    if kind == "streaming":
        from repro.streaming.streaming import StreamingRFANN
        s = manifest["streaming"]
        stream = StreamingRFANN.from_state(
            base_vecs=flat["graph/vecs"], base_attrs=flat["graph/attrs"],
            base_ids=flat["graph/order"],
            base_live=flat["stream/base_live"],
            base_nbrs=flat["graph/nbrs"], base_rmq=flat["graph/rmq"],
            base_dist_c=flat["graph/dist_c"],
            delta_vecs=flat["stream/delta_vecs"],
            delta_attrs=flat["stream/delta_attrs"],
            delta_ids=flat["stream/delta_ids"],
            next_id=s["next_id"], max_delta=s.get("max_delta", 1024),
            compact_every=s.get("compact_every", 0),
            precisions=s.get("precisions", ()),
            build_kw=s.get("build_kw"))
        _preload_quant(stream._view.sub, flat, manifest)
        return stream
    raise ValueError(f"index_from_state: unknown index kind {kind!r}")


def _preload_quant(sub, flat, manifest) -> None:
    for prec in manifest.get("quant", {}):
        sub.preload_quantized(prec, flat[f"quant/{prec}/data"],
                              flat.get(f"quant/{prec}/scale"))


# --------------------------------------------------------------- on disk
def _atomic_write(path: Path, write_fn) -> None:
    tmp = path.with_name(f"{path.name}.tmp.{os.getpid()}")
    try:
        with open(tmp, "wb") as f:
            write_fn(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        if tmp.exists():
            tmp.unlink()


def is_index_dir(path) -> bool:
    return (Path(path) / MANIFEST).is_file()


def save_index(index, path, *, shards: int = 1) -> dict:
    """Write the sharded directory format; returns the manifest.

    Arrays whose leading axis is the corpus row dimension are split into
    ``shards`` contiguous row slabs (one file each) so restore can fill
    them with parallel reads; small/global arrays stay single-file and
    mmap on restore.  Safe to save over a live directory: the new
    generation's files never collide with the old, and the manifest swap
    is the atomic commit point."""
    flat, man = index_state(index)
    p = Path(path)
    p.mkdir(parents=True, exist_ok=True)
    gen = 0
    if is_index_dir(p):
        try:
            gen = int(json.loads((p / MANIFEST).read_text())
                      .get("gen", 0)) + 1
        except (ValueError, json.JSONDecodeError):
            gen = 1
    shards = max(int(shards), 1)
    n_rows = man["n"]
    arrays: Dict[str, dict] = {}
    for key, a in flat.items():
        base = key.replace("/", ".")
        row_sharded = (shards > 1 and a.ndim >= 1
                       and a.shape[0] == n_rows and n_rows >= shards)
        parts = np.array_split(a, shards) if row_sharded else [a]
        files = []
        for i, part in enumerate(parts):
            fn = f"{base}.g{gen}.{i:02d}.npy"
            _atomic_write(p / fn,
                          lambda f, part=part: np.save(f, part))
            files.append(fn)
        arrays[key] = dict(files=files, shape=list(a.shape),
                           dtype=str(a.dtype))
    manifest = dict(schema=SCHEMA, gen=gen, shards=shards,
                    index=man, arrays=arrays)
    blob = json.dumps(manifest, indent=1).encode()
    _atomic_write(p / MANIFEST, lambda f: f.write(blob))
    _gc_stale(p, manifest)
    return manifest


def _gc_stale(p: Path, manifest: dict) -> None:
    live = {f for am in manifest["arrays"].values() for f in am["files"]}
    for f in p.iterdir():
        name = f.name
        if name in live or name == MANIFEST:
            continue
        if ".g" in name and (name.endswith(".npy") or ".npy.tmp." in name):
            f.unlink(missing_ok=True)


def load_index(path, *, mmap: bool = True, parallel: bool = True,
               workers: int = 8):
    """Restore from the directory format.  Single-file arrays mmap (zero
    copy until first touch); row-sharded arrays are filled by a thread
    pool reading all slabs concurrently.  Returns whatever
    :func:`index_from_state` builds for the saved kind."""
    p = Path(path)
    manifest = json.loads((p / MANIFEST).read_text())
    if manifest.get("schema", 0) > SCHEMA:
        raise ValueError(f"index at {p} has schema "
                         f"{manifest['schema']} > supported {SCHEMA}")
    arrays = manifest["arrays"]
    flat: Dict[str, np.ndarray] = {}
    jobs = []
    for key, am in arrays.items():
        files = am["files"]
        if len(files) == 1:
            flat[key] = np.load(p / files[0],
                                mmap_mode="r" if mmap else None)
            continue
        out = np.empty(tuple(am["shape"]), dtype=np.dtype(am["dtype"]))
        flat[key] = out
        # slab offsets follow np.array_split's rule: the first n % k slabs
        # get one extra row
        n, k = am["shape"][0], len(files)
        sizes = [n // k + (1 if i < n % k else 0) for i in range(k)]
        row = 0
        for fn, sz in zip(files, sizes):
            jobs.append((out, row, p / fn))
            row += sz
    def fill(job):
        out, row0, fn = job
        part = np.load(fn)
        out[row0:row0 + len(part)] = part
    if jobs:
        if parallel and len(jobs) > 1:
            with ThreadPoolExecutor(max_workers=workers) as ex:
                list(ex.map(fill, jobs))
        else:
            for j in jobs:
                fill(j)
    return index_from_state(flat, manifest["index"])
