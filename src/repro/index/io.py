"""Sharded on-disk index format + index-state flattening.

Two layers:

* **State flattening** — ``index_state`` / ``index_from_state`` turn an
  index object (``RNSGGraph`` / ``RNSGIndex`` incl. installed quantized
  corpora / ``StreamingRFANN`` incl. tombstone + delta segment state) into
  a flat ``{key: ndarray}`` tree plus a JSON-able manifest, and back.
  ``CheckpointManager.save_index`` rides this through the existing atomic
  npz checkpoint-step machinery; the directory format below uses the same
  flattening, so both flavors restore through one code path.
* **Directory format** — ``save_index`` / ``load_index``: one ``.npy``
  file per array (row-sharded into ``shards`` pieces for the big
  row-dimension arrays), plus ``manifest.json``.  Restore mmaps
  single-file arrays and fills sharded ones with parallel reads, so
  serving a prebuilt index starts in seconds instead of an O(n²) rebuild.

Crash safety: every array file is written tmp→fsync→``os.replace``, and
``manifest.json`` is written **last** (same atomic idiom) — a reader sees
either the previous complete generation or the new one, never a torn mix.
Array files carry a generation counter in their names so an interrupted
save can never overwrite files the current manifest still references;
superseded generations are garbage-collected after the manifest commits.

bf16 quantized corpora are stored as their exact f32 upcast (the same
convention as ``checkpoint._flatten``) and re-narrowed on restore —
bf16→f32→bf16 round-trips bit-exactly.
"""
from __future__ import annotations

import json
import os
import zlib
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path
from typing import Dict, Tuple

import numpy as np

MANIFEST = "manifest.json"
SCHEMA = 1


class IndexCorruptionError(RuntimeError):
    """A saved index file failed validation (truncated, checksum-mangled,
    or shape-mismatched).  Raised with the offending file and the manifest
    generation named, instead of propagating a raw numpy/mmap error."""


def fsync_dir(path) -> None:
    """fsync a *directory* so a rename/create just committed inside it
    survives power failure.  ``tmp → fsync(file) → os.replace`` makes the
    file contents durable, but the new *name* lives in the directory
    inode — on most filesystems it is only guaranteed on disk after the
    directory itself is fsynced.  Shared by every atomic-save site
    (``RNSGGraph.save``, ``QueryPlanner.save_calibration``, the
    ``save_index`` array/manifest commits, and the WAL's segment
    create/rotate).  No-op on platforms that refuse O_DIRECTORY opens or
    directory fsync (e.g. Windows) — there is no portable stronger
    guarantee there."""
    flags = os.O_RDONLY | getattr(os, "O_DIRECTORY", 0)
    try:
        fd = os.open(os.fspath(path), flags)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


# ----------------------------------------------------------------- state
def _quant_entries(sub) -> Tuple[Dict[str, np.ndarray], Dict[str, dict]]:
    """Flatten a substrate's installed quantized slots (nothing if the
    substrate was never forced)."""
    flat: Dict[str, np.ndarray] = {}
    man: Dict[str, dict] = {}
    for prec, slot in sub._quant.items():
        data = np.asarray(slot["data"])
        dtype = str(data.dtype)
        if dtype == "bfloat16":
            data = data.astype(np.float32)      # exact upcast; see module doc
        flat[f"quant/{prec}/data"] = data
        has_scale = slot["scale"] is not None
        if has_scale:
            flat[f"quant/{prec}/scale"] = np.asarray(slot["scale"],
                                                     np.float32)
        man[prec] = dict(dtype=dtype, has_scale=has_scale)
    return flat, man


def index_state(index) -> Tuple[Dict[str, np.ndarray], dict]:
    """(flat array tree, JSON-able manifest) for one index object.

    Accepts ``RNSGGraph``, ``RNSGIndex`` (quantized corpora installed on
    its substrate ride along), or ``StreamingRFANN`` (base graph arrays +
    external ids + tombstone mask + delta snapshot + id counter)."""
    from repro.core.construction import RNSGGraph
    from repro.core.rfann import RNSGIndex
    from repro.streaming.streaming import StreamingRFANN

    if isinstance(index, StreamingRFANN):
        with index._lock:
            # view and WAL watermark must come from the same locked
            # instant: a mutation between the two reads would bump the
            # watermark past records the snapshot does not contain, and
            # recovery would then skip them (lost acknowledged writes)
            v = index._view
            wal_lsn = int(getattr(index, "applied_lsn", 0))
        sub = v.sub
        flat = {"graph/vecs": np.asarray(v.base_vecs, np.float32),
                "graph/attrs": np.asarray(v.base_attrs, np.float32),
                "graph/nbrs": np.asarray(sub._nbrs),
                "graph/rmq": np.asarray(sub._rmq),
                "graph/dist_c": np.asarray(sub._dist_c),
                "graph/order": np.asarray(v.base_ids, np.int32),
                "stream/base_live": np.asarray(v.base_live, bool),
                "stream/delta_vecs": np.asarray(v.delta.vecs, np.float32),
                "stream/delta_attrs": np.asarray(v.delta.attrs, np.float32),
                "stream/delta_ids": np.asarray(v.delta.ids, np.int32)}
        qflat, qman = _quant_entries(sub)
        flat.update(qflat)
        manifest = dict(
            kind="streaming", n=int(len(v.base_ids)),
            d=int(v.base_vecs.shape[1]), quant=qman,
            streaming=dict(next_id=int(index._next_id),
                           max_delta=int(index.max_delta),
                           compact_every=int(index.compact_every),
                           n_delta=int(v.delta.count),
                           n_tombstones=int(v.n_tombstones),
                           precisions=sorted(index._precisions),
                           build_kw=dict(index._build_kw),
                           # WAL replay watermark: every mutation with
                           # lsn <= wal_lsn is inside this snapshot
                           wal_lsn=wal_lsn))
        return flat, manifest

    if isinstance(index, RNSGIndex):
        g, sub = index.g, index._substrate
    elif isinstance(index, RNSGGraph):
        g, sub = index, None
    else:
        raise TypeError(f"index_state: cannot flatten {type(index).__name__}"
                        " (expected RNSGGraph, RNSGIndex or StreamingRFANN)")
    flat = {"graph/vecs": np.asarray(g.vecs, np.float32),
            "graph/attrs": np.asarray(g.attrs, np.float32),
            "graph/nbrs": np.asarray(g.nbrs),
            "graph/rmq": np.asarray(g.rmq),
            "graph/dist_c": np.asarray(g.dist_c),
            "graph/order": np.asarray(g.order, np.int32),
            "graph/centroid": np.asarray(g.centroid, np.float32)}
    qman: Dict[str, dict] = {}
    if sub is not None:
        qflat, qman = _quant_entries(sub)
        flat.update(qflat)
    manifest = dict(kind="rnsg", n=int(g.n), d=int(g.vecs.shape[1]),
                    build_seconds=float(g.build_seconds),
                    meta=dict(g.meta), quant=qman)
    return flat, manifest


def index_from_state(flat: Dict[str, np.ndarray], manifest: dict):
    """Inverse of :func:`index_state`.  Returns an ``RNSGIndex`` for kind
    ``rnsg`` (``.g`` exposes the graph) or a ``StreamingRFANN`` for kind
    ``streaming``; saved quantized corpora are preloaded onto the
    substrate so the first quantized request pays no re-quantize."""
    kind = manifest.get("kind")
    if kind == "rnsg":
        from repro.core.construction import RNSGGraph
        from repro.core.rfann import RNSGIndex
        g = RNSGGraph(vecs=np.asarray(flat["graph/vecs"], np.float32),
                      attrs=np.asarray(flat["graph/attrs"], np.float32),
                      nbrs=np.asarray(flat["graph/nbrs"], np.int32),
                      order=np.asarray(flat["graph/order"], np.int32),
                      centroid=np.asarray(flat["graph/centroid"], np.float32),
                      dist_c=np.asarray(flat["graph/dist_c"], np.float32),
                      rmq=np.asarray(flat["graph/rmq"], np.int32),
                      build_seconds=float(manifest.get("build_seconds", 0.0)),
                      meta=dict(manifest.get("meta", {})))
        idx = RNSGIndex(g)
        _preload_quant(idx.substrate, flat, manifest)
        return idx
    if kind == "streaming":
        from repro.streaming.streaming import StreamingRFANN
        s = manifest["streaming"]
        stream = StreamingRFANN.from_state(
            base_vecs=flat["graph/vecs"], base_attrs=flat["graph/attrs"],
            base_ids=flat["graph/order"],
            base_live=flat["stream/base_live"],
            base_nbrs=flat["graph/nbrs"], base_rmq=flat["graph/rmq"],
            base_dist_c=flat["graph/dist_c"],
            delta_vecs=flat["stream/delta_vecs"],
            delta_attrs=flat["stream/delta_attrs"],
            delta_ids=flat["stream/delta_ids"],
            next_id=s["next_id"], max_delta=s.get("max_delta", 1024),
            compact_every=s.get("compact_every", 0),
            precisions=s.get("precisions", ()),
            build_kw=s.get("build_kw"),
            wal_lsn=s.get("wal_lsn", 0))
        _preload_quant(stream._view.sub, flat, manifest)
        return stream
    raise ValueError(f"index_from_state: unknown index kind {kind!r}")


def _preload_quant(sub, flat, manifest) -> None:
    for prec in manifest.get("quant", {}):
        sub.preload_quantized(prec, flat[f"quant/{prec}/data"],
                              flat.get(f"quant/{prec}/scale"))


# --------------------------------------------------------------- on disk
class _CrcWriter:
    """File proxy that CRC32s everything written through it, so the
    manifest can record a checksum without re-reading the file."""

    def __init__(self, f):
        self._f = f
        self.crc = 0

    def write(self, data):
        self.crc = zlib.crc32(data, self.crc)
        return self._f.write(data)

    def __getattr__(self, name):
        return getattr(self._f, name)


def _atomic_write(path: Path, write_fn) -> int:
    """tmp → fsync(file) → rename → fsync(dir); returns the CRC32 of the
    written bytes.  The directory fsync is what makes the *rename* itself
    durable — without it a power failure can roll the directory entry
    back even though the file data reached disk."""
    tmp = path.with_name(f"{path.name}.tmp.{os.getpid()}")
    try:
        with open(tmp, "wb") as f:
            w = _CrcWriter(f)
            write_fn(w)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        fsync_dir(path.parent)
        return w.crc
    finally:
        if tmp.exists():
            tmp.unlink()


def is_index_dir(path) -> bool:
    return (Path(path) / MANIFEST).is_file()


def save_index(index, path, *, shards: int = 1) -> dict:
    """Write the sharded directory format; returns the manifest.

    Arrays whose leading axis is the corpus row dimension are split into
    ``shards`` contiguous row slabs (one file each) so restore can fill
    them with parallel reads; small/global arrays stay single-file and
    mmap on restore.  Safe to save over a live directory: the new
    generation's files never collide with the old, and the manifest swap
    is the atomic commit point."""
    flat, man = index_state(index)
    p = Path(path)
    p.mkdir(parents=True, exist_ok=True)
    gen = 0
    if is_index_dir(p):
        try:
            gen = int(json.loads((p / MANIFEST).read_text())
                      .get("gen", 0)) + 1
        except (ValueError, json.JSONDecodeError):
            gen = 1
    shards = max(int(shards), 1)
    n_rows = man["n"]
    arrays: Dict[str, dict] = {}
    for key, a in flat.items():
        base = key.replace("/", ".")
        row_sharded = (shards > 1 and a.ndim >= 1
                       and a.shape[0] == n_rows and n_rows >= shards)
        parts = np.array_split(a, shards) if row_sharded else [a]
        files, crcs = [], []
        for i, part in enumerate(parts):
            fn = f"{base}.g{gen}.{i:02d}.npy"
            crcs.append(_atomic_write(p / fn,
                                      lambda f, part=part: np.save(f, part)))
            files.append(fn)
        arrays[key] = dict(files=files, shape=list(a.shape),
                           dtype=str(a.dtype), crc32=crcs)
    manifest = dict(schema=SCHEMA, gen=gen, shards=shards,
                    index=man, arrays=arrays)
    blob = json.dumps(manifest, indent=1).encode()
    _atomic_write(p / MANIFEST, lambda f: f.write(blob))
    _gc_stale(p, manifest)
    return manifest


def _gc_stale(p: Path, manifest: dict) -> None:
    live = {f for am in manifest["arrays"].values() for f in am["files"]}
    for f in p.iterdir():
        name = f.name
        if name in live or name == MANIFEST:
            continue
        if ".g" in name and (name.endswith(".npy") or ".npy.tmp." in name):
            f.unlink(missing_ok=True)


def _corrupt(p: Path, fn: str, gen, why) -> IndexCorruptionError:
    return IndexCorruptionError(
        f"load_index: array file {fn} in {p} (manifest generation {gen}) "
        f"is truncated or corrupt: {why}")


def _load_checked(p: Path, fn: str, gen, *, mmap_mode=None,
                  expect_crc=None, verify=False) -> np.ndarray:
    """np.load with the raw mmap/parse errors rewritten into
    :class:`IndexCorruptionError` naming the file and generation.  When
    the manifest carries a CRC32 for the file it is verified on every
    full read, and on mmap reads too iff ``verify=True`` (a CRC pass
    forces reading all the bytes, which defeats lazy mmap)."""
    path = p / fn
    try:
        if expect_crc is not None and (verify or mmap_mode is None):
            crc = 0
            with open(path, "rb") as f:
                for chunk in iter(lambda: f.read(1 << 20), b""):
                    crc = zlib.crc32(chunk, crc)
            if crc != expect_crc:
                raise _corrupt(p, fn, gen,
                               f"CRC32 mismatch (manifest {expect_crc:#010x}"
                               f", file {crc:#010x})")
        return np.load(path, mmap_mode=mmap_mode)
    except IndexCorruptionError:
        raise
    except FileNotFoundError as e:
        raise _corrupt(p, fn, gen, f"missing: {e}") from e
    except (ValueError, OSError, EOFError) as e:
        raise _corrupt(p, fn, gen, e) from e


def load_index(path, *, mmap: bool = True, parallel: bool = True,
               workers: int = 8, verify: bool = False):
    """Restore from the directory format.  Single-file arrays mmap (zero
    copy until first touch); row-sharded arrays are filled by a thread
    pool reading all slabs concurrently.  Returns whatever
    :func:`index_from_state` builds for the saved kind.

    Robustness: a truncated or checksum-mangled array file raises
    :class:`IndexCorruptionError` naming the file and the manifest
    generation.  Sharded slabs (read in full anyway) are always CRC32-
    verified against the manifest; mmapped single files are shape/parse
    validated, and ``verify=True`` CRC-checks them too (full read)."""
    p = Path(path)
    manifest = json.loads((p / MANIFEST).read_text())
    if manifest.get("schema", 0) > SCHEMA:
        raise ValueError(f"index at {p} has schema "
                         f"{manifest['schema']} > supported {SCHEMA}")
    gen = manifest.get("gen", 0)
    arrays = manifest["arrays"]
    flat: Dict[str, np.ndarray] = {}
    jobs = []
    for key, am in arrays.items():
        files = am["files"]
        crcs = am.get("crc32") or [None] * len(files)
        if len(files) == 1:
            a = _load_checked(p, files[0], gen,
                              mmap_mode="r" if mmap else None,
                              expect_crc=crcs[0], verify=verify)
            if list(a.shape) != list(am["shape"]):
                raise _corrupt(p, files[0], gen,
                               f"shape {list(a.shape)} != manifest "
                               f"{am['shape']}")
            flat[key] = a
            continue
        out = np.empty(tuple(am["shape"]), dtype=np.dtype(am["dtype"]))
        flat[key] = out
        # slab offsets follow np.array_split's rule: the first n % k slabs
        # get one extra row
        n, k = am["shape"][0], len(files)
        sizes = [n // k + (1 if i < n % k else 0) for i in range(k)]
        row = 0
        for fn, sz, crc in zip(files, sizes, crcs):
            jobs.append((out, row, sz, fn, crc))
            row += sz
    def fill(job):
        out, row0, sz, fn, crc = job
        part = _load_checked(p, fn, gen, expect_crc=crc, verify=verify)
        if len(part) != sz:
            raise _corrupt(p, fn, gen,
                           f"slab has {len(part)} rows, manifest says {sz}")
        out[row0:row0 + len(part)] = part
    if jobs:
        if parallel and len(jobs) > 1:
            with ThreadPoolExecutor(max_workers=workers) as ex:
                list(ex.map(fill, jobs))
        else:
            for j in jobs:
                fill(j)
    return index_from_state(flat, manifest["index"])
