"""KNN substrate: exact blocked brute force (JAX matmul) + NNDescent.

Distances are squared-L2 throughout (monotone in L2, so all pruning rules and
recall are unchanged).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def sq_dists(a: jax.Array, b: jax.Array) -> jax.Array:
    """(Na,d) × (Nb,d) -> (Na,Nb) squared L2 via ‖a‖² - 2a·b + ‖b‖²."""
    an = jnp.sum(a * a, axis=-1, keepdims=True)
    bn = jnp.sum(b * b, axis=-1)
    d = an - 2.0 * (a @ b.T) + bn[None, :]
    return jnp.maximum(d, 0.0)


@partial(jax.jit, static_argnames=("k", "block"))
def _exact_knn_jit(vecs: jax.Array, k: int, block: int):
    n = vecs.shape[0]
    nb = n // block

    def one_block(i):
        q = jax.lax.dynamic_slice_in_dim(vecs, i * block, block)
        d = sq_dists(q, vecs)
        rows = i * block + jnp.arange(block)
        d = d.at[jnp.arange(block), rows].set(jnp.inf)      # exclude self
        nd, ni = jax.lax.top_k(-d, k)
        return -nd, ni

    dists, ids = jax.lax.map(one_block, jnp.arange(nb))
    return dists.reshape(n, k), ids.reshape(n, k)


def exact_knn(vecs: np.ndarray, k: int, block: int = 512) -> Tuple[np.ndarray, np.ndarray]:
    """Exact KNN (ids exclude self). Pads n to a block multiple internally.

    When ``k >= n`` the top-k necessarily spills into the pad rows; those
    slots come back masked (id -1, distance +inf) instead of leaking
    out-of-range pad-row ids into callers' gathers."""
    n = vecs.shape[0]
    pad = (-n) % block
    if pad:  # padded rows sit far away and never enter any real row's top-k
        vecs = np.concatenate(
            [vecs, 1e9 * np.ones((pad, vecs.shape[1]), np.float32)])
    d, i = _exact_knn_jit(jnp.asarray(vecs, jnp.float32), k, block)
    d, i = np.asarray(d[:n]), np.asarray(i[:n])
    oob = i >= n                     # pad-row ids: only reachable when k >= n
    if oob.any():
        i = np.where(oob, -1, i)
        d = np.where(oob, np.inf, d)
    return d, i


# ----------------------------------------------------------------------
@partial(jax.jit, static_argnames=("k", "iters", "block"))
def _nndescent_jit(vecs: jax.Array, init_ids: jax.Array, k: int, iters: int,
                   block: int = 1024):
    n = vecs.shape[0]
    vn = jnp.sum(vecs * vecs, axis=-1)

    def dist_rows(ids):                                     # (n,c) -> dists
        c = ids.shape[1]

        def one(i):
            rows = jax.lax.dynamic_slice_in_dim(ids, i * block, block)   # (b,c)
            q = jax.lax.dynamic_slice_in_dim(vecs, i * block, block)     # (b,d)
            nb = vecs[rows]                                              # (b,c,d)
            dots = jnp.einsum("bd,bcd->bc", q, nb)
            d = vn[rows] - 2.0 * dots + jnp.sum(q * q, -1, keepdims=True)
            return jnp.maximum(d, 0.0)

        assert n % block == 0, (n, block)
        return jax.lax.map(one, jnp.arange(n // block)).reshape(n, c)

    def merge(ids_a, d_a, ids_b, d_b):
        ids = jnp.concatenate([ids_a, ids_b], axis=1)
        d = jnp.concatenate([d_a, d_b], axis=1)
        # dedupe: mark repeats with +inf (sort by id, equal-neighbor mask)
        order = jnp.argsort(ids, axis=1)
        ids_s = jnp.take_along_axis(ids, order, axis=1)
        d_s = jnp.take_along_axis(d, order, axis=1)
        dup = jnp.concatenate(
            [jnp.zeros((n, 1), bool), ids_s[:, 1:] == ids_s[:, :-1]], axis=1)
        self_m = ids_s == jnp.arange(n)[:, None]
        d_s = jnp.where(dup | self_m, jnp.inf, d_s)
        nd, sel = jax.lax.top_k(-d_s, k)
        return jnp.take_along_axis(ids_s, sel, axis=1), -nd

    d0 = dist_rows(init_ids)
    ids, d = merge(init_ids, d0, init_ids, d0)

    def body(_, state):
        ids, d = state
        # neighbors-of-neighbors (forward); reverse edges via transpose sample
        non = ids[ids].reshape(n, -1)                       # (n, k*k)
        d_non = dist_rows(non)
        return merge(ids, d, non, d_non)

    ids, d = jax.lax.fori_loop(0, iters, body, (ids, d))
    return ids, d


def nndescent(vecs: np.ndarray, k: int, iters: int = 6, seed: int = 0,
              block: int = 1024):
    """Approximate KNN graph via fixed-iteration vectorized NNDescent."""
    n = vecs.shape[0]
    pad = (-n) % block
    if pad:
        vecs = np.concatenate(
            [vecs, 1e9 * np.ones((pad, vecs.shape[1]), np.float32)])
    rng = np.random.default_rng(seed)
    init = rng.integers(0, n, (n + pad, k)).astype(np.int32)
    ids, d = _nndescent_jit(jnp.asarray(vecs, jnp.float32), jnp.asarray(init),
                            k, iters, block)
    return np.asarray(d[:n]), np.asarray(ids[:n])


def knn_recall(approx_ids: np.ndarray, exact_ids: np.ndarray) -> float:
    hits = sum(len(set(a) & set(e)) for a, e in zip(approx_ids, exact_ids))
    return hits / exact_ids.size
