"""Baselines the paper compares against, reimplemented on the same substrate:

* ``BruteForceIndex``   — pre-filtering (exact linear scan; also ground truth).
* ``MRNGIndex``         — spatial-only approximate-MRNG graph with
                          ``in-filter`` and ``post-filter`` query modes.
* ``SegmentTreeIndex``  — iRangeGraph-like: one elemental (MRNG-pruned) graph
                          per segment-tree node; queries decompose the rank
                          interval into maximal aligned blocks and search the
                          composed graph with one entry per canonical block.

All share ids = attribute ranks and squared-L2 distances.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.beam import beam_search_batch
from repro.core.entry import build_rmq, centroid_dists
from repro.core.pruning import _prune_side_batch
from repro.data.ann import ground_truth
from repro.index.knn import exact_knn, sq_dists
from repro.search import rank_interval, remap_ids, select_entry


def _sorted_by_dist(knn_ids: np.ndarray) -> np.ndarray:
    return knn_ids  # exact_knn already returns ascending-distance order


def mrng_prune_graph(vecs: np.ndarray, knn_ids: np.ndarray, m: int,
                     block: int = 2048) -> np.ndarray:
    """Plain MRNG/NSG pruning: scan candidates by ascending distance, keep v_i
    iff no kept v_j with d(x,v_j) < d(x,v_i) and d(v_j,v_i) < d(x,v_i)."""
    n = vecs.shape[0]
    v = jnp.asarray(vecs, jnp.float32)
    out = np.full((n, m), -1, np.int32)
    cand = _sorted_by_dist(knn_ids).astype(np.int32)
    for lo in range(0, n, block):
        hi = min(lo + block, n)
        ci = jnp.asarray(cand[lo:hi])
        cv = v[jnp.maximum(ci, 0)]
        kept = np.asarray(_prune_side_batch(v[lo:hi], ci, cv, m))
        for b in range(hi - lo):
            ids = cand[lo + b][kept[b]]
            out[lo + b, :len(ids)] = ids[:m]
    return out


def add_reverse_edges(nbrs: np.ndarray, cap: int) -> np.ndarray:
    """NSG-style reverse-edge augmentation, degree-capped."""
    n, m = nbrs.shape
    ext = np.full((n, cap), -1, np.int32)
    ext[:, :m] = nbrs
    fill = (nbrs >= 0).sum(1)
    for u in range(n):
        for v in nbrs[u]:
            if v < 0:
                break
            if fill[v] < cap and u not in ext[v, :fill[v]]:
                ext[v, fill[v]] = u
                fill[v] += 1
    return ext


def connectivity_repair(nbrs: np.ndarray, vecs: np.ndarray, entry: int) -> np.ndarray:
    """NSG-style tree growing: label undirected components once, then link
    every stray component to the entry's component through its closest cross
    pair (bidirectional; may evict the worst slot)."""
    n, m = nbrs.shape
    nbrs = nbrs.copy()
    comp = np.full(n, -1, np.int64)
    cid = 0
    for src in range(n):
        if comp[src] >= 0:
            continue
        comp[src] = cid
        stack = [src]
        while stack:
            u = stack.pop()
            for v in nbrs[u]:
                if v >= 0 and comp[v] < 0:
                    comp[v] = cid
                    stack.append(int(v))
        cid += 1
    # undirected closure: merge labels across reverse edges (a few sweeps)
    for _ in range(4):
        changed = False
        src = np.repeat(np.arange(n), m)
        dst = nbrs.reshape(-1)
        ok = dst >= 0
        a, b = comp[src[ok]], comp[dst[ok]]
        lo = np.minimum(a, b)
        if np.any(a != lo):
            remap = np.arange(cid)
            np.minimum.at(remap, np.maximum(a, b), lo)
            while np.any(remap[remap] != remap):
                remap = remap[remap]
            comp = remap[comp]
            changed = True
        if not changed:
            break
    main = comp[entry]
    vmain = np.flatnonzero(comp == main)
    for c in np.unique(comp):
        if c == main:
            continue
        members = np.flatnonzero(comp == c)
        d = np.asarray(sq_dists(jnp.asarray(vecs[members]),
                                jnp.asarray(vecs[vmain])))
        oi, ii = np.unravel_index(np.argmin(d), d.shape)
        u, v = int(members[oi]), int(vmain[ii])
        for a, b in ((u, v), (v, u)):
            row = nbrs[a]
            slot = int(np.argmax(row < 0)) if (row < 0).any() else m - 1
            nbrs[a, slot] = b
    return nbrs


# ----------------------------------------------------------------------
class BruteForceIndex:
    """Pre-filtering: exact scan over the in-range subset."""

    def __init__(self, vectors, attrs):
        order = np.argsort(attrs, kind="stable")
        self.vecs = np.asarray(vectors, np.float32)[order]
        self.attrs = np.asarray(attrs, np.float32)[order]
        self.order = order.astype(np.int32)
        self.build_seconds = 0.0

    def search(self, queries, attr_ranges, *, k=10, **_):
        ids, d = ground_truth(self.vecs, self.attrs, queries, attr_ranges, k)
        return remap_ids(self.order, ids), d, {}

    @property
    def index_bytes(self):
        return 0  # no graph structure


# ----------------------------------------------------------------------
class MRNGIndex:
    """Spatial-only graph (the paper's Fig.1 failure case under ranges)."""

    def __init__(self, vectors, attrs, *, m=32, ef_spatial=64,
                 mode: str = "infilter", oversample: int = 4):
        t0 = time.perf_counter()
        order = np.argsort(attrs, kind="stable")
        self.vecs = np.asarray(vectors, np.float32)[order]
        self.attrs = np.asarray(attrs, np.float32)[order]
        self.order = order.astype(np.int32)
        _, knn_ids = exact_knn(self.vecs, ef_spatial)
        self.nbrs = mrng_prune_graph(self.vecs, knn_ids, m)
        self.nbrs = add_reverse_edges(self.nbrs, m)
        self.centroid, self.dist_c = centroid_dists(self.vecs)
        self.rmq = build_rmq(self.dist_c)
        entry = int(np.argmin(self.dist_c))
        self.nbrs = connectivity_repair(self.nbrs, self.vecs, entry)
        self.mode = mode
        self.oversample = oversample
        self.build_seconds = time.perf_counter() - t0
        self._v = jnp.asarray(self.vecs)
        self._nb = jnp.asarray(self.nbrs)
        self._rmq = jnp.asarray(self.rmq)
        self._dc = jnp.asarray(self.dist_c)

    @property
    def index_bytes(self):
        return self.nbrs.nbytes + self.rmq.nbytes + self.dist_c.nbytes

    def search(self, queries, attr_ranges, *, k=10, ef=64, **_):
        n = len(self.attrs)
        lo, hi = rank_interval(self.attrs, attr_ranges)
        qv = jnp.asarray(queries, jnp.float32)
        if self.mode == "infilter":
            lo_j, hi_j = jnp.asarray(lo), jnp.asarray(hi)
            entry = select_entry(self._rmq, self._dc, lo_j, hi_j, n)
            ids, d, st = beam_search_batch(self._v, self._nb, qv, lo_j, hi_j,
                                           entry, k=k, ef=max(ef, k))
        else:  # postfilter: unfiltered search, oversampled, then range filter
            big = max(ef, k * self.oversample)
            zeros = jnp.zeros(len(lo), jnp.int32)
            full_hi = jnp.full(len(hi), n - 1, jnp.int32)
            entry = select_entry(self._rmq, self._dc, zeros, full_hi, n)
            ids, d, st = beam_search_batch(self._v, self._nb, qv, zeros, full_hi,
                                           entry, k=big, ef=big)
            idn = np.asarray(ids)
            dn = np.asarray(d)
            in_range = (idn >= lo[:, None]) & (idn <= hi[:, None]) & (idn >= 0)
            dn = np.where(in_range, dn, np.inf)
            sel = np.argsort(dn, axis=1)[:, :k]
            ids = np.take_along_axis(idn, sel, axis=1)
            d = np.take_along_axis(dn, sel, axis=1)
            ids = np.where(np.isfinite(d), ids, -1)
        orig = remap_ids(self.order, np.asarray(ids))
        return orig, np.asarray(d), jax.tree.map(np.asarray, st)


# ----------------------------------------------------------------------
@partial(jax.jit, static_argnames=("k", "ef", "max_steps", "smin"))
def _segtree_beam(vecs, nbrs_lvl, qv, lo, hi, entries, *, k, ef,
                  max_steps=0, smin=0):
    """Beam search over the composed segment-tree graph.
    nbrs_lvl: (LEVELS, n, m); a node's adjacency row comes from the level of
    the maximal aligned block containing it inside [lo, hi]."""
    levels, n, m = nbrs_lvl.shape
    steps_cap = max_steps or 8 * ef + 64

    def lvl_of(v, L, R):
        def body(s, best):
            start = (v >> s) << s
            ok = (start >= L) & (start + (1 << s) - 1 <= R)
            return jnp.where(ok, s, best)
        return jax.lax.fori_loop(0, levels, body, jnp.int32(0))

    def one(q, L, R, e0):
        e0 = e0[:ef]                         # entry list never exceeds the pool
        ev = (e0 >= 0)
        e0c = jnp.clip(e0, 0, n - 1)
        d0 = jnp.where(ev, jnp.sum(jnp.square(vecs[e0c] - q[None, :]), -1), jnp.inf)
        ne = e0.shape[0]
        cand_ids = jnp.full((ef,), -1, jnp.int32).at[:ne].set(e0c.astype(jnp.int32))
        cand_d = jnp.full((ef,), jnp.inf).at[:ne].set(d0)
        expanded = jnp.zeros((ef,), bool).at[:ne].set(~ev)
        visited = jnp.zeros((n + 1,), bool).at[jnp.where(ev, e0c, n)].set(True)

        def cond(st):
            cand_d, expanded, _, _, steps, _ = st
            best = jnp.min(jnp.where(~expanded, cand_d, jnp.inf))
            worst = jnp.where(jnp.any(~jnp.isfinite(cand_d)), jnp.inf,
                              jnp.max(jnp.where(jnp.isfinite(cand_d), cand_d, -jnp.inf)))
            return (best <= worst) & (steps < steps_cap)

        def body(st):
            cand_d, expanded, cand_ids, visited, steps, ndist = st
            bi = jnp.argmin(jnp.where(~expanded, cand_d, jnp.inf))
            expanded = expanded.at[bi].set(True)
            node = jnp.maximum(cand_ids[bi], 0)
            nb = nbrs_lvl[lvl_of(node, L, R), node]
            valid = (nb >= 0) & (nb >= L) & (nb <= R) & ~visited[jnp.maximum(nb, 0)]
            visited = visited.at[jnp.where(valid, nb, n)].set(True)
            nv = vecs[jnp.maximum(nb, 0)]
            d_nb = jnp.where(valid, jnp.sum(jnp.square(nv - q[None, :]), -1), jnp.inf)
            ids_all = jnp.concatenate([cand_ids, nb.astype(jnp.int32)])
            d_all = jnp.concatenate([cand_d, d_nb])
            exp_all = jnp.concatenate([expanded, ~valid])
            order = jnp.argsort(d_all)[:ef]
            return (d_all[order], exp_all[order], ids_all[order], visited,
                    steps + 1, ndist + jnp.sum(valid))

        st = (cand_d, expanded, cand_ids, visited,
              jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
        cand_d, _, cand_ids, _, steps, ndist = jax.lax.while_loop(cond, body, st)
        return (jnp.where(jnp.isfinite(cand_d[:k]), cand_ids[:k], -1),
                cand_d[:k], steps, ndist)

    ids, d, steps, ndist = jax.vmap(one)(qv, lo, hi, entries)
    return ids, d, {"hops": steps, "ndist": ndist}


class SegmentTreeIndex:
    """iRangeGraph-like: elemental MRNG graphs on every segment-tree node."""

    def __init__(self, vectors, attrs, *, m=16, ef_spatial=48):
        t0 = time.perf_counter()
        order = np.argsort(attrs, kind="stable")
        self.vecs = np.asarray(vectors, np.float32)[order]
        self.attrs = np.asarray(attrs, np.float32)[order]
        self.order = order.astype(np.int32)
        n = len(self.attrs)
        depth = max(1, int(np.ceil(np.log2(max(n, 2)))))
        self.levels = depth + 1
        self.m = m
        nbrs = np.full((self.levels, n, m), -1, np.int32)
        kmax = max(ef_spatial, m)
        for s in range(self.levels):
            size = 1 << s
            if size <= 1:
                continue
            # per-level batched block-local KNN (one vectorized pass per level)
            k = min(kmax, size - 1)
            knn_lvl = np.full((n, k), -1, np.int32)
            for start in range(0, n, size):
                end = min(start + size, n)
                bn = end - start
                if bn <= 1:
                    continue
                blk = self.vecs[start:end]
                d2 = np.sum(blk * blk, 1)[:, None] - 2 * blk @ blk.T \
                    + np.sum(blk * blk, 1)[None, :]
                np.fill_diagonal(d2, np.inf)
                kk = min(k, bn - 1)
                idx = np.argpartition(d2, kth=kk - 1, axis=1)[:, :kk]
                row_d = np.take_along_axis(d2, idx, axis=1)
                o = np.argsort(row_d, axis=1)
                knn_lvl[start:end, :kk] = np.take_along_axis(idx, o, axis=1) + start
            g = mrng_prune_graph(self.vecs, knn_lvl, m)
            g = add_reverse_edges(g, m)
            # repair per block only when actually disconnected (rare for
            # blocks ≲ ef_spatial, where the candidate set is near-complete)
            for start in range(0, n, size):
                end = min(start + size, n)
                bn = end - start
                if bn <= 2:
                    continue
                sub = g[start:end]
                loc = np.where(sub >= 0, sub - start, -1)
                blk = self.vecs[start:end]
                dl = np.sum((blk - blk.mean(0)) ** 2, axis=1)
                ent = int(np.argmin(dl))
                seen = np.zeros(bn, bool)
                seen[ent] = True
                stack = [ent]
                while stack:
                    u = stack.pop()
                    for vv in loc[u]:
                        if vv >= 0 and not seen[vv]:
                            seen[vv] = True
                            stack.append(int(vv))
                if not seen.all():
                    loc = connectivity_repair(loc, blk, ent)
                g[start:end] = np.where(loc >= 0, loc + start, -1)
            nbrs[s] = g
        self.nbrs = nbrs
        self.centroid, self.dist_c = centroid_dists(self.vecs)
        self.rmq = build_rmq(self.dist_c)
        self.build_seconds = time.perf_counter() - t0
        self._v = jnp.asarray(self.vecs)
        self._nb = jnp.asarray(self.nbrs)
        self._rmq = jnp.asarray(self.rmq)
        self._dc = jnp.asarray(self.dist_c)

    @property
    def index_bytes(self):
        return self.nbrs.nbytes + self.rmq.nbytes + self.dist_c.nbytes

    def _canonical_entries(self, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
        """One entry (centroid-nearest node) per maximal aligned block."""
        out = np.full((len(lo), 2 * self.levels), -1, np.int32)
        for qi, (L, R) in enumerate(zip(lo, hi)):
            if L > R:
                continue
            segs = []
            v = int(L)
            while v <= R:
                s = 0
                while s + 1 < self.levels:
                    size = 1 << (s + 1)
                    if v % size == 0 and v + size - 1 <= R:
                        s += 1
                    else:
                        break
                segs.append((v, v + (1 << s) - 1))
                v += 1 << s
            from repro.core.entry import rmq_query_np
            for j, (a, b) in enumerate(segs[:out.shape[1]]):
                out[qi, j] = rmq_query_np(self.rmq, self.dist_c, a, b)
        return out

    def search(self, queries, attr_ranges, *, k=10, ef=64, **_):
        lo, hi = rank_interval(self.attrs, attr_ranges)
        entries = self._canonical_entries(lo, hi)
        ids, d, st = _segtree_beam(self._v, self._nb, jnp.asarray(queries, jnp.float32),
                                   jnp.asarray(lo), jnp.asarray(hi),
                                   jnp.asarray(entries), k=k, ef=max(ef, k))
        orig = remap_ids(self.order, np.asarray(ids))
        return orig, np.asarray(d), jax.tree.map(np.asarray, st)
