"""Adaptive query planner: selectivity-aware routing between the exact fused
range-scan kernel and graph beam search (see docs/planner.md).

The planner is pure policy — the online-calibrated cost model, pow2
bucketing, the per-query routing decision (``choose_strategy`` scalar /
``choose_strategy_batch`` vectorized), and ``plan_batch`` partitioning.  It
never dispatches; execution — kernel dispatch, padding, stitching — lives
in the unified search substrate (``repro.search.SearchSubstrate`` on the
host, ``repro.search.MeshSubstrate`` under ``shard_map``, which runs
``choose_strategy_batch`` host-side and passes the strategy vector into the
trace as a replicated operand)."""
from repro.planner.bucketing import (bucket_for_len, ef_bucket, ef_bucket_np,
                                     next_pow2, next_pow2_np, pad_pow2,
                                     window_rows, window_rows_np)
from repro.planner.cost import CostModel
from repro.planner.planner import BEAM, SCAN, Partition, Plan, QueryPlanner

__all__ = ["CostModel", "QueryPlanner", "Plan", "Partition",
           "SCAN", "BEAM", "bucket_for_len", "ef_bucket", "ef_bucket_np",
           "next_pow2", "next_pow2_np", "pad_pow2", "window_rows",
           "window_rows_np"]
