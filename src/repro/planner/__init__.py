"""Adaptive query planner: selectivity-aware routing between the exact fused
range-scan kernel and graph beam search (see docs/planner.md).

The planner is pure policy (cost model + batch partitioning).  Execution —
kernel dispatch, padding, stitching — lives in the unified search substrate
(``repro.search.SearchSubstrate``), which consumes ``plan_batch`` output."""
from repro.planner.bucketing import (bucket_for_len, ef_bucket, next_pow2,
                                     pad_pow2, window_rows)
from repro.planner.cost import CostModel
from repro.planner.planner import BEAM, SCAN, Partition, Plan, QueryPlanner

__all__ = ["CostModel", "QueryPlanner", "Plan", "Partition",
           "SCAN", "BEAM", "bucket_for_len", "ef_bucket", "next_pow2",
           "pad_pow2", "window_rows"]
