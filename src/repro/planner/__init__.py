"""Adaptive query planner: selectivity-aware routing between the exact fused
range-scan kernel and graph beam search (see docs/planner.md)."""
from repro.planner.bucketing import (bucket_for_len, ef_bucket, next_pow2,
                                     pad_pow2, window_rows)
from repro.planner.cost import CostModel
from repro.planner.executor import PlanExecutor
from repro.planner.planner import BEAM, SCAN, Partition, Plan, QueryPlanner

__all__ = ["CostModel", "PlanExecutor", "QueryPlanner", "Plan", "Partition",
           "SCAN", "BEAM", "bucket_for_len", "ef_bucket", "next_pow2",
           "pad_pow2", "window_rows"]
