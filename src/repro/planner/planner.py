"""Selectivity-aware query planner: route each range query to the cheapest
correct strategy.

Given a batch of rank intervals ``[L, R]`` (ranks are free — the index
already computes them), the planner estimates per-query selectivity
``(R−L+1)/n``, prices the two strategies with the online-calibrated
``CostModel``, and partitions the batch:

* ``scan``  — exact fused brute-force over the contiguous rank slice
              (narrow ranges; always used for empty/degenerate intervals),
* ``beam``  — graph beam search with a selectivity-scaled ``ef``
              (wide ranges, where traversal touches a small fraction of the
              slice).

Each partition carries a pow2 bucket signature so the executor dispatches it
as one fixed-shape jit call regardless of batch mix.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import List, Tuple

import numpy as np

from repro.planner.bucketing import (buckets_np, bucket_for_len, ef_bucket,
                                     ef_bucket_np, next_pow2, pad_pow2,
                                     window_rows, window_rows_np)
from repro.planner.cost import CostModel

SCAN, BEAM = 0, 1


@dataclass
class Partition:
    kind: str                 # "scan" | "beam"
    param: int                # scan: bucket; beam: ef
    indices: np.ndarray       # positions in the request batch
    pad_q: int                # padded batch size for this dispatch

    @property
    def signature(self) -> Tuple[str, int, int]:
        return (self.kind, self.param, self.pad_q)


@dataclass
class Plan:
    strategy: np.ndarray                  # (Q,) int8: 0 scan / 1 beam
    partitions: List[Partition] = field(default_factory=list)

    @property
    def scan_frac(self) -> float:
        return float((self.strategy == SCAN).mean()) if len(self.strategy) else 0.0


class QueryPlanner:
    def __init__(self, n: int, mean_degree: float, *,
                 min_bucket: int = 64, max_scan_frac: float = 0.125,
                 scan_unit: float = 0.125, decay: float = 0.9):
        self.n = int(n)
        self.cost = CostModel(mean_degree, scan_unit=scan_unit, decay=decay)
        self.min_bucket = int(min_bucket)
        # hard selectivity ceiling for the scan strategy: above this fraction
        # the slice no longer fits the "few hundred candidates" regime and the
        # graph's sublinear traversal wins asymptotically
        self.max_scan_len = max(self.min_bucket,
                                int(max_scan_frac * self.n))
        self.max_bucket = next_pow2(self.n)
        # bumped by save_calibration: fences auto-routed cache entries (a
        # persisted calibration change may route a repeat query differently,
        # so SearchCache expires auto rows stored under an older epoch)
        self.calibration_epoch = 0

    # ----------------------------------------------------- routing decision
    def choose_strategy(self, length: int, *, k: int, ef: int,
                        beam_width: int = 1, precision: str = "f32") -> int:
        """Per-query cost-based routing for one rank-interval length.

        Scalar reference semantics for ``choose_strategy_batch`` (the unit
        tests hold the two in lockstep): empty and ``len ≤ k`` slices always
        scan (exact and ~free), slices above the selectivity ceiling always
        beam, and in between the calibrated cost model decides —
        ``beam_width`` selects which batched-expansion regime prices the
        beam side."""
        ln = int(length)
        if ln <= 0 or ln <= k:
            return SCAN
        if ln > self.max_scan_len:
            return BEAM
        bucket = bucket_for_len(ln, min_bucket=self.min_bucket,
                                max_bucket=self.max_bucket)
        scan_cost = self.cost.predict_scan_units(window_rows(bucket),
                                                 precision=precision)
        beam_cost = self.cost.predict_beam_units(ef_bucket(ln, k, ef),
                                                 beam_width,
                                                 precision=precision)
        return SCAN if scan_cost <= beam_cost else BEAM

    def predict_costs(self, lens: np.ndarray, *, k: int, ef: int,
                      beam_width: int = 1, precision: str = "f32"
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """(Q,) lengths -> per-query (scan_cost, beam_cost) in beam distance
        units, from the current calibrated model.  This is the exact pricing
        ``choose_strategy_batch`` routes on — also recorded into the plan
        span of traced requests so "what did the planner see?" is
        answerable after the fact."""
        lens = np.asarray(lens, np.int64)
        buckets = buckets_np(lens, min_bucket=self.min_bucket,
                             max_bucket=self.max_bucket)
        scan_cost = (self.cost.predict_scan_units(1, precision=precision) *
                     window_rows_np(buckets).astype(np.float64))
        beam_cost = (self.cost.beam_unit *
                     self.cost.ndist_per_ef_at(beam_width) *
                     self.cost.precision_factor("beam", precision) *
                     ef_bucket_np(lens, k, ef).astype(np.float64))
        return scan_cost, beam_cost

    def choose_strategy_batch(self, lens: np.ndarray, *, k: int, ef: int,
                              beam_width: int = 1,
                              precision: str = "f32") -> np.ndarray:
        """Vectorized ``choose_strategy``: (Q,) lengths -> (Q,) int8 strategy
        vector (``SCAN``/``BEAM``).  Pure numpy over the whole batch — this
        is the host-side half of mesh dispatch, where the strategy vector is
        computed once and passed into ``shard_map`` as a replicated operand."""
        lens = np.asarray(lens, np.int64)
        scan_cost, beam_cost = self.predict_costs(lens, k=k, ef=ef,
                                                  beam_width=beam_width,
                                                  precision=precision)
        eligible = lens <= self.max_scan_len
        use_scan = (eligible & (scan_cost <= beam_cost)) | (lens <= 0) \
            | (lens <= k)                  # tiny slices: scan is exact & free
        return np.where(use_scan, SCAN, BEAM).astype(np.int8)

    # ------------------------------------------------------------------
    def plan_batch(self, lo: np.ndarray, hi: np.ndarray, *, k: int, ef: int,
                   mode: str = "auto", beam_width: int = 1,
                   precision: str = "f32") -> Plan:
        """lo/hi: (Q,) int rank intervals (inclusive; lo > hi = empty).
        mode: "auto" (cost-based) | "scan" | "beam" (forced)."""
        lo = np.asarray(lo, np.int64)
        hi = np.asarray(hi, np.int64)
        q = len(lo)
        lens = np.clip(hi - lo + 1, 0, None)
        buckets = buckets_np(lens, min_bucket=self.min_bucket,
                             max_bucket=self.max_bucket)
        if mode == "scan":
            use_scan = np.ones(q, bool)
        elif mode == "beam":
            use_scan = lens <= 0           # beam cannot express empty ranges
        else:
            use_scan = self.choose_strategy_batch(
                lens, k=k, ef=ef, beam_width=beam_width,
                precision=precision) == SCAN
        strategy = np.where(use_scan, SCAN, BEAM).astype(np.int8)

        partitions: List[Partition] = []
        scan_idx = np.flatnonzero(use_scan)
        for b in np.unique(buckets[scan_idx]) if len(scan_idx) else []:
            idx = scan_idx[buckets[scan_idx] == b]
            partitions.append(Partition("scan", int(b), idx,
                                        pad_pow2(len(idx))))
        beam_idx = np.flatnonzero(~use_scan)
        if len(beam_idx):
            efs = np.asarray([ef_bucket(int(lens[i]), k, ef)
                              for i in beam_idx], np.int64)
            for e in np.unique(efs):
                idx = beam_idx[efs == e]
                partitions.append(Partition("beam", int(e), idx,
                                            pad_pow2(len(idx))))
        # a plan never carries an empty partition (beam dispatch pads by
        # duplicating idx[-1], which needs at least one real query)
        return Plan(strategy=strategy,
                    partitions=[p for p in partitions if len(p.indices)])

    # ------------------------------------------------------------------
    def save_calibration(self, path: str) -> None:
        """Persist the online-calibrated cost model (JSON) so a restarted
        server starts from steady-state routing instead of the prior.

        Atomic: the state is written to a sibling temp file, fsynced, and
        renamed over ``path`` — a crash mid-shutdown can never leave a
        truncated file for the next startup's ``load_calibration`` — and
        the parent directory is fsynced after the rename so the rename
        itself is durable (``repro.index.io.fsync_dir``)."""
        from repro.index.io import fsync_dir
        state = dict(version=1, n=self.n, cost=self.cost.state_dict())
        tmp = f"{path}.tmp.{os.getpid()}"
        try:
            with open(tmp, "w") as f:
                json.dump(state, f, indent=2, sort_keys=True)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
            fsync_dir(os.path.dirname(os.path.abspath(path)) or ".")
            # persisted calibration is the fence auto-routed cache rows were
            # stored under; bump so stale routing decisions expire on lookup
            self.calibration_epoch += 1
        finally:
            if os.path.exists(tmp):
                os.remove(tmp)

    def load_calibration(self, path: str) -> None:
        """Raises ValueError on a schema or corpus mismatch — calibration
        units are only meaningful for the index they were measured on."""
        with open(path) as f:
            state = json.load(f)
        if state.get("version") != 1:
            raise ValueError(f"unsupported calibration version "
                             f"{state.get('version')!r} in {path}")
        if state.get("n") != self.n:
            raise ValueError(f"calibration in {path} was measured on a "
                             f"corpus of n={state.get('n')}, this index has "
                             f"n={self.n}")
        self.cost.load_state_dict(state["cost"])
