"""Online-calibrated cost model: fused range scan vs graph beam search.

Costs are expressed in *beam distance units* (one gather-expanded neighbor
distance ≡ 1).  A row scanned inside the fused ``range_scan`` kernel is much
cheaper — it is one row of a batched MXU matmul rather than a dependent
gather inside a sequential ``while_loop`` — so it is weighted by
``scan_unit`` < 1.

Two quantities are calibrated online:

* ``ndist_per_ef`` — predicted beam distance evaluations per unit of ``ef``,
  an EMA over the ``ndist`` stats every beam batch already returns (prior:
  the graph's mean out-degree, i.e. ndist ≈ ef · m̄).  Calibrated **per
  beam width**: the batched-expansion path (``beam_width > 1``) explores a
  slightly different frontier (speculative multi-node hops plus lossy-
  visited re-scores), so each width keeps its own EMA and unseen widths
  fall back to the nearest calibrated one.
* ``scan_unit`` — refined from observed per-unit wall times of executed scan
  and beam partitions (warm calls only; the executor skips the first call of
  each jit signature so compile time never poisons the estimate).

Per-precision pricing: quantized corpora (int8/bf16) move fewer bytes per
scored row, so scan and beam units are cheaper under them.  Wall-time EMAs
are kept **per precision** (``{"f32": ..., "int8": ...}``); the predicted
cost of a precision is the f32 cost times a factor — the measured
``us[precision] / us["f32"]`` ratio once both are observed, else a static
bandwidth-derived prior (``PRECISION_PRIOR``).  The routing decision thus
shifts toward scan under quantization exactly as fast as the hardware
actually delivers the bandwidth win.
"""
from __future__ import annotations

from typing import Dict, Optional

#: prior per-unit cost relative to f32, before any wall observation of that
#: precision lands.  int8 moves 4× fewer bytes (≈0.25) plus rerank overhead;
#: bf16 moves 2× fewer (≈0.5) plus rerank overhead.
PRECISION_PRIOR: Dict[str, float] = {"f32": 1.0, "bf16": 0.6, "int8": 0.35}


class CostModel:
    def __init__(self, mean_degree: float, *, scan_unit: float = 0.125,
                 decay: float = 0.9):
        self.scan_unit = float(scan_unit)
        self.beam_unit = 1.0
        self._ndist_per_ef: Dict[int, float] = {1: float(max(mean_degree,
                                                             1.0))}
        self._beam_obs_w: Dict[int, int] = {}   # observations per beam width
        self.decay = float(decay)
        self.beam_obs = 0
        self.scan_wall_obs = 0                  # observe_wall feeds per kind
        self.beam_wall_obs = 0
        # wall us per scanned row / per beam distance, keyed by precision
        self._scan_us_p: Dict[str, float] = {}
        self._beam_us_p: Dict[str, float] = {}

    # f32 scalar view (back-compat: snapshots/state predating precisions)
    @property
    def _scan_us(self) -> Optional[float]:
        return self._scan_us_p.get("f32")

    @property
    def _beam_us(self) -> Optional[float]:
        return self._beam_us_p.get("f32")

    # back-compat scalar view (width-1 regime) -----------------------------
    @property
    def ndist_per_ef(self) -> float:
        return self._ndist_per_ef[1]

    @ndist_per_ef.setter
    def ndist_per_ef(self, value: float) -> None:
        self._ndist_per_ef[1] = float(value)

    def ndist_per_ef_at(self, beam_width: int = 1) -> float:
        """Per-width EMA; an uncalibrated width borrows the nearest
        calibrated width's value (re-score overhead varies smoothly)."""
        w = max(int(beam_width), 1)
        if w in self._ndist_per_ef:
            return self._ndist_per_ef[w]
        nearest = min(self._ndist_per_ef, key=lambda o: abs(o - w))
        return self._ndist_per_ef[nearest]

    # ---------------------------------------------------------- precisions
    def precision_factor(self, kind: str, precision: str = "f32") -> float:
        """Per-unit cost of ``precision`` relative to f32 for one strategy
        (``kind`` in {"scan", "beam"}): the measured wall-us ratio when both
        precisions have been observed, else the bandwidth prior."""
        if precision == "f32":
            return 1.0
        us = self._scan_us_p if kind == "scan" else self._beam_us_p
        f32, this = us.get("f32"), us.get(precision)
        if f32 and this:
            return this / f32
        return PRECISION_PRIOR.get(precision, 1.0)

    # ------------------------------------------------------------- predict
    def predict_beam_units(self, ef: int, beam_width: int = 1,
                           precision: str = "f32") -> float:
        return (self.beam_unit * self.ndist_per_ef_at(beam_width) *
                float(ef) * self.precision_factor("beam", precision))

    def predict_scan_units(self, window_rows: int,
                           precision: str = "f32") -> float:
        return (self.scan_unit * float(window_rows) *
                self.precision_factor("scan", precision))

    # ----------------------------------------------------------- calibrate
    def update_beam(self, ndist_mean: float, ef: int,
                    beam_width: int = 1) -> None:
        """Feed observed per-query distance evaluations from a beam batch.
        The first observation **of this width** replaces its seed (the
        construction prior, or a value borrowed from the nearest calibrated
        width) — measured data for the exact width beats any transfer;
        later observations decay-blend."""
        if ef <= 0 or not (ndist_mean >= 0):
            return
        w = max(int(beam_width), 1)
        r = float(ndist_mean) / float(ef)
        w_obs = self._beam_obs_w.get(w, 0)
        a = self.decay if w_obs else 0.0
        self._ndist_per_ef[w] = a * self.ndist_per_ef_at(w) + (1.0 - a) * r
        self._beam_obs_w[w] = w_obs + 1
        self.beam_obs += 1

    def observe_wall(self, strategy: str, units_per_query: float,
                     seconds: float, nq: int,
                     precision: str = "f32") -> None:
        """Feed measured wall time of one executed (warm) partition.  The
        EMA lands in the ``precision``'s slot; the scan/beam relative weight
        (``scan_unit``) re-anchors on f32 timings only so quantized traffic
        cannot skew the baseline strategy ratio."""
        if nq <= 0 or units_per_query <= 0 or seconds <= 0:
            return
        per_unit = seconds * 1e6 / nq / units_per_query
        us = self._scan_us_p if strategy == "scan" else self._beam_us_p
        if strategy == "scan":
            self.scan_wall_obs += 1
        else:
            self.beam_wall_obs += 1
        prev = us.get(precision)
        us[precision] = per_unit if prev is None else \
            self.decay * prev + (1.0 - self.decay) * per_unit
        if self._scan_us and self._beam_us:
            # re-anchor the relative per-unit weight on real hardware timings
            self.scan_unit = self._scan_us / self._beam_us

    def observe_wall_mixed(self, scan_units_total: float,
                           beam_units_total: float, seconds: float,
                           n_scan: int, n_beam: int,
                           precision: str = "f32") -> None:
        """Feed one **fused** dispatch that executed a scan group and a beam
        group in a single traced call (the mesh path's branchless body) —
        the wall time cannot be measured per group, so it is attributed
        proportionally to each group's *predicted* unit cost and fed through
        ``observe_wall``.  The split self-corrects: if e.g. scan is really
        cheaper than predicted, its attributed share shrinks on the next
        update as ``scan_unit`` re-anchors."""
        if seconds <= 0:
            return
        su = self.scan_unit * float(scan_units_total)
        bu = self.beam_unit * float(beam_units_total)
        tot = su + bu
        if tot <= 0:
            return
        if scan_units_total > 0 and n_scan > 0:
            self.observe_wall("scan", scan_units_total / n_scan,
                              seconds * su / tot, n_scan,
                              precision=precision)
        if beam_units_total > 0 and n_beam > 0:
            self.observe_wall("beam", beam_units_total / n_beam,
                              seconds * bu / tot, n_beam,
                              precision=precision)

    def snapshot(self) -> dict:
        return dict(scan_unit=round(self.scan_unit, 5),
                    ndist_per_ef=round(self.ndist_per_ef, 2),
                    ndist_per_ef_bw={w: round(v, 2)
                                     for w, v in self._ndist_per_ef.items()},
                    beam_obs=self.beam_obs,
                    beam_obs_bw=dict(self._beam_obs_w),
                    scan_wall_obs=self.scan_wall_obs,
                    beam_wall_obs=self.beam_wall_obs,
                    scan_us=self._scan_us, beam_us=self._beam_us,
                    scan_us_p=dict(self._scan_us_p),
                    beam_us_p=dict(self._beam_us_p))

    # -------------------------------------------------------- persistence
    def state_dict(self) -> dict:
        """Full calibration state (JSON-serializable, exact restore).
        ``ndist_per_ef`` stays the width-1 scalar so calibration files
        written before the batched-expansion regime load unchanged; the
        per-width EMAs ride along under ``ndist_per_ef_bw``, and the
        per-precision wall EMAs under ``scan_us_p``/``beam_us_p`` (the old
        scalar ``scan_us``/``beam_us`` keys keep the f32 values, so files
        round-trip across the precision boundary in both directions)."""
        return dict(scan_unit=self.scan_unit, beam_unit=self.beam_unit,
                    ndist_per_ef=self.ndist_per_ef,
                    ndist_per_ef_bw={str(w): v
                                     for w, v in self._ndist_per_ef.items()},
                    beam_obs_bw={str(w): c
                                 for w, c in self._beam_obs_w.items()},
                    decay=self.decay, beam_obs=self.beam_obs,
                    scan_wall_obs=self.scan_wall_obs,
                    beam_wall_obs=self.beam_wall_obs,
                    scan_us=self._scan_us, beam_us=self._beam_us,
                    scan_us_p=dict(self._scan_us_p),
                    beam_us_p=dict(self._beam_us_p))

    def load_state_dict(self, state: dict) -> None:
        self.scan_unit = float(state["scan_unit"])
        self.beam_unit = float(state.get("beam_unit", 1.0))
        self._ndist_per_ef = {1: float(state["ndist_per_ef"])}
        for w, v in state.get("ndist_per_ef_bw", {}).items():
            self._ndist_per_ef[int(w)] = float(v)
        self.decay = float(state.get("decay", self.decay))
        self.beam_obs = int(state["beam_obs"])
        # files from before per-width tracking: all observations were width 1
        obs_bw = state.get("beam_obs_bw")
        if obs_bw is None:
            self._beam_obs_w = {1: self.beam_obs} if self.beam_obs else {}
        else:
            self._beam_obs_w = {int(w): int(c) for w, c in obs_bw.items()}
        # pre-observability files carry no wall-obs counts: default 0
        self.scan_wall_obs = int(state.get("scan_wall_obs", 0))
        self.beam_wall_obs = int(state.get("beam_wall_obs", 0))
        # pre-precision files carry only the f32 scalars: seed the dicts
        self._scan_us_p = {k: float(v) for k, v in
                           state.get("scan_us_p", {}).items()
                           if v is not None}
        self._beam_us_p = {k: float(v) for k, v in
                           state.get("beam_us_p", {}).items()
                           if v is not None}
        if "f32" not in self._scan_us_p and state.get("scan_us") is not None:
            self._scan_us_p["f32"] = float(state["scan_us"])
        if "f32" not in self._beam_us_p and state.get("beam_us") is not None:
            self._beam_us_p["f32"] = float(state["beam_us"])
