"""Online-calibrated cost model: fused range scan vs graph beam search.

Costs are expressed in *beam distance units* (one gather-expanded neighbor
distance ≡ 1).  A row scanned inside the fused ``range_scan`` kernel is much
cheaper — it is one row of a batched MXU matmul rather than a dependent
gather inside a sequential ``while_loop`` — so it is weighted by
``scan_unit`` < 1.

Two quantities are calibrated online:

* ``ndist_per_ef`` — predicted beam distance evaluations per unit of ``ef``,
  an EMA over the ``ndist`` stats every beam batch already returns (prior:
  the graph's mean out-degree, i.e. ndist ≈ ef · m̄).
* ``scan_unit`` — refined from observed per-unit wall times of executed scan
  and beam partitions (warm calls only; the executor skips the first call of
  each jit signature so compile time never poisons the estimate).
"""
from __future__ import annotations

from typing import Optional


class CostModel:
    def __init__(self, mean_degree: float, *, scan_unit: float = 0.125,
                 decay: float = 0.9):
        self.scan_unit = float(scan_unit)
        self.beam_unit = 1.0
        self.ndist_per_ef = float(max(mean_degree, 1.0))
        self.decay = float(decay)
        self.beam_obs = 0
        self._scan_us: Optional[float] = None    # wall us per scanned row
        self._beam_us: Optional[float] = None    # wall us per beam distance

    # ------------------------------------------------------------- predict
    def predict_beam_units(self, ef: int) -> float:
        return self.beam_unit * self.ndist_per_ef * float(ef)

    def predict_scan_units(self, window_rows: int) -> float:
        return self.scan_unit * float(window_rows)

    # ----------------------------------------------------------- calibrate
    def update_beam(self, ndist_mean: float, ef: int) -> None:
        """Feed observed per-query distance evaluations from a beam batch."""
        if ef <= 0 or not (ndist_mean >= 0):
            return
        r = float(ndist_mean) / float(ef)
        a = self.decay if self.beam_obs else 0.0   # first obs replaces prior
        self.ndist_per_ef = a * self.ndist_per_ef + (1.0 - a) * r
        self.beam_obs += 1

    def observe_wall(self, strategy: str, units_per_query: float,
                     seconds: float, nq: int) -> None:
        """Feed measured wall time of one executed (warm) partition."""
        if nq <= 0 or units_per_query <= 0 or seconds <= 0:
            return
        per_unit = seconds * 1e6 / nq / units_per_query
        if strategy == "scan":
            self._scan_us = per_unit if self._scan_us is None else \
                self.decay * self._scan_us + (1.0 - self.decay) * per_unit
        else:
            self._beam_us = per_unit if self._beam_us is None else \
                self.decay * self._beam_us + (1.0 - self.decay) * per_unit
        if self._scan_us and self._beam_us:
            # re-anchor the relative per-unit weight on real hardware timings
            self.scan_unit = self._scan_us / self._beam_us

    def observe_wall_mixed(self, scan_units_total: float,
                           beam_units_total: float, seconds: float,
                           n_scan: int, n_beam: int) -> None:
        """Feed one **fused** dispatch that executed a scan group and a beam
        group in a single traced call (the mesh path's branchless body) —
        the wall time cannot be measured per group, so it is attributed
        proportionally to each group's *predicted* unit cost and fed through
        ``observe_wall``.  The split self-corrects: if e.g. scan is really
        cheaper than predicted, its attributed share shrinks on the next
        update as ``scan_unit`` re-anchors."""
        if seconds <= 0:
            return
        su = self.scan_unit * float(scan_units_total)
        bu = self.beam_unit * float(beam_units_total)
        tot = su + bu
        if tot <= 0:
            return
        if scan_units_total > 0 and n_scan > 0:
            self.observe_wall("scan", scan_units_total / n_scan,
                              seconds * su / tot, n_scan)
        if beam_units_total > 0 and n_beam > 0:
            self.observe_wall("beam", beam_units_total / n_beam,
                              seconds * bu / tot, n_beam)

    def snapshot(self) -> dict:
        return dict(scan_unit=round(self.scan_unit, 5),
                    ndist_per_ef=round(self.ndist_per_ef, 2),
                    beam_obs=self.beam_obs,
                    scan_us=self._scan_us, beam_us=self._beam_us)

    # -------------------------------------------------------- persistence
    def state_dict(self) -> dict:
        """Full calibration state (JSON-serializable, exact restore)."""
        return dict(scan_unit=self.scan_unit, beam_unit=self.beam_unit,
                    ndist_per_ef=self.ndist_per_ef, decay=self.decay,
                    beam_obs=self.beam_obs,
                    scan_us=self._scan_us, beam_us=self._beam_us)

    def load_state_dict(self, state: dict) -> None:
        self.scan_unit = float(state["scan_unit"])
        self.beam_unit = float(state.get("beam_unit", 1.0))
        self.ndist_per_ef = float(state["ndist_per_ef"])
        self.decay = float(state.get("decay", self.decay))
        self.beam_obs = int(state["beam_obs"])
        self._scan_us = state.get("scan_us")
        self._beam_us = state.get("beam_us")
