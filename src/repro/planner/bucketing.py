"""Power-of-two bucketing: fixed jit signatures for dynamic range queries.

Every dynamic quantity that would otherwise leak into a traced shape — slice
length, per-partition batch size, beam ``ef`` — is rounded up to a power of
two, so a mixed stream of queries collapses onto a small, closed set of
compiled signatures: ``(bucket, padded_Q, k)`` for the scan kernel and
``(ef_bucket, padded_Q, k)`` for the beam.
"""
from __future__ import annotations

import numpy as np

from repro.kernels.range_scan import window_rows  # noqa: F401  (re-export:
# the kernel owns the scanned-window contract; planner code imports it here)

ROW_TILE = 128          # scan-kernel row tile; window = bucket + one tile


def next_pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length() if x > 1 else 1


def bucket_for_len(length: int, *, min_bucket: int = 64,
                   max_bucket: int = 1 << 30) -> int:
    """Slice length -> scan bucket (pow2, clamped)."""
    return int(min(max(next_pow2(max(int(length), 1)), min_bucket), max_bucket))


def ef_bucket(length: int, k: int, ef: int) -> int:
    """Selectivity-scaled beam width: ``ef`` beyond the number of in-range
    nodes is pure waste (the candidate pool only ever holds in-range nodes),
    so cap at next_pow2(len); floor at k; quantize to pow2."""
    cap = next_pow2(max(int(length), 1))
    return int(max(min(next_pow2(ef), cap), next_pow2(k)))


def pad_pow2(count: int, *, floor: int = 8) -> int:
    """Padded per-partition batch size (bounded set of compiled shapes)."""
    return max(next_pow2(max(count, 1)), floor)


def buckets_np(lens: np.ndarray, *, min_bucket: int = 64,
               max_bucket: int = 1 << 30) -> np.ndarray:
    """Vectorized bucket_for_len."""
    ln = np.maximum(lens.astype(np.int64), 1)
    b = 1 << np.ceil(np.log2(ln)).astype(np.int64)
    return np.clip(b, min_bucket, max_bucket).astype(np.int64)


def next_pow2_np(x: np.ndarray) -> np.ndarray:
    """Vectorized next_pow2 (floor 1, like the scalar)."""
    ln = np.maximum(np.asarray(x, np.int64), 1)
    return (1 << np.ceil(np.log2(ln)).astype(np.int64)).astype(np.int64)


def ef_bucket_np(lens: np.ndarray, k: int, ef: int) -> np.ndarray:
    """Vectorized ef_bucket (same cap/floor/quantize contract)."""
    cap = next_pow2_np(lens)
    return np.maximum(np.minimum(next_pow2(int(ef)), cap),
                      next_pow2(int(k))).astype(np.int64)


def window_rows_np(buckets: np.ndarray, tb: int = ROW_TILE) -> np.ndarray:
    """Vectorized window_rows (kernel-owned contract: ceil(b/tb)+1 blocks)."""
    b = np.asarray(buckets, np.int64)
    return ((-(-b // tb) + 1) * tb).astype(np.int64)
