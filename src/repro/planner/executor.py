"""Batched plan executor: one fixed-shape jit dispatch per partition, results
stitched back in request order.

Scan partitions go to the fused Pallas ``range_scan`` kernel over the padded
rank slice; beam partitions go to the existing ``beam_search_batch`` with the
partition's bucketed ``ef``.  Per-partition batch sizes are padded to pow2 —
scan pads with empty windows (masked, ~free), beam pads by duplicating the
last real query (a duplicate lane adds no extra ``while_loop`` iterations
under vmap, unlike a synthetic query that converges on a different schedule).

After every dispatch the executor feeds the cost model: observed ``ndist``
from beam stats, and warm-call wall times per work unit (the first call of
each jit signature is excluded so compile time never enters calibration).
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.beam import beam_search_batch
from repro.core.entry import rmq_query_jax
from repro.kernels.ops import range_scan
from repro.planner.bucketing import ROW_TILE, window_rows
from repro.planner.planner import QueryPlanner

INF = np.float32(np.inf)


class PlanExecutor:
    def __init__(self, vecs: np.ndarray, nbrs, rmq, dist_c,
                 planner: QueryPlanner, *, use_kernel: bool = False):
        self.planner = planner
        self.tb = ROW_TILE          # must match the range_scan kernel tile
        self._vecs = jnp.asarray(vecs, jnp.float32)
        self._nbrs = jnp.asarray(nbrs)
        self._rmq = jnp.asarray(rmq)
        self._dist_c = jnp.asarray(dist_c)
        self.use_kernel = use_kernel
        n, d = self._vecs.shape
        self.n, self.d = n, d
        self.d_pad = -(-d // 128) * 128
        n_pad = -(-n // self.tb) * self.tb
        # one-time padded copy for the scan kernel (rows→tb, cols→lane tile)
        self._x_pad = jnp.pad(self._vecs,
                              ((0, n_pad - n), (0, self.d_pad - d)))
        self._warm: Set[Tuple] = set()

    # ------------------------------------------------------------------
    def execute(self, qv, lo, hi, *, k: int, ef: int, mode: str = "auto",
                use_kernel: bool = None):
        """qv:(Q,d); lo/hi:(Q,) rank intervals. Returns (ids:(Q,k) rank ids,
        dists:(Q,k), stats) in request order."""
        if use_kernel is None:
            use_kernel = self.use_kernel
        qv = np.asarray(qv, np.float32)
        lo = np.asarray(lo, np.int64)
        hi = np.asarray(hi, np.int64)
        q = len(qv)
        plan = self.planner.plan_batch(lo, hi, k=k, ef=ef, mode=mode)
        out_ids = np.full((q, k), -1, np.int32)
        out_d = np.full((q, k), INF, np.float32)
        hops = np.zeros(q, np.int32)
        ndist = np.zeros(q, np.int32)

        for part in plan.partitions:
            idx = part.indices
            if part.kind == "scan":
                ids_p, d_p, units = self._run_scan(qv, lo, hi, idx,
                                                   part.param, part.pad_q, k)
                ndist[idx] = units
            else:
                ids_p, d_p, st = self._run_beam(qv, lo, hi, idx,
                                                part.param, part.pad_q, k,
                                                calibrate=(mode == "auto"),
                                                use_kernel=use_kernel)
                hops[idx] = st["hops"]
                ndist[idx] = st["ndist"]
            out_ids[idx] = ids_p
            out_d[idx] = d_p

        stats = {"hops": hops, "ndist": ndist,
                 "strategy": plan.strategy, "scan_frac": plan.scan_frac}
        return out_ids, out_d, stats

    # ------------------------------------------------------------------
    def _run_scan(self, qv, lo, hi, idx, bucket: int, pad_q: int, k: int):
        nq = len(idx)
        starts = np.zeros(pad_q, np.int32)
        lens = np.zeros(pad_q, np.int32)
        starts[:nq] = lo[idx]
        lens[:nq] = np.clip(hi[idx] - lo[idx] + 1, 0, bucket)
        qp = np.zeros((pad_q, self.d_pad), np.float32)
        qp[:nq, :self.d] = qv[idx]
        sig = ("scan", bucket, pad_q, k)
        t0 = time.perf_counter()
        ids, d = range_scan(self._x_pad, jnp.asarray(starts),
                            jnp.asarray(lens), jnp.asarray(qp),
                            bucket=bucket, k=k)
        ids = np.asarray(ids)[:nq]
        d = np.asarray(d)[:nq]
        dt = time.perf_counter() - t0
        units = window_rows(bucket, self.tb)
        if sig in self._warm:
            # the dispatch did pad_q windows of work, not nq: normalize by
            # pad_q so calibration measures the kernel, not the padding ratio
            self.planner.cost.observe_wall("scan", units, dt, pad_q)
        self._warm.add(sig)
        return ids, d, units

    def _run_beam(self, qv, lo, hi, idx, ef: int, pad_q: int, k: int, *,
                  calibrate: bool, use_kernel: bool = False):
        nq = len(idx)
        pad = np.concatenate([idx, np.repeat(idx[-1:], pad_q - nq)])
        lo_j = jnp.asarray(np.clip(lo[pad], 0, self.n - 1).astype(np.int32))
        hi_j = jnp.asarray(np.clip(hi[pad], 0, self.n - 1).astype(np.int32))
        entry = rmq_query_jax(self._rmq, self._dist_c, lo_j, hi_j)
        qp = jnp.asarray(qv[pad])
        sig = ("beam", ef, pad_q, k)
        t0 = time.perf_counter()
        ids, d, st = beam_search_batch(
            self._vecs, self._nbrs, qp,
            jnp.asarray(lo[pad].astype(np.int32)),
            jnp.asarray(hi[pad].astype(np.int32)),
            entry, k=k, ef=max(ef, k), use_kernel=use_kernel)
        ids = np.asarray(ids)[:nq]
        d = np.asarray(d)[:nq]
        st = {kk: np.asarray(vv)[:nq] for kk, vv in st.items()}
        dt = time.perf_counter() - t0
        if calibrate:
            self.planner.cost.update_beam(float(st["ndist"].mean()), ef)
            if sig in self._warm:
                # pad lanes duplicate the last real query, so pad_q lanes of
                # ~ndist work each were executed — normalize by pad_q
                self.planner.cost.observe_wall(
                    "beam", max(float(st["ndist"].mean()), 1.0), dt, pad_q)
        self._warm.add(sig)
        return ids, d, st
