"""Result cache in front of the substrate dispatch stage.

``SearchCache`` memoizes **finished per-query results** (original corpus
ids + distances + scalar stats) keyed on everything that determines them:

    (blake2b(query vector), lo, hi, k, ef, strategy, use_kernel,
     beam_width, precision)

The rank interval — not the raw attribute range — is part of the key, so
two different attribute ranges that resolve to the same ranks share one
entry.  Substrates that share a cache (the distributed local path's shard
substrates, the mesh substrate) additionally key a **namespace** (shard
index / ``"mesh"``): different shards routinely see identical
(query, clipped interval) pairs over different vectors, which must never
collide.

Eviction is LRU under an explicit **byte budget** (ids/dists row bytes +
per-entry overhead), so a long-running server holds a bounded working set
regardless of query-stream cardinality.  ``invalidate()`` empties the cache
wholesale — required whenever the index contents or the calibration that
results were computed under change (``RFANNEngine.swap_index`` wires this).
``invalidate_segment(ns)`` is the surgical variant for multi-segment indexes:
it drops only rows whose namespace matches and bumps that namespace's
**segment epoch**, so a streaming compaction that replaces the base segment
leaves every other segment's rows (other shards, the mesh) warm.  Stores made
by dispatches that split before the bump carry the old ``(global, segment)``
epoch pair and are fenced exactly like a wholesale invalidation.

Requests that carry a per-row liveness mask (``SearchRequest.live``) are
cached under the same keys as unmasked ones: the mask is corpus state, not a
request parameter, and the owner of the mask (the streaming layer) must call
``invalidate_segment`` on every mask change — that is the per-segment epoch
invalidation invariant (see docs/streaming.md).

The cache is installed at the single substrate choke point: both
``SearchSubstrate.dispatch`` and ``MeshSubstrate.run`` split each request
into hit/miss rows via :meth:`SearchCache.split`, execute only the misses,
then :meth:`SearchCache.assemble` stitches the batch back in request order.
Hits therefore skip resolve-entry selection, kernel dispatch, *and* the
rank→id remap — a repeat-query batch performs no device work at all.

Results returned from a hit are the stored bytes verbatim, so a cached
batch is bit-identical to the dispatch that populated it (asserted by the
parity tests).  Under ``strategy="auto"`` a stored row reflects the routing
decision at store time; online calibration may route a later identical
query differently, but both executions are valid results for the same
(query, range, k, ef) contract.
"""
from __future__ import annotations

import hashlib
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.search.request import SearchResult

#: rough per-entry bookkeeping cost (key tuple, digest, dict slot) charged
#: against the byte budget on top of the payload arrays.
ENTRY_OVERHEAD = 128


def hash_query(q: np.ndarray) -> bytes:
    """Content hash of one query vector.  Callers fanning a batch out to
    several substrates (the distributed local path) hash each row **once**
    and pass the digests through — the key differs per shard only in
    ``ns``/``lo``/``hi``, so re-hashing per shard would be S-fold waste."""
    return hashlib.blake2b(np.ascontiguousarray(q, np.float32).tobytes(),
                           digest_size=16).digest()


def query_key(q: np.ndarray, lo: int, hi: int, k: int, ef: int,
              strategy: str, use_kernel: bool = False, ns=None,
              digest: Optional[bytes] = None, beam_width: int = 1,
              precision: str = "f32") -> Tuple:
    """Cache key for one query row: content hash of the vector plus every
    request parameter that changes the result (``beam_width`` included —
    the batched-expansion frontier may legitimately differ from the
    single-expansion one at sub-exhaustive ``ef``).  ``precision`` is also
    keyed: the quantized paths return the exact f32 top-k id set after
    rerank, but distances/stats and the traversal at sub-exhaustive ``ef``
    are precision-dependent, so rows never cross precisions.

    ``ns`` namespaces the key to one corpus slice.  It is required whenever
    several substrates share a cache: two shards routinely see the *same*
    (query, shard-local interval, k, ef) — e.g. a full-span query clips to
    ``(0, per-1)`` on every shard — but search different vectors, so without
    the namespace their entries would collide and serve wrong rows."""
    h = digest if digest is not None else hash_query(q)
    return (ns, h, int(lo), int(hi), int(k), int(ef), strategy,
            bool(use_kernel), int(beam_width), precision)


@dataclass
class CacheEntry:
    """One finished per-query result (original corpus ids, -1 padded).

    ``stamp``/``cal_epoch`` implement staleness fencing for rows whose
    routing was a *decision*, not part of the request contract:
    ``strategy="auto"`` rows record the planner's calibration epoch at
    store time (``cal_epoch``) and their insertion time (``stamp``).  A
    later lookup re-validates both — see :meth:`SearchCache.lookup`.
    Forced-strategy rows leave ``cal_epoch`` as ``None`` and are never
    age- or epoch-expired (their result is calibration-independent)."""
    ids: np.ndarray                 # (k,) int32
    dists: np.ndarray               # (k,) float32
    stats: Dict[str, np.generic]    # scalar per-query stats (hops/ndist/...)
    stamp: float = 0.0              # clock() at store time
    cal_epoch: Optional[int] = None  # planner calibration epoch (auto rows)

    @property
    def nbytes(self) -> int:
        return (self.ids.nbytes + self.dists.nbytes +
                16 * len(self.stats) + ENTRY_OVERHEAD)


class SearchCache:
    """LRU result cache with a byte budget and explicit invalidation.

    Thread-safe: the engine's dispatch thread and ``swap_index`` callers may
    touch it concurrently (one short lock around every structural op)."""

    def __init__(self, max_bytes: int = 64 << 20, *,
                 ttl_s: Optional[float] = None, clock=time.monotonic):
        """``ttl_s`` bounds the age of ``strategy="auto"`` rows (None = no
        age limit); ``clock`` is injectable for deterministic expiry tests.
        Forced-strategy rows are exempt — their result does not depend on
        planner calibration, so age cannot make them wrong."""
        self.max_bytes = int(max_bytes)
        self.ttl_s = ttl_s
        self.clock = clock
        self._d: "OrderedDict[Tuple, CacheEntry]" = OrderedDict()
        self._lock = threading.Lock()
        self.bytes = 0
        self.epoch = 0          # bumped by invalidate(); fences late stores
        self._seg_epochs: Dict[object, int] = {}   # ns -> segment epoch
        self.hits = 0
        self.misses = 0
        self.dedup_hits = 0     # intra-batch duplicates served by one dispatch
        self.evictions = 0
        self.invalidations = 0
        self.seg_invalidations = 0
        self.expired = 0        # TTL / calibration-epoch expiries

    def __len__(self) -> int:
        return len(self._d)

    # ------------------------------------------------------------ core ops
    def lookup(self, key: Tuple,
               cal_epoch: Optional[int] = None) -> Optional[CacheEntry]:
        """``cal_epoch``: the planner's current calibration epoch.  Entries
        stored under ``strategy="auto"`` (``entry.cal_epoch is not None``)
        are re-validated on every hit: a calibration-epoch mismatch (the
        planner persisted new calibration since the row was stored) or an
        age beyond ``ttl_s`` expires the row — it is dropped and the lookup
        counts as a miss, so the caller re-executes under current routing."""
        with self._lock:
            e = self._d.get(key)
            if e is None:
                self.misses += 1
                return None
            if e.cal_epoch is not None:
                stale = (cal_epoch is not None and e.cal_epoch != cal_epoch)
                if not stale and self.ttl_s is not None:
                    stale = (self.clock() - e.stamp) > self.ttl_s
                if stale:
                    del self._d[key]
                    self.bytes -= e.nbytes
                    self.expired += 1
                    self.misses += 1
                    return None
            self._d.move_to_end(key)
            self.hits += 1
            return e

    def store(self, key: Tuple, entry: CacheEntry,
              epoch=None) -> None:
        """Insert one entry.  ``epoch`` (captured at lookup/split time)
        fences stores against a concurrent ``invalidate``: a dispatch that
        was in flight when the cache was invalidated — e.g. a batch still
        executing on a just-swapped-out index — must not repopulate the
        cache with rows of the old corpus.  The check runs under the same
        lock ``invalidate`` takes, so no stale store can slip through.

        ``epoch`` is either the legacy global ``int`` or the
        ``(global, segment)`` pair from :meth:`epoch_for`; the pair
        additionally fences stores against a concurrent
        ``invalidate_segment`` of this key's namespace (``key[0]``)."""
        with self._lock:
            if epoch is not None:
                if isinstance(epoch, tuple):
                    if (epoch[0] != self.epoch or
                            epoch[1] != self._seg_epochs.get(key[0], 0)):
                        return
                elif epoch != self.epoch:
                    return
            entry.stamp = self.clock()
            old = self._d.pop(key, None)
            if old is not None:
                self.bytes -= old.nbytes
            if entry.nbytes > self.max_bytes:
                return                      # larger than the whole budget
            self._d[key] = entry
            self.bytes += entry.nbytes
            while self.bytes > self.max_bytes and self._d:
                _, ev = self._d.popitem(last=False)
                self.bytes -= ev.nbytes
                self.evictions += 1

    def invalidate(self) -> None:
        """Drop everything and bump the epoch.  Must be called when the
        index contents change (cached rows reference the old corpus) — see
        ``swap_index``.  In-flight dispatches that split before the bump
        carry the old epoch and their late ``store_batch`` is dropped."""
        with self._lock:
            self._d.clear()
            self.bytes = 0
            self.epoch += 1
            self.invalidations += 1

    def invalidate_segment(self, ns=None) -> None:
        """Drop only the rows of one namespace and bump its segment epoch.
        The hot-swap primitive for multi-segment indexes: a streaming
        compaction replaces the base segment's corpus, so only base-keyed
        rows (``key[0] == ns``) are wrong — rows of other segments stay
        warm.  In-flight dispatches on the old segment captured the old
        ``(global, segment)`` epoch pair via :meth:`epoch_for` and their
        late stores are dropped by :meth:`store`."""
        with self._lock:
            dead = [k for k in self._d if k[0] == ns]
            for k in dead:
                self.bytes -= self._d.pop(k).nbytes
            self._seg_epochs[ns] = self._seg_epochs.get(ns, 0) + 1
            self.seg_invalidations += 1

    def epoch_for(self, ns=None) -> Tuple[int, int]:
        """The ``(global, segment)`` epoch pair to capture before a dispatch
        whose stores should be fenced against both wholesale and
        per-segment invalidation of ``ns``."""
        with self._lock:
            return (self.epoch, self._seg_epochs.get(ns, 0))

    def snapshot(self) -> dict:
        return dict(entries=len(self._d), bytes=self.bytes,
                    max_bytes=self.max_bytes, hits=self.hits,
                    misses=self.misses, dedup_hits=self.dedup_hits,
                    evictions=self.evictions,
                    invalidations=self.invalidations,
                    seg_invalidations=self.seg_invalidations,
                    expired=self.expired)

    # ------------------------------------------------- batch split / stitch
    def split(self, qv: np.ndarray, lo: np.ndarray, hi: np.ndarray, k: int,
              ef: int, strategy: str, use_kernel: bool = False, ns=None,
              digests: Optional[List[bytes]] = None, beam_width: int = 1,
              precision: str = "f32", cal_epoch: Optional[int] = None):
        """Partition one batch into cache hits, misses, and intra-batch
        duplicates of a miss.

        Returns ``(keys, hit_rows, miss_idx, dups)``: per-row keys, a dict
        ``{row -> CacheEntry}`` for the hits, the *unique* miss positions
        (the only rows the substrate has to execute), and
        ``dups: {row -> position in miss_idx}`` for rows whose key equals
        an earlier miss in the same batch — those dispatch **once** and the
        single result fans back out at assembly (dynamic batches routinely
        coalesce identical requests; without this they execute twice on the
        miss path).  ``digests`` are optional precomputed ``hash_query``
        values (one per row) so multi-substrate callers hash each query
        once, not once per shard."""
        keys = [query_key(qv[i], lo[i], hi[i], k, ef, strategy, use_kernel,
                          ns=ns,
                          digest=digests[i] if digests is not None else None,
                          beam_width=beam_width, precision=precision)
                for i in range(len(qv))]
        hit_rows: Dict[int, CacheEntry] = {}
        miss: List[int] = []
        first_at: Dict[Tuple, int] = {}     # miss key -> its slot in `miss`
        dups: Dict[int, int] = {}
        for i, key in enumerate(keys):
            e = self.lookup(key, cal_epoch=cal_epoch)
            if e is not None:
                hit_rows[i] = e
                continue
            p = first_at.get(key)
            if p is None:
                first_at[key] = len(miss)
                miss.append(i)
            else:
                dups[i] = p
        if dups:                    # engine dispatch + direct callers may
            with self._lock:        # split concurrently: count under lock
                self.dedup_hits += len(dups)
        return keys, hit_rows, np.asarray(miss, np.int64), dups

    def store_batch(self, keys: List[Tuple], res: SearchResult,
                    epoch=None,
                    cal_epoch: Optional[int] = None) -> None:
        """Store every row of a finished miss-batch result (rows are copied
        so the cache never pins the batch arrays).  Pass the ``epoch``
        captured at split time — see :meth:`store`.  ``cal_epoch`` (auto
        rows only) arms the staleness fence on each stored entry."""
        q = len(res.ids)
        per_row = [(n, v) for n, v in res.stats.items()
                   if isinstance(v, np.ndarray) and v.ndim >= 1 and len(v) == q]
        for j, key in enumerate(keys):
            self.store(key, CacheEntry(
                np.array(res.ids[j]), np.array(res.dists[j]),
                {n: v[j] for n, v in per_row},
                cal_epoch=cal_epoch), epoch=epoch)

    def assemble(self, q: int, k: int, hit_rows: Dict[int, CacheEntry],
                 miss_res: Optional[SearchResult],
                 miss_idx: np.ndarray,
                 dups: Optional[Dict[int, int]] = None) -> SearchResult:
        """Stitch hits + executed misses back into request order; ``dups``
        rows copy the executed result of their representative miss."""
        ids = np.full((q, k), -1, np.int32)
        dists = np.full((q, k), np.inf, np.float32)
        per_row: Dict[str, Dict[int, np.generic]] = {}
        for i, e in hit_rows.items():
            ids[i] = e.ids
            dists[i] = e.dists
            for name, v in e.stats.items():
                per_row.setdefault(name, {})[i] = v
        if miss_res is not None and len(miss_idx):
            ids[miss_idx] = miss_res.ids
            dists[miss_idx] = miss_res.dists
            for name, v in miss_res.stats.items():
                if isinstance(v, np.ndarray) and v.ndim >= 1 \
                        and len(v) == len(miss_idx):
                    d = per_row.setdefault(name, {})
                    for j, i in enumerate(miss_idx):
                        d[int(i)] = v[j]
        if dups and miss_res is not None:
            for i, p in dups.items():
                ids[i] = miss_res.ids[p]
                dists[i] = miss_res.dists[p]
                for name, d in per_row.items():
                    if int(miss_idx[p]) in d:
                        d[i] = d[int(miss_idx[p])]
        stats: Dict[str, object] = {}
        for name, vals in per_row.items():
            sample = np.asarray(next(iter(vals.values())))
            arr = np.zeros(q, dtype=sample.dtype)
            for i, v in vals.items():
                arr[i] = v
            stats[name] = arr
        if "strategy" in stats:
            from repro.planner.planner import SCAN
            stats["scan_frac"] = float((stats["strategy"] == SCAN).mean())
        stats["cache_hits"] = len(hit_rows)
        if dups:
            stats["batch_dedup"] = len(dups)
        return SearchResult(ids, dists, stats)
