"""Dispatch + stitch stages: one strategy-routed execution layer.

``SearchSubstrate`` owns the entire query path for one attribute-sorted
corpus slice (a whole index, or one shard of a distributed one):

* ``resolve``  — attribute ranges -> rank intervals (``repro.search.resolve``);
* dispatch     — ``graph`` runs the paper's beam search over the full batch;
                 ``auto``/``scan``/``beam`` go through the adaptive planner,
                 which partitions the batch into fixed-shape jit dispatches
                 (fused Pallas ``range_scan`` | bucketed beam search);
* stitch       — partition results land back in request order, rank ids are
                 remapped to original corpus ids, and per-query stats
                 (hops / ndist / strategy) are assembled.

Scan partitions pad with empty windows (masked, ~free); beam partitions pad
by duplicating the last real query (a duplicate lane adds no extra
``while_loop`` iterations under vmap).  After every planned dispatch the
substrate feeds the cost model: observed ``ndist`` from beam stats and
warm-call wall times per work unit (the first call of each jit signature is
excluded so compile time never enters calibration).
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.beam import beam_search_batch
from repro.kernels.ops import range_scan
from repro.planner.bucketing import ROW_TILE, window_rows
from repro.planner.planner import QueryPlanner, SCAN
from repro.search import resolve
from repro.search.request import SearchRequest, SearchResult

INF = np.float32(np.inf)


class SearchSubstrate:
    def __init__(self, vecs, nbrs, rmq, dist_c, order, attrs, *,
                 planner: Optional[QueryPlanner] = None,
                 use_kernel: bool = False):
        self._vecs = jnp.asarray(vecs, jnp.float32)
        self._nbrs = jnp.asarray(nbrs)
        self._rmq = jnp.asarray(rmq)
        self._dist_c = jnp.asarray(dist_c)
        self.order = np.asarray(order)
        self.attrs = np.asarray(attrs)
        self.use_kernel = use_kernel
        n, d = self._vecs.shape
        self.n, self.d = n, d
        self.tb = ROW_TILE          # must match the range_scan kernel tile
        self.d_pad = -(-d // 128) * 128
        if planner is None:
            deg = float((np.asarray(nbrs) >= 0).sum(1).mean()) if n else 1.0
            planner = QueryPlanner(max(n, 1), deg)
        self.planner = planner
        self._x_pad = None          # padded scan copy, built on first scan
        self._warm: Set[Tuple] = set()

    @classmethod
    def from_graph(cls, g, **kw) -> "SearchSubstrate":
        """Build over one ``RNSGGraph`` (single node or one shard)."""
        return cls(g.vecs, g.nbrs, g.rmq, g.dist_c, g.order, g.attrs, **kw)

    # ------------------------------------------------------------ resolve
    def resolve(self, attr_ranges: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Attribute ranges (Q,2) -> inclusive rank intervals (lo, hi)."""
        return resolve.rank_interval(self.attrs, attr_ranges)

    # ---------------------------------------------------------------- run
    def run(self, req: SearchRequest) -> SearchResult:
        """Dispatch one request and stitch the result (original ids)."""
        qv = np.asarray(req.queries, np.float32)
        lo = np.asarray(req.lo, np.int64)
        hi = np.asarray(req.hi, np.int64)
        k, ef = int(req.k), int(req.ef)
        if req.strategy == "graph":
            ids, dists, stats = self._run_graph(qv, lo, hi, k, ef,
                                                req.use_kernel)
        else:
            ids, dists, stats = self._run_planned(qv, lo, hi, k, ef,
                                                  req.strategy, req.use_kernel)
        return SearchResult(resolve.remap_ids(self.order, ids), dists, stats)

    # ------------------------------------------------------ graph strategy
    def _run_graph(self, qv, lo, hi, k, ef, use_kernel):
        """The paper's path: one beam-search dispatch over the full batch."""
        qj = jnp.asarray(qv, jnp.float32)
        lo_j = jnp.asarray(lo)
        hi_j = jnp.asarray(hi)
        entry = resolve.select_entry(self._rmq, self._dist_c, lo_j, hi_j,
                                     self.n)
        ids, dists, st = beam_search_batch(
            self._vecs, self._nbrs, qj, lo_j, hi_j, entry,
            k=k, ef=max(ef, k), use_kernel=use_kernel)
        st = jax.tree.map(np.asarray, st)
        st["strategy"] = np.ones(len(qv), np.int8)          # all graph/beam
        st["scan_frac"] = 0.0
        return np.asarray(ids), np.asarray(dists), st

    # ---------------------------------------------------- planned strategies
    def _run_planned(self, qv, lo, hi, k, ef, mode, use_kernel):
        """Routing policy: plan the batch, dispatch each fixed-shape
        partition, stitch back in request order."""
        q = len(qv)
        plan = self.planner.plan_batch(lo, hi, k=k, ef=ef, mode=mode)
        out_ids = np.full((q, k), -1, np.int32)
        out_d = np.full((q, k), INF, np.float32)
        hops = np.zeros(q, np.int32)
        ndist = np.zeros(q, np.int32)

        for part in plan.partitions:
            idx = part.indices      # never empty (guarded at plan time)
            if part.kind == "scan":
                ids_p, d_p, units = self._run_scan(qv, lo, hi, idx,
                                                   part.param, part.pad_q, k)
                ndist[idx] = units
            else:
                ids_p, d_p, st = self._run_beam(qv, lo, hi, idx,
                                                part.param, part.pad_q, k,
                                                calibrate=(mode == "auto"),
                                                use_kernel=use_kernel)
                hops[idx] = st["hops"]
                ndist[idx] = st["ndist"]
            out_ids[idx] = ids_p
            out_d[idx] = d_p

        stats = {"hops": hops, "ndist": ndist,
                 "strategy": plan.strategy, "scan_frac": plan.scan_frac}
        return out_ids, out_d, stats

    # ------------------------------------------------------------------
    def _scan_corpus(self):
        """Row/lane-padded corpus copy for the scan kernel (lazy: shards
        that never route to scan skip the duplicate)."""
        if self._x_pad is None:
            n_pad = -(-self.n // self.tb) * self.tb
            self._x_pad = jnp.pad(
                self._vecs, ((0, n_pad - self.n), (0, self.d_pad - self.d)))
        return self._x_pad

    def _run_scan(self, qv, lo, hi, idx, bucket: int, pad_q: int, k: int):
        nq = len(idx)
        starts = np.zeros(pad_q, np.int32)
        lens = np.zeros(pad_q, np.int32)
        starts[:nq] = lo[idx]
        lens[:nq] = np.clip(hi[idx] - lo[idx] + 1, 0, bucket)
        qp = np.zeros((pad_q, self.d_pad), np.float32)
        qp[:nq, :self.d] = qv[idx]
        sig = ("scan", bucket, pad_q, k)
        t0 = time.perf_counter()
        ids, d = range_scan(self._scan_corpus(), jnp.asarray(starts),
                            jnp.asarray(lens), jnp.asarray(qp),
                            bucket=bucket, k=k)
        ids = np.asarray(ids)[:nq]
        d = np.asarray(d)[:nq]
        dt = time.perf_counter() - t0
        units = window_rows(bucket, self.tb)
        if sig in self._warm:
            # the dispatch did pad_q windows of work, not nq: normalize by
            # pad_q so calibration measures the kernel, not the padding ratio
            self.planner.cost.observe_wall("scan", units, dt, pad_q)
        self._warm.add(sig)
        return ids, d, units

    def _run_beam(self, qv, lo, hi, idx, ef: int, pad_q: int, k: int, *,
                  calibrate: bool, use_kernel: bool = False):
        nq = len(idx)
        if nq == 0:                 # empty partition: nothing to dispatch
            empty = np.zeros(0, np.int32)
            return (np.zeros((0, k), np.int32), np.zeros((0, k), np.float32),
                    {"hops": empty, "ndist": empty})
        pad = np.concatenate([idx, np.repeat(idx[-1:], pad_q - nq)])
        lo_j = jnp.asarray(np.clip(lo[pad], 0, self.n - 1).astype(np.int32))
        hi_j = jnp.asarray(np.clip(hi[pad], 0, self.n - 1).astype(np.int32))
        entry = resolve.select_entry(self._rmq, self._dist_c, lo_j, hi_j,
                                     self.n)
        qp = jnp.asarray(qv[pad])
        sig = ("beam", ef, pad_q, k)
        t0 = time.perf_counter()
        ids, d, st = beam_search_batch(
            self._vecs, self._nbrs, qp,
            jnp.asarray(lo[pad].astype(np.int32)),
            jnp.asarray(hi[pad].astype(np.int32)),
            entry, k=k, ef=max(ef, k), use_kernel=use_kernel)
        ids = np.asarray(ids)[:nq]
        d = np.asarray(d)[:nq]
        st = {kk: np.asarray(vv)[:nq] for kk, vv in st.items()}
        dt = time.perf_counter() - t0
        if calibrate:
            self.planner.cost.update_beam(float(st["ndist"].mean()), ef)
            if sig in self._warm:
                # pad lanes duplicate the last real query, so pad_q lanes of
                # ~ndist work each were executed — normalize by pad_q
                self.planner.cost.observe_wall(
                    "beam", max(float(st["ndist"].mean()), 1.0), dt, pad_q)
        self._warm.add(sig)
        return ids, d, st
