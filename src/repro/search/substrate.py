"""Dispatch + stitch stages: one strategy-routed execution layer.

``SearchSubstrate`` owns the entire query path for one attribute-sorted
corpus slice (a whole index, or one shard of a distributed one):

* ``resolve``  — attribute ranges -> rank intervals (``repro.search.resolve``);
* cache        — when a ``SearchCache`` is installed, each request is split
                 into hit rows (served from memory, no device work), unique
                 miss rows (executed), and intra-batch duplicates of a miss
                 (executed once, fanned back out), stitched in request
                 order;
* dispatch     — ``graph`` runs the paper's beam search over the full batch;
                 ``auto``/``scan``/``beam`` go through the adaptive planner,
                 which partitions the batch into fixed-shape jit dispatches
                 (fused Pallas ``range_scan`` | bucketed beam search);
* stitch       — partition results land back in request order, rank ids are
                 remapped to original corpus ids, and per-query stats
                 (hops / ndist / strategy) are assembled.

Dispatch is **asynchronous at the substrate boundary**: ``dispatch(req)``
enqueues all device work (jax async dispatch) and returns a
``PendingSearch`` whose ``result()`` blocks and stitches.  ``run`` is the
synchronous spelling (``dispatch(..., defer=False).result()``); the
distributed local path dispatches every shard before blocking any of them,
overlapping the per-shard device queues.  Deferred dispatches skip
wall-time calibration (their block time includes sibling shards' work),
while ndist-based beam calibration still applies.

Scan partitions pad with empty windows (masked, ~free); beam partitions pad
by duplicating the last real query (a duplicate lane adds no extra
``while_loop`` iterations under vmap).  After every planned synchronous
dispatch the substrate feeds the cost model: observed ``ndist`` from beam
stats and warm-call wall times per work unit (the first call of each jit
signature is excluded so compile time never enters calibration).

``MeshSubstrate`` is the ``shard_map`` twin for multi-device serving: the
planner runs **host-side** over the globally resolved rank intervals (clipped
per shard), and the resulting strategy vector partitions the batch into
scan/beam sub-batches that enter the traced per-device body as replicated
operands — a branchless select in which each shard executes the ``range_scan``
kernel and the beam search at most once per call, scatters both groups back
into request order, and finishes with the cross-shard ``all_gather`` + top-k
merge.  Warm-call wall times of the traced dispatches feed the cost model
(mixed scan+beam calls are attributed proportionally to predicted unit
costs — ``CostModel.observe_wall_mixed``), so mesh routing converges to
measured hardware ratios instead of serving from the prior forever.  See
docs/distributed.md.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Callable, Dict, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.beam import beam_search_batch, rerank_pool
from repro.kernels.ops import range_scan
from repro.kernels.quantize import (QuantizedCorpus, quantize_corpus,
                                    rerank_depth)
from repro.obs.metrics import MetricsRegistry
from repro.obs.profiler import annotate
from repro.obs.trace import maybe_span
from repro.parallel.sharding import shard_map_compat
from repro.planner.bucketing import (ROW_TILE, bucket_for_len, next_pow2,
                                     pad_pow2, window_rows)
from repro.planner.planner import BEAM, QueryPlanner, SCAN
from repro.search import resolve
from repro.search.cache import SearchCache
from repro.search.request import SearchRequest, SearchResult

INF = np.float32(np.inf)


def merge_topk(ids: jax.Array, dists: jax.Array, k: int):
    """(S,Q,k) per-shard results -> (Q,k) global top-k.  Shared by the local
    path, the mesh bodies, and the dry-run — identical merges by
    construction (same flatten order, same ``lax.top_k`` tie-breaking)."""
    s, q, kk = ids.shape
    flat_i = jnp.moveaxis(ids, 0, 1).reshape(q, s * kk)
    flat_d = jnp.moveaxis(dists, 0, 1).reshape(q, s * kk)
    nd, sel = jax.lax.top_k(-flat_d, k)
    out_i = jnp.take_along_axis(flat_i, sel, axis=1)
    return jnp.where(jnp.isfinite(-nd), out_i, -1), -nd


class PendingSearch:
    """Handle for an in-flight substrate dispatch.

    The device work is already enqueued when this object exists (jax async
    dispatch); ``result()`` blocks on the outputs, stitches, feeds the cost
    model, and returns the ``SearchResult``.  Idempotent — repeated calls
    return the same object."""
    __slots__ = ("_finalize", "_result")

    def __init__(self, finalize: Callable[[], SearchResult]):
        self._finalize: Optional[Callable[[], SearchResult]] = finalize
        self._result: Optional[SearchResult] = None

    def result(self) -> SearchResult:
        if self._finalize is not None:
            self._result = self._finalize()
            self._finalize = None
        return self._result


class SearchSubstrate:
    def __init__(self, vecs, nbrs, rmq, dist_c, order, attrs, *,
                 planner: Optional[QueryPlanner] = None,
                 use_kernel: bool = False,
                 cache: Optional[SearchCache] = None,
                 cache_ns=None,
                 metrics: Optional[MetricsRegistry] = None):
        self._vecs = jnp.asarray(vecs, jnp.float32)
        self._nbrs = jnp.asarray(nbrs)
        self._rmq = jnp.asarray(rmq)
        self._dist_c = jnp.asarray(dist_c)
        self.order = np.asarray(order)
        self.attrs = np.asarray(attrs)
        self.use_kernel = use_kernel
        self.cache = cache
        self.cache_ns = cache_ns    # distinguishes shards sharing one cache
        self.metrics = metrics      # optional MetricsRegistry (obs layer)
        n, d = self._vecs.shape
        self.n, self.d = n, d
        self.tb = ROW_TILE          # must match the range_scan kernel tile
        self.d_pad = -(-d // 128) * 128
        if planner is None:
            deg = float((np.asarray(nbrs) >= 0).sum(1).mean()) if n else 1.0
            planner = QueryPlanner(max(n, 1), deg)
        self.planner = planner
        self._x_pad = None          # padded scan copy, built on first scan
        self._quant: Dict[str, dict] = {}   # precision -> quantized slots
        self._live_memo = None      # (mask, (n,) bool dev, (1,n_pad) i32 dev)
        self._warm: Set[Tuple] = set()

    @classmethod
    def from_graph(cls, g, **kw) -> "SearchSubstrate":
        """Build over one ``RNSGGraph`` (single node or one shard)."""
        return cls(g.vecs, g.nbrs, g.rmq, g.dist_c, g.order, g.attrs, **kw)

    # ------------------------------------------------------------ resolve
    def resolve(self, attr_ranges: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Attribute ranges (Q,2) -> inclusive rank intervals (lo, hi)."""
        return resolve.rank_interval(self.attrs, attr_ranges)

    # ---------------------------------------------------------------- run
    def run(self, req: SearchRequest) -> SearchResult:
        """Dispatch one request synchronously and stitch the result."""
        return self.dispatch(req, defer=False).result()

    def dispatch(self, req: SearchRequest, *, defer: bool = True,
                 q_digests=None) -> PendingSearch:
        """Enqueue one request's device work and return a ``PendingSearch``.

        ``defer=True`` (the async path) enqueues every partition before any
        block and skips wall-time calibration; ``defer=False`` reproduces
        the synchronous per-partition dispatch+block loop, whose wall times
        are clean enough to calibrate on.  Cache hits are resolved here —
        a fully-hit request performs no device work at all.  ``q_digests``
        are optional precomputed ``hash_query`` values (the distributed
        local path hashes each query once, not once per shard).

        A ``req.trace`` collects plan / dispatch / stitch spans (the stitch
        span on a deferred dispatch includes the device block); the
        installed ``MetricsRegistry`` (when any) counts routed queries,
        cache outcomes and pad waste, and observes dispatch wall
        histograms."""
        qv = np.asarray(req.queries, np.float32)
        lo = np.asarray(req.lo, np.int64)
        hi = np.asarray(req.hi, np.int64)
        k, ef, bw = int(req.k), int(req.ef), int(req.beam_width)
        prec = req.precision
        tr = req.trace
        met = self.metrics
        nq = len(qv)
        if met is not None and nq:
            met.counter("queries_total").inc(nq)
            met.counter(f"queries_{prec}_total").inc(nq)
        live = req.live
        cache = self.cache
        cache_info = dict(cache_enabled=cache is not None,
                          cache_hits=0, cache_misses=nq, batch_dedup=0)
        if cache is None or nq == 0:
            fin = self._dispatch_all(qv, lo, hi, k, ef, req.strategy,
                                     req.use_kernel, defer, bw, prec,
                                     trace=tr, cache_info=cache_info,
                                     live=live)
            return PendingSearch(self._stitched(fin, tr))
        # (global, segment) epoch pair: fences stores vs both invalidate()
        # and invalidate_segment(self.cache_ns) — the streaming layer bumps
        # the segment epoch on every tombstone change / compaction
        epoch = cache.epoch_for(self.cache_ns)
        cal_epoch = (self.planner.calibration_epoch
                     if req.strategy == "auto" else None)
        keys, hit_rows, miss, dups = cache.split(
            qv, lo, hi, k, ef, req.strategy, req.use_kernel,
            ns=self.cache_ns, digests=q_digests, beam_width=bw,
            precision=prec, cal_epoch=cal_epoch)
        cache_info.update(cache_hits=len(hit_rows), cache_misses=len(miss),
                          batch_dedup=len(dups))
        if met is not None:
            met.counter("cache_hit_rows_total").inc(len(hit_rows))
            met.counter("cache_miss_rows_total").inc(len(miss))
            if dups:
                met.counter("cache_dedup_rows_total").inc(len(dups))
        if len(miss) == 0:
            if tr is not None:          # fully hit: no device work at all
                tr.add_span("dispatch", dispatched=0, ns=self.cache_ns,
                            **cache_info)
            return PendingSearch(self._stitched(
                lambda: cache.assemble(nq, k, hit_rows, None, miss), tr))
        fin = self._dispatch_all(qv[miss], lo[miss], hi[miss], k, ef,
                                 req.strategy, req.use_kernel, defer, bw,
                                 prec, trace=tr, cache_info=cache_info,
                                 live=live)
        miss_keys = [keys[i] for i in miss]

        def finalize() -> SearchResult:
            miss_res = fin()
            cache.store_batch(miss_keys, miss_res, epoch=epoch,
                              cal_epoch=cal_epoch)
            if not hit_rows and not dups:
                miss_res.stats["cache_hits"] = 0
                return miss_res
            return cache.assemble(nq, k, hit_rows, miss_res, miss,
                                  dups)
        return PendingSearch(self._stitched(finalize, tr))

    def _stitched(self, fin: Callable[[], SearchResult],
                  tr) -> Callable[[], SearchResult]:
        """Wrap a finalize closure with the stitch span (block + assembly +
        id remap; on deferred dispatches the block time includes sibling
        device work) and attach the trace to the result.  Identity when
        neither tracing nor metrics are on — the hot path is unchanged."""
        met = self.metrics
        if tr is None and met is None:
            return fin

        def finalize() -> SearchResult:
            t0 = time.perf_counter()
            with maybe_span(tr, "stitch", ns=self.cache_ns):
                res = fin()
            if met is not None:
                met.histogram("stitch_ms").observe(
                    (time.perf_counter() - t0) * 1e3)
            if tr is not None:
                res.trace = tr
            return res
        return finalize

    # ----------------------------------------------------------- dispatch
    def _dispatch_all(self, qv, lo, hi, k, ef, strategy, use_kernel,
                      defer: bool, beam_width: int = 1,
                      precision: str = "f32", trace=None,
                      cache_info=None, live=None) -> Callable[[], SearchResult]:
        """Enqueue the uncached work for one (sub-)batch; the returned
        closure blocks, stitches, and remaps rank ids to original ids.
        The dispatch span covers the enqueue (plus, on the ``defer=False``
        path, the per-partition blocks); the plan span is recorded inside
        it, so spans land in resolve -> plan -> dispatch -> stitch order."""
        met = self.metrics
        with maybe_span(trace, "dispatch") as sp:
            sp.attrs.update(cache_info or {})
            sp.attrs.update(strategy_mode=strategy, use_kernel=use_kernel,
                            beam_width=beam_width, ns=self.cache_ns,
                            precision=precision,
                            dispatched=len(qv), deferred=defer)
            if strategy == "graph":
                if trace is not None:
                    trace.add_span("plan", strategy_mode="graph",
                                   chosen="graph", beam_width=beam_width)
                if met is not None and len(qv):
                    met.counter("graph_queries_total").inc(len(qv))
                fin = self._dispatch_graph(qv, lo, hi, k, ef, use_kernel,
                                           beam_width, precision, live=live)
            else:
                fin = self._dispatch_planned(qv, lo, hi, k, ef, strategy,
                                             use_kernel, defer, beam_width,
                                             precision,
                                             trace=trace, span=sp, live=live)

        def finalize() -> SearchResult:
            ids, dists, stats = fin()
            return SearchResult(resolve.remap_ids(self.order, ids), dists,
                                stats)
        return finalize

    # ------------------------------------------------------ graph strategy
    def _dispatch_graph(self, qv, lo, hi, k, ef, use_kernel, beam_width=1,
                        precision="f32", live=None):
        """The paper's path: one beam-search dispatch over the full batch.
        Non-f32 precisions score the traversal against the quantized corpus
        and rerank the final pool in f32 inside ``beam_search_batch``."""
        qj = jnp.asarray(qv, jnp.float32)
        lo_j = jnp.asarray(lo)
        hi_j = jnp.asarray(hi)
        entry = resolve.select_entry(self._rmq, self._dist_c, lo_j, hi_j,
                                     self.n)
        slot = self._quant_for(precision)
        quant = None if slot is None else (slot["data"], slot["scale"])
        live_b, _ = self._live_ops(live)
        t0 = time.perf_counter()
        with annotate("rnsg.graph_beam_dispatch"):
            ids, dists, st = beam_search_batch(
                self._vecs, self._nbrs, qj, lo_j, hi_j, entry,
                k=k, ef=max(ef, k), use_kernel=use_kernel,
                beam_width=beam_width, quant=quant, live=live_b)
        met = self.metrics

        def finalize():
            st_h = jax.tree.map(np.asarray, st)
            st_h["strategy"] = np.ones(len(qv), np.int8)     # all graph/beam
            st_h["scan_frac"] = 0.0
            if met is not None:
                met.histogram("graph_dispatch_ms").observe(
                    (time.perf_counter() - t0) * 1e3)
            return np.asarray(ids), np.asarray(dists), st_h
        return finalize

    # ---------------------------------------------------- planned strategies
    def _dispatch_planned(self, qv, lo, hi, k, ef, mode, use_kernel,
                          defer: bool, beam_width: int = 1,
                          precision: str = "f32", trace=None,
                          span=None, live=None):
        """Routing policy: plan the batch, dispatch each fixed-shape
        partition, stitch back in request order.  ``defer=False`` blocks
        each partition before dispatching the next (today's calibrated
        loop); ``defer=True`` enqueues them all and blocks only in the
        returned closure."""
        q = len(qv)
        met = self.metrics
        if trace is None:
            plan = self.planner.plan_batch(lo, hi, k=k, ef=ef, mode=mode,
                                           beam_width=beam_width,
                                           precision=precision)
        else:
            with trace.span("plan") as psp:
                plan = self.planner.plan_batch(lo, hi, k=k, ef=ef,
                                               mode=mode,
                                               beam_width=beam_width,
                                               precision=precision)
                lens = np.clip(hi - lo + 1, 0, None)
                sc, bc = self.planner.predict_costs(lens, k=k, ef=ef,
                                                    beam_width=beam_width,
                                                    precision=precision)
                psp.attrs.update(
                    strategy_mode=mode, strategy=plan.strategy.copy(),
                    scan_frac=plan.scan_frac, beam_width=beam_width,
                    precision=precision,
                    partitions=[p.signature for p in plan.partitions],
                    predicted_scan_units=sc, predicted_beam_units=bc)
        pad_rows = sum(p.pad_q - len(p.indices) for p in plan.partitions)
        if met is not None and q:
            n_scan = int((plan.strategy == SCAN).sum())
            met.counter("scan_routed_total").inc(n_scan)
            met.counter("beam_routed_total").inc(q - n_scan)
            if pad_rows:
                met.counter("pad_rows_total").inc(pad_rows)
        if span is not None:
            span.attrs["pad_rows"] = pad_rows
        fins = []
        for part in plan.partitions:
            if part.kind == "scan":
                fin = self._dispatch_scan(qv, lo, hi, part.indices,
                                          part.param, part.pad_q, k, ef,
                                          calibrate_wall=not defer,
                                          precision=precision, trace=trace,
                                          live=live)
            else:
                fin = self._dispatch_beam(qv, lo, hi, part.indices,
                                          part.param, part.pad_q, k,
                                          calibrate=(mode == "auto"),
                                          calibrate_wall=not defer,
                                          use_kernel=use_kernel,
                                          beam_width=beam_width,
                                          precision=precision, live=live)
            if not defer:
                val = fin()
                fin = (lambda v: lambda: v)(val)
            fins.append(fin)

        def finalize():
            out_ids = np.full((q, k), -1, np.int32)
            out_d = np.full((q, k), INF, np.float32)
            hops = np.zeros(q, np.int32)
            ndist = np.zeros(q, np.int32)
            for part, fin in zip(plan.partitions, fins):
                idx = part.indices  # never empty (guarded at plan time)
                if part.kind == "scan":
                    ids_p, d_p, units = fin()
                    ndist[idx] = units
                else:
                    ids_p, d_p, st = fin()
                    hops[idx] = st["hops"]
                    ndist[idx] = st["ndist"]
                out_ids[idx] = ids_p
                out_d[idx] = d_p
            stats = {"hops": hops, "ndist": ndist,
                     "strategy": plan.strategy, "scan_frac": plan.scan_frac}
            return out_ids, out_d, stats
        return finalize

    # ------------------------------------------------------------------
    def _scan_corpus(self):
        """Row/lane-padded corpus copy for the scan kernel (lazy: shards
        that never route to scan skip the duplicate)."""
        if self._x_pad is None:
            n_pad = -(-self.n // self.tb) * self.tb
            self._x_pad = jnp.pad(
                self._vecs, ((0, n_pad - self.n), (0, self.d_pad - self.d)))
        return self._x_pad

    # ------------------------------------------------------- liveness mask
    def _live_ops(self, live):
        """Device forms of a per-rank liveness mask: ((n,) bool for the beam
        paths, (1, n_pad) i32 row for the scan kernel).  Memoized by object
        identity — the streaming layer publishes one immutable mask array
        per corpus version, so ``is`` is a sound cache key and mask reuse
        costs no re-upload."""
        if live is None:
            return None, None
        memo = self._live_memo
        if memo is not None and memo[0] is live:
            return memo[1], memo[2]
        lv = np.asarray(live, bool)
        if lv.shape != (self.n,):
            raise ValueError(
                f"live mask shape {lv.shape} does not match corpus ({self.n},)")
        n_pad = -(-self.n // self.tb) * self.tb
        row = np.zeros((1, n_pad), np.int32)
        row[0, :self.n] = lv
        out = (jnp.asarray(lv), jnp.asarray(row))
        self._live_memo = (live,) + out
        return out

    # --------------------------------------------------- quantized corpus
    def install_quantized(self, precision: str) -> None:
        """Build (or rebuild) the quantized corpus copies for one precision
        ahead of serving, so the first quantized request pays no build cost.
        Lazy build happens anyway on first use (``_quant_for``).

        Rebuilding the quantized slots changes what a non-f32 request scores
        against, so any installed cache must go cold for this substrate:
        rows stored before the switch would otherwise stay servable under
        unchanged keys."""
        if precision != "f32":
            self._quant.pop(precision, None)
            self._quant_for(precision)
            if self.cache is not None:
                self.cache.invalidate_segment(self.cache_ns)

    def _quant_for(self, precision: str) -> Optional[dict]:
        """Quantized scoring slots for one precision (lazy, cached):
        ``data`` (n,d) for the beam's gathered rows, ``data_pad``
        (n_pad,d_pad) rank-ordered for the scan kernel (interval slicing is
        unchanged — quantization is per-element), ``scale``/``scale_pad``
        ((d,)/(d_pad,) f32, int8 only; padding scale with 1.0 is inert
        because padded query/corpus lanes are zero)."""
        if precision == "f32":
            return None
        slot = self._quant.get(precision)
        if slot is None:
            slot = self._slot_of(quantize_corpus(self._vecs, precision))
            self._quant[precision] = slot
        return slot

    def _slot_of(self, qc: QuantizedCorpus) -> dict:
        """Scoring slots from one quantized corpus copy (shared between the
        lazy quantize path and the restore preload path)."""
        n_pad = -(-self.n // self.tb) * self.tb
        data_pad = jnp.pad(qc.data, ((0, n_pad - self.n),
                                     (0, self.d_pad - self.d)))
        scale_pad = (None if qc.scale is None else
                     jnp.pad(qc.scale, (0, self.d_pad - self.d),
                             constant_values=1.0))
        return dict(data=qc.data, data_pad=data_pad,
                    scale=qc.scale, scale_pad=scale_pad,
                    bytes_per_vector=qc.bytes_per_vector)

    def preload_quantized(self, precision: str, data, scale=None) -> None:
        """Attach a prebuilt quantized corpus copy (the index-restore path,
        ``repro.index.io``) without re-quantizing.  ``data`` may arrive as
        the checkpoint's exact f32 upcast — it is narrowed back to the
        precision's dtype here, which round-trips bit-exactly.  Same cache
        rule as :meth:`install_quantized`: the scored corpus changed, so
        this substrate's cache segment goes cold."""
        if precision == "f32":
            return
        dt = jnp.bfloat16 if precision == "bf16" else jnp.int8
        qc = QuantizedCorpus(precision, jnp.asarray(data).astype(dt),
                             None if scale is None
                             else jnp.asarray(scale, jnp.float32))
        self._quant[precision] = self._slot_of(qc)
        if self.cache is not None:
            self.cache.invalidate_segment(self.cache_ns)

    def _dispatch_scan(self, qv, lo, hi, idx, bucket: int, pad_q: int,
                       k: int, ef: int, *, calibrate_wall: bool,
                       precision: str = "f32", trace=None, live=None):
        nq = len(idx)
        starts = np.zeros(pad_q, np.int32)
        lens = np.zeros(pad_q, np.int32)
        starts[:nq] = lo[idx]
        lens[:nq] = np.clip(hi[idx] - lo[idx] + 1, 0, bucket)
        qp = np.zeros((pad_q, self.d_pad), np.float32)
        qp[:nq, :self.d] = qv[idx]
        slot = self._quant_for(precision)
        _, live_row = self._live_ops(live)
        sig = ("scan", bucket, pad_q, k, precision, live is not None)
        warm = sig in self._warm
        self._warm.add(sig)
        t0 = time.perf_counter()
        rq = 0
        with annotate("rnsg.scan_dispatch"):
            if slot is None:
                ids, d = range_scan(self._scan_corpus(), jnp.asarray(starts),
                                    jnp.asarray(lens), jnp.asarray(qp),
                                    bucket=bucket, k=k, live=live_row)
            else:
                # quantized scan keeps rerank_depth survivors (clamped to
                # the slice via lens ≤ bucket masking; tombstoned rows are
                # masked here, so the survivor pool is live-only) ...
                rq = rerank_depth(k, ef, cap=self.tb)
                ids_q, _ = range_scan(slot["data_pad"], jnp.asarray(starts),
                                      jnp.asarray(lens), jnp.asarray(qp),
                                      bucket=bucket, k=rq,
                                      scale=slot["scale_pad"],
                                      live=live_row)
                # ... then a fused f32 rescore of those ids restores the
                # exact top-k (candidates rank-sorted so ties break exactly
                # as the oracle's)
                with maybe_span(trace, "rerank", precision=precision,
                                rows=pad_q * rq, k=k):
                    ids, d = rerank_pool(self._vecs, ids_q,
                                         jnp.asarray(qp[:, :self.d]), k,
                                         use_kernel=True)
        units = window_rows(bucket, self.tb)
        met = self.metrics

        def finalize():
            ids_h = np.asarray(ids)[:nq]
            d_h = np.asarray(d)[:nq]
            dt = time.perf_counter() - t0
            if met is not None:
                met.histogram("scan_dispatch_ms").observe(dt * 1e3)
                if rq:
                    met.counter("rerank_rows_total").inc(pad_q * rq)
            if calibrate_wall and warm:
                # the dispatch did pad_q windows of work, not nq: normalize
                # by pad_q so calibration measures the kernel, not the
                # padding ratio
                self.planner.cost.observe_wall("scan", units, dt, pad_q,
                                               precision=precision)
            return ids_h, d_h, units
        return finalize

    def _dispatch_beam(self, qv, lo, hi, idx, ef: int, pad_q: int, k: int, *,
                       calibrate: bool, calibrate_wall: bool = True,
                       use_kernel: bool = False, beam_width: int = 1,
                       precision: str = "f32", live=None):
        nq = len(idx)
        if nq == 0:                 # empty partition: nothing to dispatch
            empty = np.zeros(0, np.int32)
            return lambda: (np.zeros((0, k), np.int32),
                            np.zeros((0, k), np.float32),
                            {"hops": empty, "ndist": empty})
        pad = np.concatenate([idx, np.repeat(idx[-1:], pad_q - nq)])
        lo_j = jnp.asarray(np.clip(lo[pad], 0, self.n - 1).astype(np.int32))
        hi_j = jnp.asarray(np.clip(hi[pad], 0, self.n - 1).astype(np.int32))
        entry = resolve.select_entry(self._rmq, self._dist_c, lo_j, hi_j,
                                     self.n)
        qp = jnp.asarray(qv[pad])
        slot = self._quant_for(precision)
        quant = None if slot is None else (slot["data"], slot["scale"])
        live_b, _ = self._live_ops(live)
        sig = ("beam", ef, pad_q, k, beam_width, precision, live is not None)
        warm = sig in self._warm
        self._warm.add(sig)
        t0 = time.perf_counter()
        with annotate("rnsg.beam_dispatch"):
            ids, d, st = beam_search_batch(
                self._vecs, self._nbrs, qp,
                jnp.asarray(lo[pad].astype(np.int32)),
                jnp.asarray(hi[pad].astype(np.int32)),
                entry, k=k, ef=max(ef, k), use_kernel=use_kernel,
                beam_width=beam_width, quant=quant, live=live_b)
        met = self.metrics

        def finalize():
            ids_h = np.asarray(ids)[:nq]
            d_h = np.asarray(d)[:nq]
            st_h = {kk: np.asarray(vv)[:nq] for kk, vv in st.items()}
            dt = time.perf_counter() - t0
            if met is not None:
                met.histogram("beam_dispatch_ms").observe(dt * 1e3)
            if calibrate:
                self.planner.cost.update_beam(float(st_h["ndist"].mean()), ef,
                                              beam_width=beam_width)
                if calibrate_wall and warm:
                    # pad lanes duplicate the last real query, so pad_q lanes
                    # of ~ndist work each were executed — normalize by pad_q
                    self.planner.cost.observe_wall(
                        "beam", max(float(st_h["ndist"].mean()), 1.0), dt,
                        pad_q, precision=precision)
            return ids_h, d_h, st_h
        return finalize

    # ------------------------------------------------- legacy sync wrapper
    def _run_beam(self, qv, lo, hi, idx, ef: int, pad_q: int, k: int, *,
                  calibrate: bool, use_kernel: bool = False):
        """Synchronous beam partition dispatch (kept for the empty-partition
        regression test and any external caller of the pre-async API)."""
        return self._dispatch_beam(qv, lo, hi, np.asarray(idx, np.int64),
                                   ef, pad_q, k, calibrate=calibrate,
                                   use_kernel=use_kernel)()


# ======================================================================
# Mesh path: traced per-device bodies + the host-planned mesh substrate.
# ======================================================================
def _shard_graph(vecs, nbrs, rmq, dist_c, order, rank0, xq, scale, live, qv,
                 lo, hi, *, k: int, ef: int, axis: str, beam_width: int = 1,
                 precision: str = "f32", use_live: bool = False):
    """Per-device graph body (the paper's mesh path): clip the replicated
    global rank interval to this shard, one beam dispatch over the full
    batch, then the cross-shard merge.  Leading shard dim of size 1.

    ``xq``/``scale`` are the quantized scoring operands (``xq`` sharded like
    ``vecs``; ``scale`` a replicated (d_pad,) f32 row, sliced to d here).
    Under ``precision="f32"`` the caller passes ``vecs`` itself as ``xq``
    (no copy) and both are ignored — the operand list stays uniform so one
    body shape serves every precision.  Quantized traversals rerank their
    final pool in f32 inside ``beam_search_batch``, so the merged id set
    matches the f32 body's.

    ``live`` is the sharded (1, per) shard-local liveness mask, same uniform
    -operand idiom: under ``use_live=False`` the caller passes an all-ones
    array and the trace never touches it; under ``use_live=True`` the beam
    filters tombstoned candidates out of its final pool.

    Besides the merged top-k, the body all-gathers each shard's **summed
    ndist** (one scalar per shard) so the host can feed the cost model's
    ``ndist_per_ef`` EMA — without it the mesh path would never move the
    beam-cost estimate (traced bodies return no per-query stats)."""
    vecs, nbrs = vecs[0], nbrs[0]
    rmq, dist_c, order = rmq[0], dist_c[0], order[0]
    n, d = vecs.shape
    if precision == "f32":
        quant = None
    else:
        quant = (xq[0], scale[:d] if precision == "int8" else None)
    slo, shi = resolve.clip_interval_jax(lo, hi, rank0[0], n)
    entry = resolve.select_entry(rmq, dist_c, slo, shi, n)
    ids, dists, st = beam_search_batch(vecs, nbrs, qv, slo, shi, entry,
                                       k=k, ef=ef, beam_width=beam_width,
                                       quant=quant,
                                       live=live[0] if use_live else None)
    orig = resolve.remap_ids_jax(order, ids)
    dists = jnp.where(ids >= 0, dists, jnp.inf)
    ids_g = jax.lax.all_gather(orig, axis)               # (S, Q, k)
    ds_g = jax.lax.all_gather(dists, axis)
    nd_g = jax.lax.all_gather(jnp.sum(st["ndist"]), axis)    # (S,)
    out_i, out_d = merge_topk(ids_g, ds_g, k)
    return out_i, out_d, nd_g


def _shard_planned(x_scan, vecs, nbrs, rmq, dist_c, order, rank0, xq, scale,
                   live, scan_q, scan_lo, scan_hi, scan_dst,
                   beam_q, beam_lo, beam_hi, beam_dst, *,
                   k: int, ef: int, bucket: int, nq: int,
                   has_beam: bool, axis: str, beam_width: int = 1,
                   precision: str = "f32", use_live: bool = False):
    """Per-device planned body: branchless strategy dispatch.

    The host already split the batch into scan/beam sub-batches (replicated
    operands, padded to pow2 with empty windows), so the trace runs the
    ``range_scan`` kernel and the beam search **at most once each** — no
    ``lax.cond`` on traced values, no per-query branching.  Each group's
    results scatter into an ``(nq+1, k)`` buffer at its original request
    positions (pads land in the sink row ``nq``, dropped before the merge),
    restoring request order *before* the cross-shard top-k merge so the merge
    is identical to the graph body's.

    Quantized precisions: ``x_scan`` holds the *quantized* padded scan
    corpus (the caller swaps it per precision — same rank order, narrower
    DMA), ``xq`` the unpadded quantized rows for the beam's gathers, and
    ``scale`` the replicated (d_pad,) dequant row.  The scan keeps
    ``rerank_depth`` survivors and rescores them against the f32 ``vecs``
    in-trace, so scan rows leave this body exact; the beam reranks inside
    ``beam_search_batch``.  Under f32 the extra operands alias ``vecs`` /
    ones and are ignored.

    The scan group is always non-empty here — uniform-beam batches dispatch
    the graph body instead (``MeshSubstrate.run`` fast path).

    ``live`` is the sharded (1, per) shard-local liveness mask (all-ones and
    untouched under ``use_live=False``): the scan masks dead rows in-kernel
    (a (1, per_pad) i32 row built in-trace), the beam filters its final
    pool."""
    x_scan, vecs, nbrs = x_scan[0], vecs[0], nbrs[0]
    rmq, dist_c, order = rmq[0], dist_c[0], order[0]
    n, d = vecs.shape
    if use_live:
        live_sh = live[0]                                # (per,) shard-local
        live_row = jnp.pad(live_sh.astype(jnp.int32),
                           (0, x_scan.shape[0] - n))[None, :]
        live_beam = live_sh.astype(bool)
    else:
        live_row = live_beam = None
    out_i = jnp.full((nq + 1, k), -1, jnp.int32)
    out_d = jnp.full((nq + 1, k), jnp.inf, jnp.float32)
    slo, shi = resolve.clip_interval_jax(scan_lo, scan_hi, rank0[0], n)
    lens = jnp.clip(shi - slo + 1, 0, bucket)            # shard-local window
    starts = jnp.clip(slo, 0, n - 1)                     # (len 0 when empty)
    if precision == "f32":
        ids_s, d_s = range_scan(x_scan, starts, lens, scan_q,
                                bucket=bucket, k=k, n_valid=n, live=live_row)
    else:
        rq = rerank_depth(k, ef, cap=ROW_TILE)
        ids_q, _ = range_scan(x_scan, starts, lens, scan_q,
                              bucket=bucket, k=rq, n_valid=n,
                              scale=scale if precision == "int8" else None,
                              live=live_row)
        ids_s, d_s = rerank_pool(vecs, ids_q, scan_q[:, :d], k,
                                 use_kernel=False)
    d_s = jnp.where(ids_s >= 0, d_s, jnp.inf)
    out_i = out_i.at[scan_dst].set(resolve.remap_ids_jax(order, ids_s))
    out_d = out_d.at[scan_dst].set(d_s)
    nd = jnp.zeros((), jnp.int32)
    if has_beam:
        if precision == "f32":
            quant = None
        else:
            quant = (xq[0], scale[:d] if precision == "int8" else None)
        slo, shi = resolve.clip_interval_jax(beam_lo, beam_hi, rank0[0], n)
        entry = resolve.select_entry(rmq, dist_c, slo, shi, n)
        ids_b, d_b, st = beam_search_batch(vecs, nbrs, beam_q, slo, shi,
                                           entry, k=k, ef=ef,
                                           beam_width=beam_width,
                                           quant=quant, live=live_beam)
        d_b = jnp.where(ids_b >= 0, d_b, jnp.inf)
        out_i = out_i.at[beam_dst].set(resolve.remap_ids_jax(order, ids_b))
        out_d = out_d.at[beam_dst].set(d_b)
        nd = jnp.sum(st["ndist"])       # pad lanes: empty windows, ndist 0
    ids_g = jax.lax.all_gather(out_i[:nq], axis)         # (S, Q, k)
    ds_g = jax.lax.all_gather(out_d[:nq], axis)
    nd_g = jax.lax.all_gather(nd, axis)                  # (S,) beam-group sum
    out_ii, out_dd = merge_topk(ids_g, ds_g, k)
    return out_ii, out_dd, nd_g


class MeshSubstrate:
    """Mesh-path twin of ``SearchSubstrate``: host planning, traced dispatch.

    The cost router is host-side policy and cannot run inside a traced
    ``shard_map`` body, so the strategy split happens **before** tracing:

    * plan     — ``QueryPlanner.choose_strategy_batch`` over each query's
                 widest shard-local clip of the globally resolved rank
                 interval (one replicated decision per query — every shard
                 must agree so the traced shapes stay uniform);
    * dispatch — the strategy vector partitions the batch host-side into a
                 scan sub-batch (one shared pow2 ``bucket``) and a beam
                 sub-batch, entering ``shard_map`` as replicated operands;
                 ``_shard_planned`` runs each kernel at most once per shard;
    * stitch   — in-trace scatter back to request order, ``all_gather`` +
                 ``merge_topk`` across shards, replicated result.

    Compiled signatures are bounded the same way as the local planner's:
    ``(k, ef, bucket, pad_pow2(|scan|), pad_pow2(|beam|), Q)``.

    Calibration feedback: routed dispatches (``auto``/``scan``/``beam``)
    whose jit signature is already warm feed their wall time back into the
    planner's cost model — pure-beam calls observe the beam unit cost
    (work per lane ≈ ``ndist_per_ef · ef``), and mixed scan+beam calls are
    attributed proportionally to predicted unit costs
    (``observe_wall_mixed``).  The traced bodies additionally **all-gather
    a per-shard ndist scalar**, so warm routed dispatches also move the
    ``ndist_per_ef`` EMA itself — the mesh path calibrates the same two
    quantities the local path does.  ``req.strategy == "graph"`` — the
    paper's pure path — never calibrates.
    """

    def __init__(self, mesh, axis: str, vecs, nbrs, rmq, dist_c, order,
                 rank0, *, planner: Optional[QueryPlanner] = None,
                 cache: Optional[SearchCache] = None,
                 calibrate: bool = True,
                 metrics: Optional[MetricsRegistry] = None):
        self.mesh, self.axis = mesh, axis
        self._vecs = jnp.asarray(vecs, jnp.float32)      # (S, per, d)
        self._nbrs = jnp.asarray(nbrs)
        self._rmq = jnp.asarray(rmq)
        self._dist_c = jnp.asarray(dist_c)
        self._order = jnp.asarray(order)
        self._rank0 = jnp.asarray(rank0)                 # (S, 1) int32
        s, per, d = self._vecs.shape
        self.n_shards, self.per, self.d = s, per, d
        self.tb = ROW_TILE
        self.d_pad = -(-d // 128) * 128
        if planner is None:
            deg = float((np.asarray(nbrs) >= 0).sum(-1).mean()) if per else 1.0
            planner = QueryPlanner(max(per, 1), deg)
        self.planner = planner
        self.cache = cache
        self.calibrate = calibrate
        self.metrics = metrics      # optional MetricsRegistry (obs layer)
        self._x_pad = None          # padded scan corpus, built on first scan
        self._quant: Dict[str, dict] = {}   # precision -> quantized slots
        self._ones = None           # dummy replicated scale row (f32/bf16)
        self._live_memo = None      # (mask, (S, per) bool device copy)
        self._live_ones = None      # dummy all-live mask (uniform operands)
        self._fns: Dict[Tuple, object] = {}

    @property
    def index_bytes(self) -> int:
        return self._nbrs.nbytes + self._rmq.nbytes + self._dist_c.nbytes

    # --------------------------------------------------- quantized corpus
    def install_quantized(self, precision: str) -> None:
        """Eagerly build the per-shard quantized corpus copies (lazy build
        on first quantized request otherwise).  Rebuilding changes what
        non-f32 requests score against, so the mesh cache segment goes
        cold (same invariant as ``SearchSubstrate.install_quantized``)."""
        if precision != "f32":
            self._quant.pop(precision, None)
            self._quant_for(precision)
            if self.cache is not None:
                self.cache.invalidate_segment("mesh")

    # ------------------------------------------------------- liveness mask
    def _live_shards(self, live):
        """(n,) global rank-space mask -> (S, per) sharded device copy,
        memoized by object identity (one immutable array per corpus
        version)."""
        if live is None:
            if self._live_ones is None:
                self._live_ones = jnp.ones((self.n_shards, self.per), bool)
            return self._live_ones
        memo = self._live_memo
        if memo is not None and memo[0] is live:
            return memo[1]
        lv = np.asarray(live, bool)
        if lv.shape != (self.n_shards * self.per,):
            raise ValueError(
                f"live mask shape {lv.shape} does not match corpus "
                f"({self.n_shards * self.per},)")
        dev = jnp.asarray(lv.reshape(self.n_shards, self.per))
        self._live_memo = (live, dev)
        return dev

    def _ones_scale(self):
        """Replicated dummy scale row for precisions without one — keeps
        the traced bodies' operand list uniform across precisions."""
        if self._ones is None:
            self._ones = jnp.ones((self.d_pad,), jnp.float32)
        return self._ones

    def _quant_for(self, precision: str) -> Optional[dict]:
        """Per-shard quantized slots (lazy, cached).  The int8 scale is
        computed over the **whole** corpus (all shards jointly), so every
        shard dequantizes with the same replicated (d_pad,) row and merged
        distances are comparable across shards."""
        if precision == "f32":
            return None
        slot = self._quant.get(precision)
        if slot is None:
            s, per, d = self.n_shards, self.per, self.d
            qc = quantize_corpus(self._vecs.reshape(s * per, d), precision)
            data = qc.data.reshape(s, per, d)
            per_pad = -(-per // self.tb) * self.tb
            data_pad = jnp.pad(data, ((0, 0), (0, per_pad - per),
                                      (0, self.d_pad - d)))
            scale_pad = (self._ones_scale() if qc.scale is None else
                         jnp.pad(qc.scale, (0, self.d_pad - d),
                                 constant_values=1.0))
            slot = dict(data=data, data_pad=data_pad, scale_pad=scale_pad,
                        bytes_per_vector=qc.bytes_per_vector)
            self._quant[precision] = slot
        return slot

    # ------------------------------------------------------------- planning
    def plan_strategies(self, lo: np.ndarray, hi: np.ndarray, *, k: int,
                        ef: int, mode: str, beam_width: int = 1,
                        precision: str = "f32"
                        ) -> Tuple[np.ndarray, np.ndarray]:
        """Host half of mesh dispatch: (strategy (Q,) int8, lens_eff (Q,)).

        ``lens_eff`` is each query's **widest shard-local clip** of its
        global rank interval — the decision must be one replicated scalar
        per query, and the widest shard is the one whose scan cost the
        traced dispatch actually pays (shards execute in lockstep)."""
        lo = np.asarray(lo, np.int64)
        hi = np.asarray(hi, np.int64)
        lens_eff = np.zeros(len(lo), np.int64)
        for s in range(self.n_shards):
            slo, shi = resolve.clip_interval(lo, hi, s * self.per, self.per)
            lens_eff = np.maximum(lens_eff, np.clip(
                shi.astype(np.int64) - slo + 1, 0, None))
        if mode == "scan":
            return np.full(len(lo), SCAN, np.int8), lens_eff
        if mode == "beam":
            return np.full(len(lo), BEAM, np.int8), lens_eff
        return (self.planner.choose_strategy_batch(lens_eff, k=k, ef=ef,
                                                   beam_width=beam_width,
                                                   precision=precision),
                lens_eff)

    # ---------------------------------------------------------------- run
    def run(self, req: SearchRequest) -> SearchResult:
        """Dispatch one request on the mesh; result ids are original corpus
        ids, already merged across shards (replicated).  With a cache
        installed, hit rows skip the mesh dispatch entirely.  A ``req.trace``
        collects plan / dispatch / stitch spans (the cross-shard scatter +
        merge run *inside* the traced body, so the host-side stitch span
        covers output conversion and cache assembly)."""
        qv = np.asarray(req.queries, np.float32)
        lo = np.asarray(req.lo, np.int64)
        hi = np.asarray(req.hi, np.int64)
        k, ef = int(req.k), max(int(req.ef), int(req.k))
        bw = int(req.beam_width)
        prec = req.precision
        tr = req.trace
        met = self.metrics
        nq = len(qv)
        if nq == 0:
            return SearchResult(np.zeros((0, k), np.int32),
                                np.zeros((0, k), np.float32),
                                {"strategy": np.zeros(0, np.int8),
                                 "scan_frac": 0.0}, trace=tr)
        if met is not None:
            met.counter("queries_total").inc(nq)
            met.counter("mesh_queries_total").inc(nq)
            met.counter(f"queries_{prec}_total").inc(nq)
        live = req.live
        cache = self.cache
        cache_info = dict(cache_enabled=cache is not None,
                          cache_hits=0, cache_misses=nq, batch_dedup=0)
        if cache is None:
            res = self._run_uncached(qv, lo, hi, k, ef, req.strategy, bw,
                                     prec, trace=tr, cache_info=cache_info,
                                     live=live)
            res.trace = tr
            return res
        # fences stores vs invalidate() / invalidate_segment("mesh")
        epoch = cache.epoch_for("mesh")
        cal_epoch = (self.planner.calibration_epoch
                     if req.strategy == "auto" else None)
        keys, hit_rows, miss, dups = cache.split(qv, lo, hi, k, ef,
                                                 req.strategy, ns="mesh",
                                                 beam_width=bw,
                                                 precision=prec,
                                                 cal_epoch=cal_epoch)
        cache_info.update(cache_hits=len(hit_rows), cache_misses=len(miss),
                          batch_dedup=len(dups))
        if met is not None:
            met.counter("cache_hit_rows_total").inc(len(hit_rows))
            met.counter("cache_miss_rows_total").inc(len(miss))
            if dups:
                met.counter("cache_dedup_rows_total").inc(len(dups))
        if len(miss) == 0:
            if tr is not None:          # fully hit: no mesh dispatch at all
                tr.add_span("dispatch", dispatched=0, ns="mesh",
                            **cache_info)
            with maybe_span(tr, "stitch", ns="mesh"):
                res = cache.assemble(nq, k, hit_rows, None, miss)
            res.trace = tr
            return res
        miss_res = self._run_uncached(qv[miss], lo[miss], hi[miss], k, ef,
                                      req.strategy, bw, prec, trace=tr,
                                      cache_info=cache_info, live=live)
        cache.store_batch([keys[i] for i in miss], miss_res, epoch=epoch,
                          cal_epoch=cal_epoch)
        if not hit_rows and not dups:
            miss_res.stats["cache_hits"] = 0
            miss_res.trace = tr
            return miss_res
        with maybe_span(tr, "stitch", ns="mesh"):
            res = cache.assemble(nq, k, hit_rows, miss_res, miss, dups)
        res.trace = tr
        return res

    def _shard_clip_widths(self, lo, hi) -> np.ndarray:
        """(S, Q) shard-local clipped interval widths — the dispatch-span
        view of how each query's global interval lands on the mesh."""
        w = []
        for s in range(self.n_shards):
            slo, shi = resolve.clip_interval(lo, hi, s * self.per, self.per)
            w.append(np.clip(shi.astype(np.int64) - slo + 1, 0, None))
        return np.stack(w)

    def _run_uncached(self, qv, lo, hi, k: int, ef: int, mode: str,
                      beam_width: int = 1, precision: str = "f32",
                      trace=None, cache_info=None, live=None) -> SearchResult:
        nq = len(qv)
        met = self.metrics
        if mode == "graph":
            if trace is not None:
                trace.add_span("plan", strategy_mode="graph", chosen="graph",
                               beam_width=beam_width)
            if met is not None:
                met.counter("graph_queries_total").inc(nq)
            with maybe_span(trace, "dispatch") as sp:
                sp.attrs.update(cache_info or {})
                sp.attrs.update(strategy_mode=mode, ns="mesh",
                                dispatched=nq, beam_width=beam_width,
                                precision=precision,
                                shard_clip_widths=self._shard_clip_widths(
                                    lo, hi) if trace is not None else None)
                ids, dists = self._call_graph(qv, lo, hi, k, ef,
                                              calibrate=False,
                                              beam_width=beam_width,
                                              precision=precision, live=live)
            with maybe_span(trace, "stitch", ns="mesh"):
                res = SearchResult(ids, dists,
                                   {"strategy": np.ones(nq, np.int8),
                                    "scan_frac": 0.0})
            return res
        if trace is None:
            strategy, lens_eff = self.plan_strategies(lo, hi, k=k, ef=ef,
                                                      mode=mode,
                                                      beam_width=beam_width,
                                                      precision=precision)
        else:
            with trace.span("plan") as psp:
                strategy, lens_eff = self.plan_strategies(
                    lo, hi, k=k, ef=ef, mode=mode, beam_width=beam_width,
                    precision=precision)
                sc, bc = self.planner.predict_costs(lens_eff, k=k, ef=ef,
                                                    beam_width=beam_width,
                                                    precision=precision)
                psp.attrs.update(strategy_mode=mode,
                                 strategy=strategy.copy(),
                                 lens_eff=lens_eff.copy(),
                                 beam_width=beam_width,
                                 precision=precision,
                                 scan_frac=float((strategy == SCAN).mean()),
                                 predicted_scan_units=sc,
                                 predicted_beam_units=bc)
        scan_idx = np.flatnonzero(strategy == SCAN)
        beam_idx = np.flatnonzero(strategy == BEAM)
        if met is not None:
            met.counter("scan_routed_total").inc(len(scan_idx))
            met.counter("beam_routed_total").inc(len(beam_idx))
        if len(scan_idx) == 0:
            # uniform-beam batch: the planned body would degenerate to the
            # graph body plus pow2 padding and a scatter — dispatch the graph
            # fn directly (same ef, same merge, bit-identical results)
            with maybe_span(trace, "dispatch") as sp:
                sp.attrs.update(cache_info or {})
                sp.attrs.update(strategy_mode=mode, ns="mesh",
                                dispatched=nq, beam_width=beam_width,
                                precision=precision,
                                uniform_beam_fast_path=True,
                                shard_clip_widths=self._shard_clip_widths(
                                    lo, hi) if trace is not None else None)
                ids, dists = self._call_graph(qv, lo, hi, k, ef,
                                              calibrate=self.calibrate,
                                              beam_width=beam_width,
                                              precision=precision, live=live)
            with maybe_span(trace, "stitch", ns="mesh"):
                res = SearchResult(ids, dists,
                                   {"strategy": strategy, "scan_frac": 0.0})
            return res
        # scan_idx is non-empty past the fast path; one shared bucket covers
        # every scan query's widest shard-local clip (never truncates)
        cap = next_pow2(self.per)
        bucket = max(bucket_for_len(
            int(ln), min_bucket=self.planner.min_bucket, max_bucket=cap)
            for ln in lens_eff[scan_idx])
        pad_s = pad_pow2(len(scan_idx))
        pad_b = pad_pow2(len(beam_idx)) if len(beam_idx) else 0
        use_live = live is not None
        key = ("planned", k, ef, bucket, pad_s, pad_b, nq, beam_width,
               precision, use_live)
        warm = key in self._fns
        fn = self._planned_fn(k=k, ef=ef, bucket=bucket, pad_s=pad_s,
                              pad_b=pad_b, nq=nq, beam_width=beam_width,
                              precision=precision, use_live=use_live)
        slot = self._quant_for(precision)
        if slot is None:
            x_scan, xq, scale = (self._scan_corpus(), self._vecs,
                                 self._ones_scale())
        else:
            x_scan, xq, scale = (slot["data_pad"], slot["data"],
                                 slot["scale_pad"])
        scan_ops = self._group_operands(qv, lo, hi, scan_idx, pad_s, nq,
                                        lane_pad=True)
        beam_ops = self._group_operands(qv, lo, hi, beam_idx, pad_b, nq,
                                        lane_pad=False)
        pad_rows = (pad_s - len(scan_idx)) + (pad_b - len(beam_idx))
        if met is not None and pad_rows:
            met.counter("pad_rows_total").inc(pad_rows)
        t0 = time.perf_counter()
        with maybe_span(trace, "dispatch") as sp:
            sp.attrs.update(cache_info or {})
            sp.attrs.update(strategy_mode=mode, ns="mesh", dispatched=nq,
                            beam_width=beam_width, warm=warm, bucket=bucket,
                            precision=precision,
                            pad_scan=pad_s, pad_beam=pad_b,
                            pad_rows=pad_rows,
                            shard_clip_widths=self._shard_clip_widths(
                                lo, hi) if trace is not None else None)
            with annotate("rnsg.mesh_planned_dispatch"):
                ids, dists, nd_g = fn(x_scan, self._vecs,
                                      self._nbrs, self._rmq, self._dist_c,
                                      self._order, self._rank0, xq, scale,
                                      self._live_shards(live),
                                      *scan_ops, *beam_ops)
                ids = np.asarray(ids)
                dists = np.asarray(dists)
        if met is not None:
            met.histogram("mesh_dispatch_ms").observe(
                (time.perf_counter() - t0) * 1e3)
        if self.calibrate and warm:
            # one fused traced step: attribute the wall time across the two
            # groups proportionally to their predicted unit costs.  Scan
            # lanes count the pow2 padding (empty windows still scan their
            # fixed-shape blocks — real work); beam lanes count only the
            # real queries (pad lanes carry empty windows and exit the
            # while_loop immediately)
            dt = time.perf_counter() - t0
            n_beam = len(beam_idx)
            self.planner.cost.observe_wall_mixed(
                window_rows(bucket, self.tb) * pad_s,
                self.planner.cost.ndist_per_ef_at(beam_width) * ef * n_beam,
                dt, pad_s, n_beam, precision=precision)
            if len(beam_idx):
                # all-gathered per-shard ndist sums: pad lanes carry empty
                # windows (ndist 0), so normalize by the real beam count —
                # this is the signal that moves the mesh path's ndist EMA
                nd_mean = float(np.asarray(nd_g).mean()) / len(beam_idx)
                self.planner.cost.update_beam(nd_mean, ef,
                                              beam_width=beam_width)
        scan_frac = len(scan_idx) / nq
        with maybe_span(trace, "stitch", ns="mesh"):
            res = SearchResult(ids, dists,
                               {"strategy": strategy,
                                "scan_frac": scan_frac})
        return res

    def _call_graph(self, qv, lo, hi, k: int, ef: int, *, calibrate: bool,
                    beam_width: int = 1, precision: str = "f32", live=None):
        """One graph-body mesh dispatch (+ optional warm-call beam
        calibration for routed uniform-beam batches: wall time and the
        all-gathered per-shard ndist feed the cost model)."""
        use_live = live is not None
        warm = ("graph", k, max(ef, k), beam_width, precision,
                use_live) in self._fns
        fn = self.graph_fn(k, ef, beam_width, precision, use_live=use_live)
        slot = self._quant_for(precision)
        xq = self._vecs if slot is None else slot["data"]
        scale = self._ones_scale() if slot is None else slot["scale_pad"]
        t0 = time.perf_counter()
        with annotate("rnsg.mesh_graph_dispatch"):
            ids, dists, nd_g = fn(self._vecs, self._nbrs, self._rmq,
                                  self._dist_c, self._order, self._rank0,
                                  xq, scale, self._live_shards(live),
                                  jnp.asarray(qv),
                                  jnp.asarray(np.asarray(lo).astype(np.int32)),
                                  jnp.asarray(np.asarray(hi).astype(np.int32)))
            ids = np.asarray(ids)
            dists = np.asarray(dists)
        if self.metrics is not None:
            self.metrics.histogram("mesh_dispatch_ms").observe(
                (time.perf_counter() - t0) * 1e3)
        if calibrate and warm:
            # both feeds normalize by the NON-EMPTY row count: forced-beam
            # batches may carry empty intervals (the local path routes
            # those to scan), which exit the while_loop immediately and
            # would bias both the wall-per-unit estimate and the ndist EMA
            # toward free
            n_real = int((np.asarray(lo) <= np.asarray(hi)).sum())
            if n_real:
                dt = time.perf_counter() - t0
                self.planner.cost.observe_wall(
                    "beam",
                    max(self.planner.cost.ndist_per_ef_at(beam_width) * ef,
                        1.0),
                    dt, n_real, precision=precision)
                nd_mean = float(np.asarray(nd_g).mean()) / n_real
                self.planner.cost.update_beam(nd_mean, ef,
                                              beam_width=beam_width)
        return ids, dists

    # ------------------------------------------------------------ operands
    def _group_operands(self, qv, lo, hi, idx, pad: int, nq: int, *,
                        lane_pad: bool):
        """One strategy group's replicated operands: queries (pow2-padded),
        global rank interval, and scatter destinations.  Pads carry empty
        windows (lo=1 > hi=0 — masked in scan, immediate exit in beam) and
        scatter into the sink row ``nq``."""
        m = len(idx)
        qd = self.d_pad if lane_pad else self.d
        g_q = np.zeros((pad, qd), np.float32)
        g_lo = np.ones(pad, np.int32)
        g_hi = np.zeros(pad, np.int32)
        dst = np.full(pad, nq, np.int32)
        if m:
            g_q[:m, :self.d] = qv[idx]
            g_lo[:m] = lo[idx]
            g_hi[:m] = hi[idx]
            dst[:m] = idx
        return (jnp.asarray(g_q), jnp.asarray(g_lo), jnp.asarray(g_hi),
                jnp.asarray(dst))

    def _scan_corpus(self):
        """Row/lane-padded per-shard corpus for the scan kernel (lazy: a
        mesh that never routes to scan skips the duplicate)."""
        if self._x_pad is None:
            per_pad = -(-self.per // self.tb) * self.tb
            self._x_pad = jnp.pad(
                self._vecs, ((0, 0), (0, per_pad - self.per),
                             (0, self.d_pad - self.d)))
        return self._x_pad

    # ---------------------------------------------------------- traced fns
    def graph_fn(self, k: int, ef: int, beam_width: int = 1,
                 precision: str = "f32", use_live: bool = False):
        """Jitted graph-strategy mesh fn (also the dry-run lowering target).
        Operands: 6 sharded index arrays + sharded ``xq`` + replicated
        ``scale`` + sharded ``live`` + replicated ``(qv, lo, hi)`` — under
        f32 pass ``vecs`` again as ``xq`` and any (d_pad,) f32 row as
        ``scale``; under ``use_live=False`` pass any (S, per) array as
        ``live`` (all ignored).  Returns (ids, dists, ndist_per_shard)."""
        key = ("graph", k, max(ef, k), beam_width, precision, use_live)
        fn = self._fns.get(key)
        if fn is None:
            body = partial(_shard_graph, k=k, ef=max(ef, k), axis=self.axis,
                           beam_width=beam_width, precision=precision,
                           use_live=use_live)
            shard, rep = P(self.axis), P()
            fn = jax.jit(shard_map_compat(
                body, self.mesh,
                in_specs=(shard,) * 7 + (rep,) + (shard,) + (rep,) * 3,
                out_specs=(rep, rep, rep)))
            self._fns[key] = fn
        return fn

    def _planned_fn(self, *, k, ef, bucket, pad_s, pad_b, nq,
                    beam_width: int = 1, precision: str = "f32",
                    use_live: bool = False):
        key = ("planned", k, ef, bucket, pad_s, pad_b, nq, beam_width,
               precision, use_live)
        fn = self._fns.get(key)
        if fn is None:
            body = partial(_shard_planned, k=k, ef=ef, bucket=bucket, nq=nq,
                           has_beam=pad_b > 0, axis=self.axis,
                           beam_width=beam_width, precision=precision,
                           use_live=use_live)
            shard, rep = P(self.axis), P()
            fn = jax.jit(shard_map_compat(
                body, self.mesh,
                in_specs=(shard,) * 8 + (rep,) + (shard,) + (rep,) * 8,
                out_specs=(rep, rep, rep)))
            self._fns[key] = fn
        return fn
