"""Unified search substrate: one strategy-routed execution layer.

Every query path in the repo — single-node ``RNSGIndex``, the adaptive
planner, the dynamic-batching engine, and range-partitioned distributed
serving (both its local and ``shard_map`` mesh paths) — flows through this
package:

    SearchRequest (queries, rank intervals, k/ef, strategy)
        -> resolve   (rank-interval mapping + RMQ entry selection)
        -> cache     (optional SearchCache: hit rows skip dispatch entirely)
        -> dispatch  (range-scan kernel | graph beam | planned mix;
                      async at the substrate boundary — PendingSearch)
        -> stitch    (request-order stats, rank -> original id remap)
        -> SearchResult

Two execution substrates implement dispatch + stitch over the same resolve
primitives:

* ``SearchSubstrate`` — one attribute-sorted corpus slice on the host
  (single node, or one shard of the distributed local path); the planner
  partitions each batch into fixed-shape jit dispatches and calibrates the
  cost model from observed wall times.
* ``MeshSubstrate`` — all shards at once under ``shard_map``; the planner
  runs host-side over shard-clipped global intervals and the traced
  per-device body executes a branchless scan+beam select, restitched in
  request order before the cross-shard ``merge_topk``.

See docs/architecture.md for the layer diagram and docs/distributed.md for
the mesh dispatch flow.
"""
from repro.search.cache import SearchCache, query_key
from repro.search.request import (PRECISIONS, STRATEGIES, SearchRequest,
                                  SearchResult)
from repro.search.resolve import (clip_interval, clip_interval_jax,
                                  rank_interval, rank_interval_jax,
                                  remap_ids, remap_ids_jax, select_entry)
from repro.search.substrate import (MeshSubstrate, PendingSearch,
                                    SearchSubstrate, merge_topk)

__all__ = ["PRECISIONS", "STRATEGIES", "SearchRequest", "SearchResult",
           "SearchSubstrate",
           "MeshSubstrate", "PendingSearch", "SearchCache", "query_key",
           "merge_topk",
           "rank_interval", "rank_interval_jax", "select_entry",
           "remap_ids", "remap_ids_jax", "clip_interval", "clip_interval_jax"]
