"""Unified search substrate: one strategy-routed execution layer.

Every query path in the repo — single-node ``RNSGIndex``, the adaptive
planner, the dynamic-batching engine, and range-partitioned distributed
serving — flows through this package:

    SearchRequest (queries, rank intervals, k/ef, strategy)
        -> resolve   (rank-interval mapping + RMQ entry selection)
        -> dispatch  (range-scan kernel | graph beam | planned mix)
        -> stitch    (request-order stats, rank -> original id remap)
        -> SearchResult

See docs/architecture.md for the layer diagram.
"""
from repro.search.request import STRATEGIES, SearchRequest, SearchResult
from repro.search.resolve import (clip_interval, clip_interval_jax,
                                  rank_interval, rank_interval_jax,
                                  remap_ids, remap_ids_jax, select_entry)
from repro.search.substrate import SearchSubstrate

__all__ = ["STRATEGIES", "SearchRequest", "SearchResult", "SearchSubstrate",
           "rank_interval", "rank_interval_jax", "select_entry",
           "remap_ids", "remap_ids_jax", "clip_interval", "clip_interval_jax"]
