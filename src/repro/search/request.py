"""Search request/result types shared by every query path.

``SearchResult`` intentionally behaves like the historical
``(ids, dists, stats)`` tuple (iteration and indexing) so call sites can
migrate to attribute access incrementally.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import numpy as np

STRATEGIES = ("graph", "auto", "scan", "beam")

# mirrored from repro.kernels.quantize (imported lazily there to keep this
# module dependency-free for type-only consumers)
PRECISIONS = ("f32", "int8", "bf16")


def _invalid(field_name: str, value, requirement: str) -> ValueError:
    """Uniform validation error: names the offending field and the value it
    carried, so a batch producer can map the message back to its input."""
    return ValueError(
        f"SearchRequest: invalid {field_name}={value!r} ({requirement})")


@dataclass(frozen=True)
class SearchRequest:
    """One batched range-filtered kNN request in rank space.

    queries : (Q, d) float32 query vectors.
    lo, hi  : (Q,) inclusive attribute-rank interval per query (lo > hi
              encodes an empty range).  Rank mapping from raw attribute
              ranges lives in ``repro.search.resolve``.
    strategy: "graph" — the paper's pure beam search over the full batch;
              "auto"  — cost-based scan/beam routing per query;
              "scan" / "beam" — forced strategy (tests, benchmarks).
    beam_width: batched-expansion width for every beam dispatch this
              request performs (1 = the legacy single-node expansion; B>1
              expands the best B candidates per hop — see
              ``repro.core.beam``).
    precision: corpus dtype the distance pass scores against — "f32"
              (exact), or "int8"/"bf16" (quantized scan/traversal followed
              by a fused f32 rerank of the survivors, so the returned top-k
              id set matches the f32 path — see ``repro.kernels.quantize``).
              Non-f32 requires the substrate to have the quantized corpus
              installed (``install_quantized``).
    trace   : optional ``repro.obs.QueryTrace``.  When attached, every
              stage that touches the request appends a wall-timed span
              (resolve / plan / dispatch / stitch) and the trace comes back
              on the ``SearchResult``.  ``None`` (the default) keeps the
              hot path to a single ``is None`` check.
    live    : optional (n,) bool per-**rank** liveness mask (the streaming
              layer's tombstones; ``False`` = deleted).  Dead rows never
              appear in results but stay traversable routing nodes on the
              beam path; the scan path masks them in-kernel.  The mask is
              corpus state, not part of the cache key — a caller that
              mutates it owns invalidating the substrate's cache segment
              (``SearchCache.invalidate_segment``); the streaming layer
              does this on every delete/compaction.
    """
    queries: np.ndarray
    lo: np.ndarray
    hi: np.ndarray
    k: int = 10
    ef: int = 64
    strategy: str = "graph"
    use_kernel: bool = False
    beam_width: int = 1
    precision: str = "f32"
    trace: Optional[Any] = None
    live: Optional[np.ndarray] = None

    def __post_init__(self):
        if self.live is not None and np.ndim(self.live) != 1:
            raise _invalid("live", getattr(self.live, "shape", self.live),
                           "expected a 1-D per-rank mask")
        if self.strategy not in STRATEGIES:
            raise _invalid("strategy", self.strategy,
                           f"expected one of {STRATEGIES}")
        if self.precision not in PRECISIONS:
            raise _invalid("precision", self.precision,
                           f"expected one of {PRECISIONS}")
        if self.k < 1:
            raise _invalid("k", self.k, "must be >= 1")
        if self.ef < 1:
            raise _invalid("ef", self.ef, "must be >= 1")
        if self.beam_width < 1:
            raise _invalid("beam_width", self.beam_width, "must be >= 1")


@dataclass
class SearchResult:
    """ids: (Q, k) original corpus ids (-1 padded); dists: (Q, k) squared L2
    (+inf padded); stats: per-query hops/ndist plus routing info; trace:
    the request's ``QueryTrace`` (when one was attached), with every span
    the path recorded."""
    ids: np.ndarray
    dists: np.ndarray
    stats: Dict[str, Any] = field(default_factory=dict)
    trace: Optional[Any] = None

    # tuple compatibility ------------------------------------------------
    def __iter__(self):
        return iter((self.ids, self.dists, self.stats))

    def __getitem__(self, i):
        return (self.ids, self.dists, self.stats)[i]

    def __len__(self):
        return 3

    def row(self, i: int) -> "SearchResult":
        """Per-request slice (engine futures resolve to these).  The batch
        trace rides along on every row — spans are batch-scoped."""
        return SearchResult(self.ids[i], self.dists[i],
                            {k: v[i] for k, v in self.stats.items()
                             if isinstance(v, np.ndarray) and v.ndim >= 1
                             and len(v) == len(self.ids)},
                            trace=self.trace)
