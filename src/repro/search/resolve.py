"""Resolve stage: the single implementation of rank-interval mapping, RMQ
entry selection, and rank -> original-id remapping.

Ids everywhere in the search path are attribute ranks over the sorted
corpus; raw attribute ranges enter here and leave as inclusive rank
intervals ``[lo, hi]`` (``lo > hi`` = empty).  Both host (numpy) and traced
(jax, usable inside ``shard_map`` bodies) variants live in this module —
no other module under ``src/repro`` may call ``searchsorted`` or
``rmq_query_jax`` directly (enforced by
``tests/test_search_substrate.py::test_resolve_is_single_source``).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.entry import rmq_query_jax


# ------------------------------------------------------------- rank mapping
def rank_interval(attrs_sorted: np.ndarray,
                  attr_ranges: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Host path: [a_l, a_r] (inclusive) -> rank interval [lo, hi] (inclusive).
    attrs_sorted: (n,) ascending; attr_ranges: (Q, 2)."""
    ar = np.asarray(attr_ranges, np.float32)
    lo = np.searchsorted(attrs_sorted, ar[:, 0], side="left")
    hi = np.searchsorted(attrs_sorted, ar[:, 1], side="right") - 1
    return lo.astype(np.int32), hi.astype(np.int32)


def rank_interval_jax(attrs_sorted: jax.Array,
                      attr_ranges: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Traced path (shard_map bodies): same contract as ``rank_interval``."""
    lo = jnp.searchsorted(attrs_sorted, attr_ranges[:, 0],
                          side="left").astype(jnp.int32)
    hi = (jnp.searchsorted(attrs_sorted, attr_ranges[:, 1],
                           side="right") - 1).astype(jnp.int32)
    return lo, hi


# ----------------------------------------------------------- shard clipping
def clip_interval(lo: np.ndarray, hi: np.ndarray, rank0: int,
                  n_local: int) -> Tuple[np.ndarray, np.ndarray]:
    """Clip a *global* rank interval to the shard covering global ranks
    [rank0, rank0 + n_local); returns shard-local ranks (empty stays empty).
    Shards are contiguous slices of the sorted corpus, so this equals a
    per-shard ``searchsorted`` (Theorem 4.7 heredity at the resolve layer)."""
    slo = np.maximum(np.asarray(lo, np.int64) - rank0, 0)
    shi = np.minimum(np.asarray(hi, np.int64) - rank0, n_local - 1)
    return slo.astype(np.int32), shi.astype(np.int32)


def clip_interval_jax(lo: jax.Array, hi: jax.Array, rank0: jax.Array,
                      n_local: int) -> Tuple[jax.Array, jax.Array]:
    slo = jnp.maximum(lo.astype(jnp.int32) - rank0, 0)
    shi = jnp.minimum(hi.astype(jnp.int32) - rank0, n_local - 1)
    return slo, shi


# ---------------------------------------------------------- entry selection
def select_entry(rmq: jax.Array, dist_c: jax.Array, lo: jax.Array,
                 hi: jax.Array, n: int) -> jax.Array:
    """RMQ entry node(s) for [lo, hi]: argmin of centroid distance over the
    interval, with the empty/degenerate clipping every caller needs."""
    return rmq_query_jax(rmq, dist_c, jnp.minimum(lo, n - 1),
                         jnp.clip(hi, 0, n - 1))


# -------------------------------------------------------------- id remap
def remap_ids(order: np.ndarray, ids: np.ndarray) -> np.ndarray:
    """Stitch stage, host path: attribute-rank ids -> original corpus ids
    (-1 padding preserved)."""
    ids = np.asarray(ids)
    return np.where(ids >= 0, np.asarray(order)[np.maximum(ids, 0)], -1)


def remap_ids_jax(order: jax.Array, ids: jax.Array) -> jax.Array:
    return jnp.where(ids >= 0, order[jnp.maximum(ids, 0)], -1)
