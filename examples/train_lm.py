"""End-to-end training driver: ~20M-param llama-family model, a few hundred
steps on the synthetic Markov stream, with checkpoint/restart and straggler
monitoring.  (Use --preset 100m on a beefier host; this container has 1 core.)

  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--preset 20m]
"""
import argparse
import dataclasses
import sys

from repro.configs.registry import get_smoke_config
import repro.launch.train as T

PRESETS = {
    "tiny": dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                 vocab_size=512),
    "20m": dict(n_layers=6, d_model=384, n_heads=6, n_kv_heads=2, d_ff=1024,
                vocab_size=4096),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=2048, vocab_size=8192),
}

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--seq", type=int, default=128)
args = ap.parse_args()

base = get_smoke_config("llama3-8b")
cfg = dataclasses.replace(base, **PRESETS[args.preset], head_dim=0)

# monkey-patch the trainer's config resolution with our preset
orig = T.get_smoke_config
T.get_smoke_config = lambda arch: cfg
try:
    T.main(["--arch", "llama3-8b", "--steps", str(args.steps),
            "--batch", str(args.batch), "--seq", str(args.seq),
            "--ckpt-dir", "/tmp/repro_train_lm", "--ckpt-every", "100",
            "--log-every", "20"])
finally:
    T.get_smoke_config = orig
