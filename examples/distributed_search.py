"""Range-partitioned distributed RFANN (the heredity theorem at scale).

Shards are attribute-contiguous; each shard's induced subgraph IS the RNSG of
that shard (Thm 4.7), so shard-local searches + a top-k merge equal a global
search.  Runs on CPU with a single device (sequential shards) — the same
class drives the shard_map path over a real mesh (see DESIGN.md).

  PYTHONPATH=src python examples/distributed_search.py
"""
import numpy as np

from repro.data.ann import (ground_truth, make_attrs, make_vectors,
                            mixed_workload, recall_at_k)
from repro.serving.distributed import DistributedRFANN

n, d, nq, k = 8192, 32, 100, 10
vectors = make_vectors(n, d, seed=0)
attrs = make_attrs(n, seed=0)

dist = DistributedRFANN(vectors, attrs, n_shards=8, m=16, ef_spatial=16,
                        ef_attribute=24)
print(f"built {dist.n_shards} shards "
      f"({dist.index_bytes/2**20:.2f} MB graph structure)")
print("shard attribute spans:", np.round(dist.shard_span[:4], 3), "...")

queries = make_vectors(nq, d, seed=7)
ranges, _ = mixed_workload(attrs, nq, seed=2)
ids, dists = dist.search(queries, ranges, k=k, ef=96)

order = np.argsort(attrs, kind="stable")
gt_r, _ = ground_truth(vectors[order], attrs[order], queries, ranges, k)
gt = np.where(gt_r >= 0, order[np.maximum(gt_r, 0)], -1)
print(f"distributed recall@{k} = {recall_at_k(ids, gt):.4f}")
