"""Quickstart: build an RNSG index and answer range-filtered ANN queries.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.rfann import RNSGIndex
from repro.data.ann import (ground_truth, make_attrs, make_vectors,
                            recall_at_k, selectivity_ranges)

n, d, nq, k = 4096, 32, 100, 10

# a corpus: one vector + one numeric attribute (price, timestamp, ...) each
vectors = make_vectors(n, d, seed=0)
attrs = make_attrs(n, seed=0)

# ONE index serves every query range (Theorems 3.5 / 4.7: heredity)
index = RNSGIndex.build(vectors, attrs, m=16, ef_spatial=16, ef_attribute=24)
print("index:", index.stats())

queries = make_vectors(nq, d, seed=7)
ranges = selectivity_ranges(attrs, nq, frac=0.05, seed=1)   # 5% selectivity

ids, dists, stats = index.search(queries, ranges, k=k, ef=64)
order = np.argsort(attrs, kind="stable")
gt_r, _ = ground_truth(vectors[order], attrs[order], queries, ranges, k)
gt = np.where(gt_r >= 0, order[np.maximum(gt_r, 0)], -1)
print(f"recall@{k} = {recall_at_k(ids, gt):.4f}  "
      f"(mean hops {stats['hops'].mean():.1f}, "
      f"mean dist-evals {stats['ndist'].mean():.0f})")

# every hit respects the range filter
for q in range(nq):
    for i in ids[q]:
        assert i < 0 or ranges[q, 0] <= attrs[i] <= ranges[q, 1]
print("all results in range ✓")

# adaptive query planner (docs/planner.md): each query is routed to the
# cheapest correct strategy — a fused exact scan of the rank slice for narrow
# ranges, beam search for wide ones — with cost calibration happening online
mixed = np.concatenate([selectivity_ranges(attrs, nq // 2, 0.005, seed=2),
                        selectivity_ranges(attrs, nq // 2, 0.5, seed=3)])
pids, _, pstats = index.search(queries, mixed, k=k, ef=64, plan="auto")
gt_r, _ = ground_truth(vectors[order], attrs[order], queries, mixed, k)
gt = np.where(gt_r >= 0, order[np.maximum(gt_r, 0)], -1)
print(f"planner recall@{k} = {recall_at_k(pids, gt):.4f}  "
      f"({pstats['scan_frac']:.0%} of queries routed to range_scan)")
