"""End-to-end RAG-style serving: LM embeds the query, RNSG retrieves
range-filtered context (e.g. "similar docs from this date range"), the LM
generates conditioned on retrieved context.

  PYTHONPATH=src python examples/rag_serving.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.registry import get_smoke_config
from repro.core.rfann import RNSGIndex
from repro.data.ann import make_attrs
from repro.models.lm import Model
from repro.models.params import ShardPlan

# --- a small LM (reduced llama3 config) --------------------------------
cfg = get_smoke_config("llama3-8b")
model = Model(cfg, ShardPlan())
params = model.init(jax.random.key(0))
rng = np.random.default_rng(0)


def embed(tokens: np.ndarray) -> np.ndarray:
    """Mean-pooled final hidden state as the retrieval embedding."""
    batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
    cache, _ = model.prefill(params, batch)
    # pool the value cache of the last layer as a cheap sentence embedding
    v = np.asarray(cache["v"][-1], np.float32)           # (B, S, Kh, hd)
    return v.mean(axis=(1, 2))                            # (B, hd)


# --- corpus: 2048 "documents" with timestamps ---------------------------
n_docs, doc_len = 2048, 16
docs = rng.integers(0, cfg.vocab_size, (n_docs, doc_len)).astype(np.int32)
timestamps = make_attrs(n_docs, seed=3)                  # pretend dates
print("embedding corpus ...")
doc_emb = np.concatenate([embed(docs[i:i + 256]) for i in range(0, n_docs, 256)])

index = RNSGIndex.build(doc_emb, timestamps, m=16, ef_spatial=16,
                        ef_attribute=24)
print("retrieval index:", index.stats())

# --- a user query restricted to a date range ----------------------------
query_tokens = rng.integers(0, cfg.vocab_size, (1, doc_len)).astype(np.int32)
q_emb = embed(query_tokens)
date_lo, date_hi = np.quantile(timestamps, [0.2, 0.4])
ids, dists, _ = index.search(q_emb, np.asarray([[date_lo, date_hi]],
                                               np.float32), k=3, ef=64)
print(f"retrieved docs {ids[0].tolist()} from date range "
      f"[{date_lo:.3f}, {date_hi:.3f}]")
for i in ids[0]:
    assert date_lo <= timestamps[i] <= date_hi

# --- generate conditioned on retrieved context --------------------------
context = np.concatenate([docs[i] for i in ids[0]] + [query_tokens[0]])[None]
S = context.shape[1]
cache, logits = model.prefill(params, {"tokens": jnp.asarray(context)},
                              cache_len=S + 16)
dec = jax.jit(model.decode)
tok = jnp.argmax(logits[:, :cfg.vocab_size], -1).astype(jnp.int32)
out = [int(tok[0])]
for i in range(15):
    logits, cache = dec(params, cache, jnp.asarray(S + i, jnp.int32), tok)
    tok = jnp.argmax(logits[:, :cfg.vocab_size], -1).astype(jnp.int32)
    out.append(int(tok[0]))
print("generated continuation ids:", out)
print("RAG pipeline complete ✓")
