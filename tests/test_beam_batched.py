"""Batched beam expansion (``beam_width > 1``): parity with the legacy
single-expansion path, bounded-merge/hashed-visited exactness, per-query
state independent of the corpus size, and the blocked gather kernels."""
import numpy as np
import pytest
from _hyp import given, settings, st

import jax
import jax.numpy as jnp

from repro.core.beam import beam_search_batch, visited_table_size
from repro.core.rfann import RNSGIndex
from repro.data.ann import make_attrs, make_vectors, selectivity_ranges
from repro.search import select_entry


@pytest.fixture(scope="module")
def small_index():
    n, d = 600, 16
    vecs = make_vectors(n, d, seed=0)
    attrs = make_attrs(n, seed=0)
    return vecs, attrs, RNSGIndex.build(vecs, attrs, m=16, ef_spatial=16,
                                        ef_attribute=24)


def _run(ix, qv, lo, hi, *, k=10, ef=64, bw=1, use_kernel=False):
    g = ix.g
    loj = jnp.asarray(np.asarray(lo, np.int32))
    hij = jnp.asarray(np.asarray(hi, np.int32))
    entry = select_entry(jnp.asarray(g.rmq), jnp.asarray(g.dist_c),
                         loj, hij, g.n)
    return beam_search_batch(jnp.asarray(g.vecs), jnp.asarray(g.nbrs),
                             jnp.asarray(qv), loj, hij, entry, k=k, ef=ef,
                             beam_width=bw, use_kernel=use_kernel)


def _interval_mix(n, nq, rng):
    """Narrow / wide / empty / sub-ef intervals in one batch."""
    lo = rng.integers(0, n, nq).astype(np.int64)
    width = np.concatenate([
        rng.integers(1, 8, nq // 4),              # narrow
        rng.integers(n // 2, n, nq // 4),         # wide
        np.full(nq // 4, -3),                     # empty (lo > hi)
        rng.integers(8, 60, nq - 3 * (nq // 4)),  # sub-ef
    ])
    hi = np.clip(lo + width[:nq], -1, n - 1)
    return lo, hi


def _id_sets_equal(a, b):
    assert a.shape == b.shape
    for q in range(a.shape[0]):
        sa = set(a[q][a[q] >= 0].tolist())
        sb = set(b[q][b[q] >= 0].tolist())
        if sa != sb:
            return False, (q, sorted(sa), sorted(sb))
    return True, None


# --------------------------------------------------------------- seeded sweep
@pytest.mark.parametrize("bw", [2, 3, 4, 8])
@pytest.mark.parametrize("ef_mode", ["exhaustive", "sub"])
def test_batched_matches_legacy(small_index, bw, ef_mode):
    """Bounded-merge + hashed-visited batched beam returns identical id sets
    to the beam_width=1 legacy beam across narrow/wide/empty/sub-ef
    intervals, in the two regimes where equality is *guaranteed* (not just
    empirical): ``ef >= n`` makes every interval exhaustive over its
    in-range component, and at ``ef=64`` any interval with at most ``ef``
    in-range nodes keeps the pool under-full, so nothing is ever evicted
    and both widths expand the full reachable set.  (A wide interval at
    sub-exhaustive ef may legitimately explore a different frontier — that
    is exactly why ``beam_width`` is part of the cache key.)"""
    vecs, attrs, ix = small_index
    n = ix.g.n
    nq = 24
    rng = np.random.default_rng(7 + bw)
    qv = make_vectors(nq, 16, seed=5)
    ef = n if ef_mode == "exhaustive" else 64
    lo, hi = _interval_mix(n, nq, rng)
    if ef_mode == "sub":                    # keep only guaranteed intervals
        hi = np.minimum(hi, lo + ef - 1)
    base = _run(ix, qv, lo, hi, ef=ef, bw=1)
    got = _run(ix, qv, lo, hi, ef=ef, bw=bw)
    ok, why = _id_sets_equal(np.asarray(base[0]), np.asarray(got[0]))
    assert ok, why
    # batched iterations ≈ expansions / B
    assert float(np.asarray(got[2]["hops"]).mean()) < \
        float(np.asarray(base[2]["hops"]).mean())


_PROP_IX = {}


def _prop_index(n=220, d=8):
    if "ix" not in _PROP_IX:                  # one build for every example
        vecs = make_vectors(n, d, seed=3)
        attrs = make_attrs(n, seed=3)
        _PROP_IX["ix"] = RNSGIndex.build(vecs, attrs, m=8, ef_spatial=8,
                                         ef_attribute=12)
    return _PROP_IX["ix"]


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 8), st.integers(0, 2**31 - 1))
def test_batched_matches_legacy_property(bw, seed):
    """Hypothesis sweep (exhaustive ef): any interval mix, any width."""
    n, d = 220, 8
    ix = _prop_index(n, d)
    rng = np.random.default_rng(seed)
    nq = 8
    qv = make_vectors(nq, d, seed=seed % 1000)
    lo, hi = _interval_mix(n, nq, rng)
    base = _run(ix, qv, lo, hi, k=5, ef=n, bw=1)
    got = _run(ix, qv, lo, hi, k=5, ef=n, bw=bw)
    ok, why = _id_sets_equal(np.asarray(base[0]), np.asarray(got[0]))
    assert ok, why


def test_batched_kernel_path_matches_jnp(small_index):
    """interpret-mode blocked gather/top-k kernels inside the batched beam
    reproduce the jnp gather path exactly."""
    vecs, attrs, ix = small_index
    n = ix.g.n
    nq = 12
    rng = np.random.default_rng(11)
    qv = make_vectors(nq, 16, seed=9)
    lo, hi = _interval_mix(n, nq, rng)
    a = _run(ix, qv, lo, hi, ef=48, bw=4, use_kernel=False)
    b = _run(ix, qv, lo, hi, ef=48, bw=4, use_kernel=True)
    assert np.array_equal(np.asarray(a[0]), np.asarray(b[0]))
    assert np.allclose(np.asarray(a[1]), np.asarray(b[1]),
                       rtol=1e-4, atol=1e-4, equal_nan=True)


def test_beam_width_beyond_ef_is_clamped(small_index):
    """A width larger than the pool (e.g. --beam-width 128 at ef=8) clamps
    to ef instead of dying in a reshape deep inside the traced body."""
    vecs, attrs, ix = small_index
    n = ix.g.n
    nq = 6
    rng = np.random.default_rng(13)
    qv = make_vectors(nq, 16, seed=17)
    lo, hi = _interval_mix(n, nq, rng)
    explicit = _run(ix, qv, lo, hi, k=5, ef=8, bw=8)
    clamped = _run(ix, qv, lo, hi, k=5, ef=8, bw=16)    # clamps to 8
    assert np.array_equal(np.asarray(explicit[0]), np.asarray(clamped[0]))
    assert np.asarray(clamped[0]).shape == (nq, 5)


# ----------------------------------------------------- state is n-independent
def test_visited_state_independent_of_corpus_size():
    """Acceptance: the batched path carries no (Q, n+1) visited array — its
    hash table is sized by (ef, m) only.  Checked structurally: the traced
    jaxpr of the legacy path contains an (n+1)-extent bool array, the
    batched path's contains no (n+1)-extent value at all."""
    n, d, m, nq = 5000, 8, 12, 3
    vecs = jnp.zeros((n, d), jnp.float32)
    nbrs = jnp.zeros((n, m), jnp.int32)
    qv = jnp.zeros((nq, d), jnp.float32)
    lo = jnp.zeros((nq,), jnp.int32)
    hi = jnp.full((nq,), n - 1, jnp.int32)
    entry = jnp.zeros((nq,), jnp.int32)

    def trace(bw):
        return repr(jax.make_jaxpr(
            lambda *a: beam_search_batch(*a, k=5, ef=32, beam_width=bw))(
                vecs, nbrs, qv, lo, hi, entry))

    assert f"{n + 1}" in trace(1)           # legacy: (n+1,) visited bitmask
    assert f"{n + 1}" not in trace(4)       # batched: fixed-size hash table
    for ef, mm in ((16, 8), (64, 24), (128, 48)):
        s = visited_table_size(ef, mm)
        assert s & (s - 1) == 0 and 256 <= s <= (1 << 13)


# ------------------------------------------------------- substrate-level knob
def test_substrate_beam_width_parity(small_index):
    """RNSGIndex.search(beam_width=...) is exact for every plan at
    exhaustive ef, and per-width ndist calibration lands in the planner."""
    vecs, attrs, ix = small_index
    nq = 10
    qv = make_vectors(nq, 16, seed=21)
    ranges = selectivity_ranges(attrs, nq, 0.2, seed=4)
    n = ix.g.n
    base = ix.search(qv, ranges, k=8, ef=n, plan="graph")
    for plan in ("graph", "auto", "beam"):
        got = ix.search(qv, ranges, k=8, ef=n, plan=plan, beam_width=4)
        ok, why = _id_sets_equal(base.ids, got.ids)
        assert ok, (plan, why)
    # the auto plan's beam partitions calibrated the width-4 EMA
    assert 4 in ix.planner.cost._ndist_per_ef
