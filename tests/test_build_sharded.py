"""Sharded construction: bit-identity to the single-host build, and
restore-then-query parity through the sharded on-disk format.

The multi-shard cases run in subprocesses with
``--xla_force_host_platform_device_count`` (same pattern as
test_multidevice.py) so the fake-device flag never leaks into the suite.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.build_sharded import build_rnsg_sharded
from repro.core.construction import build_rnsg
from repro.core.rfann import RNSGIndex

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def _corpus(n, d=24, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, d)).astype(np.float32),
            rng.normal(size=n).astype(np.float32))


FIELDS = ("vecs", "attrs", "nbrs", "order", "centroid", "dist_c", "rmq")


def _assert_graph_equal(a, b):
    for f in FIELDS:
        assert np.array_equal(getattr(a, f), getattr(b, f)), f


@pytest.mark.parametrize("n", [700, 37, 1024])
def test_sharded_build_one_shard_bit_identical(n):
    v, a = _corpus(n)
    ref = build_rnsg(v, a, m=16, ef_spatial=16, ef_attribute=24)
    got = build_rnsg_sharded(v, a, n_shards=1, m=16, ef_spatial=16,
                             ef_attribute=24)
    _assert_graph_equal(ref, got)
    assert got.meta["shards"] == 1
    assert got.meta["knn"] == "exact"


def test_sharded_build_tiny_corpus_degenerate():
    # n=1 short-circuits to the host builder (k_eff < 1) but keeps the
    # shard annotation
    v, a = _corpus(1)
    g = build_rnsg_sharded(v, a, n_shards=1, m=8)
    assert g.nbrs.shape[0] == 1 and (g.nbrs < 1).all()
    assert g.meta["shards"] == 1


def test_sharded_build_rejects_bad_shard_count():
    v, a = _corpus(64)
    with pytest.raises(ValueError, match="exceeds"):
        build_rnsg_sharded(v, a, n_shards=9999)


@pytest.mark.slow
def test_sharded_build_multi_shard_bit_identical():
    _run("""
        import numpy as np
        from repro.core.build_sharded import build_rnsg_sharded
        from repro.core.construction import build_rnsg
        for n in (1500, 512):
            rng = np.random.default_rng(n)
            v = rng.normal(size=(n, 24)).astype(np.float32)
            a = rng.normal(size=n).astype(np.float32)
            ref = build_rnsg(v, a, m=16, ef_spatial=16, ef_attribute=24)
            for S in (1, 2, 8):
                g = build_rnsg_sharded(v, a, n_shards=S, m=16,
                                       ef_spatial=16, ef_attribute=24)
                for f in ("vecs", "attrs", "nbrs", "order", "centroid",
                          "dist_c", "rmq"):
                    assert np.array_equal(getattr(ref, f), getattr(g, f)), \\
                        (n, S, f)
                assert g.meta["shards"] == S
        print("OK")
    """)


def test_sharded_build_restore_query_parity(tmp_path):
    """Build sharded -> save (sharded dir) -> load -> every strategy
    returns the same ids/dists as the never-persisted single-host index."""
    v, a = _corpus(900)
    ref = RNSGIndex.build(v, a, m=16, ef_spatial=16, ef_attribute=24)
    idx = RNSGIndex(build_rnsg_sharded(v, a, n_shards=1, m=16,
                                       ef_spatial=16, ef_attribute=24))
    idx.save(str(tmp_path / "dir"), shards=4)
    got = RNSGIndex.load(str(tmp_path / "dir"))
    _assert_graph_equal(ref.g, got.g)

    rng = np.random.default_rng(5)
    q = rng.normal(size=(24, v.shape[1])).astype(np.float32)
    r = np.sort(rng.normal(size=(24, 2)).astype(np.float32), axis=1)
    for plan in ("graph", "scan", "auto", "beam"):
        want = ref.search(q, r, k=5, ef=32, plan=plan)
        have = got.search(q, r, k=5, ef=32, plan=plan)
        assert np.array_equal(want.ids, have.ids), plan
        assert np.allclose(want.dists, have.dists, equal_nan=True), plan
