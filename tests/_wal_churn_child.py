"""Subprocess child for the WAL kill-durability tests.

Runs a deterministic insert/delete churn through ``RFANNEngine`` with a
WAL attached, appending one line to an ack file after each mutation
returns (i.e. after the WAL acknowledged it).  The parent test SIGKILLs
this process mid-churn, recovers from the checkpoint + WAL tail, and
asserts the recovered live set equals ``live_after(m)`` for some prefix
``m >= acked`` — every acknowledged mutation survived a hard process
death.

The script generator lives here (not in the test) so parent and child
share one definition of the op sequence.
"""
import os
import sys

import numpy as np

N0, D = 48, 8
N_OPS = 600
BUILD = dict(m=8, ef_spatial=8, ef_attribute=8)


def corpus():
    rng = np.random.default_rng(7)
    return (rng.standard_normal((N0, D)).astype(np.float32),
            rng.standard_normal(N0).astype(np.float32))


def script():
    """Deterministic mutation sequence; deletes always target a live id."""
    rng = np.random.default_rng(11)
    live = list(range(N0))
    nxt = 1000
    ops = []
    for _ in range(N_OPS):
        if rng.random() < 0.25 and len(live) > 16:
            ops.append(("D", live.pop(int(rng.integers(len(live))))))
        else:
            ops.append(("I", nxt,
                        rng.standard_normal(D).astype(np.float32),
                        float(rng.standard_normal())))
            live.append(nxt)
            nxt += 1
    return ops


def live_after(m):
    """External-id live set after the first ``m`` script ops."""
    live = set(range(N0))
    for op in script()[:m]:
        if op[0] == "I":
            live.add(op[1])
        else:
            live.discard(op[1])
    return live


def main(wal_dir: str, ckpt_dir: str, ack_path: str) -> None:
    from repro.serving.engine import RFANNEngine
    from repro.streaming import StreamingRFANN

    vecs, attrs = corpus()
    idx = StreamingRFANN(vecs, attrs, max_delta=64, **BUILD)
    eng = RFANNEngine(idx, k=4, ef=16, wal_dir=wal_dir, index_path=ckpt_dir)
    # O_APPEND + one write per line: each ack hits the file before the
    # next mutation starts, so the parent's read is a true prefix count
    fd = os.open(ack_path, os.O_CREAT | os.O_WRONLY | os.O_APPEND, 0o644)
    os.write(fd, b"READY\n")
    for i, op in enumerate(script()):
        if op[0] == "I":
            eng.insert(op[2], op[3], ext_id=op[1])
        else:
            eng.delete(op[1])
        os.write(fd, f"{i + 1}\n".encode())
    os.write(fd, b"DONE\n")
    eng.close()
    idx.close()


if __name__ == "__main__":
    main(sys.argv[1], sys.argv[2], sys.argv[3])
