"""Substrate tests: checkpointing, fault tolerance, data pipeline, sharding
resolver, serving engine, distributed RFANN."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import CheckpointManager
from repro.data.ann import (ground_truth, make_attrs, make_vectors,
                            mixed_workload, recall_at_k, selectivity_ranges)
from repro.data.tokens import Prefetcher, SyntheticTokenStream, TokenStreamConfig
from repro.parallel.sharding import (DEFAULT_RULES, FSDP_RULES,
                                     spec_for_logical)
from repro.runtime.fault_tolerance import (Heartbeat, StragglerMonitor,
                                           int8_compress_decompress)
from repro.serving.distributed import DistributedRFANN
from repro.serving.engine import RFANNEngine
from repro.core.rfann import RNSGIndex


# ---------------------------------------------------------------- checkpoint
def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.standard_normal((8, 4)), jnp.float32),
                       "b": jnp.asarray(rng.standard_normal(4), jnp.bfloat16)},
            "opt": {"step": jnp.asarray(7, jnp.int32)}}


def test_checkpoint_roundtrip_and_gc(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2)
    st = _state()
    for step in (10, 20, 30, 40):
        ckpt.save(step, st, blocking=True, extra={"note": "x"})
    assert ckpt.all_steps() == [30, 40]          # gc kept last 2
    back = ckpt.restore(jax.tree.map(lambda a: jnp.zeros_like(a), st))
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(back)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert ckpt.meta()["step"] == 40


def test_checkpoint_async_and_atomic(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    st = _state(1)
    ckpt.save(5, st, blocking=False)
    ckpt.wait()
    assert ckpt.latest_step() == 5
    # a stale tmp file never shadows a real checkpoint
    (tmp_path / "tmp.99.npz").write_bytes(b"garbage")
    assert ckpt.latest_step() == 5


def test_elastic_resume_resharding(tmp_path):
    """Checkpoint saved (conceptually) on mesh A restores onto 'mesh' B —
    arrays are stored unsharded, so only the device_put differs."""
    ckpt = CheckpointManager(str(tmp_path))
    st = _state(2)
    ckpt.save(1, st, blocking=True)
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = jax.tree.map(lambda a: NamedSharding(mesh, P()), st)
    back = ckpt.restore(st, shardings=sh)
    assert back["params"]["w"].sharding == NamedSharding(mesh, P())


def test_train_resume_equivalence(tmp_path):
    from repro.launch.train import main as train_main
    base = ["--arch", "mamba2-780m", "--batch", "2", "--seq", "32",
            "--log-every", "1000"]
    _, full = train_main(base + ["--steps", "8"])
    d = str(tmp_path / "ck")
    train_main(base + ["--steps", "4", "--ckpt-dir", d, "--ckpt-every", "100"])
    _, resumed = train_main(base + ["--steps", "8", "--ckpt-dir", d, "--resume"])
    # restart-from-checkpoint must replay the exact loss trajectory
    assert np.allclose(full[4:], resumed, rtol=1e-4), (full, resumed)


# ---------------------------------------------------------------- fault tolerance
def test_straggler_monitor_flags_and_evicts():
    mon = StragglerMonitor(n_hosts=4, evict_after=3)
    out = {}
    for _ in range(6):
        t = np.asarray([1.0, 1.0, 1.0, 3.5])
        out = mon.record(t)
    assert out["stragglers"] == [3]
    assert out["evict"] == [3]


def test_straggler_monitor_recovers():
    mon = StragglerMonitor(n_hosts=4, evict_after=10)
    for _ in range(2):
        mon.record(np.asarray([1.0, 1.0, 1.0, 3.5]))
    for _ in range(8):          # EMA decays back under the threshold
        out = mon.record(np.asarray([1.0, 1.0, 1.0, 1.0]))
    assert mon.flags[3] == 0 and out["evict"] == []


def test_heartbeat_detects_dead_host():
    hb = Heartbeat(3, timeout=1.0)
    now = time.monotonic()
    hb.beat(0, now)
    hb.beat(1, now)
    hb.beat(2, now - 5.0)
    assert hb.dead_hosts(now) == [2]


def test_int8_compression_error_bound():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((256, 64)), jnp.float32)
    gq = int8_compress_decompress(g)
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.max(jnp.abs(gq - g))) <= scale * 0.5 + 1e-6


# ---------------------------------------------------------------- data pipeline
def test_token_stream_determinism_and_host_sharding():
    c = dict(vocab_size=97, seq_len=16, global_batch=8)
    s0 = SyntheticTokenStream(TokenStreamConfig(**c, n_hosts=2, host_id=0))
    s0b = SyntheticTokenStream(TokenStreamConfig(**c, n_hosts=2, host_id=0))
    s1 = SyntheticTokenStream(TokenStreamConfig(**c, n_hosts=2, host_id=1))
    a, b = s0.batch_at(5), s0b.batch_at(5)
    assert np.array_equal(a["tokens"], b["tokens"])           # replayable
    assert not np.array_equal(a["tokens"], s1.batch_at(5)["tokens"])
    assert a["tokens"].shape == (4, 16)                        # host shard
    assert np.array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_prefetcher_preserves_order():
    it = iter([{"i": np.asarray(i)} for i in range(10)])
    got = [int(b["i"]) for b in Prefetcher(it, depth=3)]
    assert got == list(range(10))


# ---------------------------------------------------------------- resolver
def test_resolver_divisibility_and_conflicts():
    from repro.parallel.sharding import abstract_mesh
    mesh = abstract_mesh((4, 2), ("data", "model"))
    # divisible both dims
    assert spec_for_logical(("fsdp", "tp"), (8, 6), mesh) == \
        jax.sharding.PartitionSpec("data", "model")
    # dim not divisible -> dropped
    assert spec_for_logical(("fsdp", "tp"), (7, 6), mesh)[0] is None
    # same mesh axis never used twice in one tensor
    spec = spec_for_logical(("expert", "fsdp", "tp"), (2, 8, 6), mesh)
    flat = [a for part in spec if part for a in
            (part if isinstance(part, tuple) else (part,))]
    assert len(flat) == len(set(flat))
    # batch over (pod, data) prefix logic
    mesh3 = abstract_mesh((2, 2, 2), ("pod", "data", "model"))
    assert spec_for_logical(("batch",), (4,), mesh3) == \
        jax.sharding.PartitionSpec(("pod", "data"))
    # FSDP strategy: batch spreads over (data, model) when pod doesn't divide
    assert spec_for_logical(("batch",), (4,), mesh3, FSDP_RULES) == \
        jax.sharding.PartitionSpec(("data", "model"))


# ---------------------------------------------------------------- distributed RFANN
def test_distributed_rfann_matches_ground_truth():
    n, d, nq, k = 2048, 16, 40, 10
    vecs = make_vectors(n, d, seed=0)
    attrs = make_attrs(n, seed=0)
    dist = DistributedRFANN(vecs, attrs, n_shards=4, m=16, ef_spatial=16,
                            ef_attribute=24)
    qv = make_vectors(nq, d, seed=9)
    ranges, _ = mixed_workload(attrs, nq, seed=2, levels=4)
    ids, dd = dist.search(qv, ranges, k=k, ef=96)
    order = np.argsort(attrs, kind="stable")
    gt_r, _ = ground_truth(vecs[order], attrs[order], qv, ranges, k)
    gt = np.where(gt_r >= 0, order[np.maximum(gt_r, 0)], -1)
    assert recall_at_k(ids, gt) > 0.95


def test_distributed_single_shard_range_equals_shard_search():
    """A range inside one shard: heredity ⇒ the merge equals that shard alone."""
    n, d = 1024, 8
    vecs = make_vectors(n, d, seed=1)
    attrs = np.sort(make_attrs(n, seed=1))
    dist = DistributedRFANN(vecs, attrs, n_shards=4, m=16, ef_spatial=16,
                            ef_attribute=24)
    lo, hi = dist.shard_span[1]          # entirely inside shard 1
    qv = make_vectors(6, d, seed=3)
    rg = np.tile(np.asarray([[lo, hi]], np.float32), (6, 1))
    ids, dd = dist.search(qv, rg, k=5, ef=64)
    assert (ids >= 0).all()
    for q in range(6):
        for i in ids[q]:
            assert lo <= attrs[i] <= hi


# ---------------------------------------------------------------- engine
def test_engine_dynamic_batching():
    n, d = 1024, 16
    vecs = make_vectors(n, d, seed=0)
    attrs = make_attrs(n, seed=0)
    idx = RNSGIndex.build(vecs, attrs, m=16, ef_spatial=16, ef_attribute=24)
    eng = RFANNEngine(idx, k=5, ef=32, max_batch=16, max_wait_ms=5)
    qv = make_vectors(32, d, seed=2)
    rgs = selectivity_ranges(attrs, 32, 0.5, seed=0)
    futs = [eng.submit(qv[i], rgs[i]) for i in range(32)]
    res = [f.result(timeout=60) for f in futs]
    eng.close()
    assert len(res) == 32 and all(r[0].shape == (5,) for r in res)
    assert eng.stats.summary()["mean_batch"] > 1.0
