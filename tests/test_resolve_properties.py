"""Property-based tests for the resolve stage (``repro.search.resolve``).

The resolve contract: a raw attribute range ``[a_l, a_r]`` over a sorted
attribute array maps to the inclusive rank interval covering exactly the
in-range positions (``lo > hi`` = empty), and a shard clip of a *global*
interval covers exactly the in-range positions of the shard's slice
(Theorem 4.7 heredity at the resolve layer).

Hypothesis drives random sorted attribute arrays (with heavy duplicate
pressure) × random ranges against a brute-force mask oracle; a deterministic
seeded sweep covers the same ground when hypothesis is not installed
(``tests/_hyp.py`` turns the ``@given`` tests into skips)."""
import numpy as np
from _hyp import given, settings, st

from repro.search.resolve import (clip_interval, rank_interval,
                                  rank_interval_jax)


# --------------------------------------------------------------- the oracle
def oracle_positions(attrs_sorted: np.ndarray, a_l: float, a_r: float):
    """Brute force: the set of positions whose attribute lies in [a_l, a_r]."""
    mask = (attrs_sorted >= a_l) & (attrs_sorted <= a_r)
    return np.flatnonzero(mask)


def interval_positions(lo: int, hi: int):
    return np.arange(lo, hi + 1) if lo <= hi else np.zeros(0, np.int64)


def check_against_oracle(attrs_sorted: np.ndarray, ranges: np.ndarray):
    """rank_interval must cover exactly the oracle's in-range positions —
    including empty, single-point, full-span, and duplicate-heavy inputs."""
    lo, hi = rank_interval(attrs_sorted, ranges)
    for q in range(len(ranges)):
        want = oracle_positions(attrs_sorted, ranges[q, 0], ranges[q, 1])
        got = interval_positions(int(lo[q]), int(hi[q]))
        assert np.array_equal(got, want), (
            q, ranges[q].tolist(), got.tolist(), want.tolist())
    return lo, hi


# ---------------------------------------------------------------- strategies
# Integer-valued attributes keep float32 exact, so the oracle comparison is
# never about rounding; duplicates are frequent by construction (small value
# universe), which is exactly the edge searchsorted sides must get right.
attr_arrays = st.lists(st.integers(min_value=-40, max_value=40),
                       min_size=1, max_size=64).map(
    lambda xs: np.sort(np.asarray(xs, np.float32)))

range_pairs = st.tuples(st.integers(min_value=-45, max_value=45),
                        st.integers(min_value=-45, max_value=45))


@settings(max_examples=60, deadline=None)
@given(attr_arrays, st.lists(range_pairs, min_size=1, max_size=12))
def test_rank_interval_matches_oracle(attrs_sorted, pairs):
    """Random sorted arrays × random ranges (inverted pairs included — an
    inverted attribute range must resolve to an empty rank interval)."""
    ranges = np.asarray(pairs, np.float32)
    check_against_oracle(attrs_sorted, ranges)


@settings(max_examples=40, deadline=None)
@given(attr_arrays, st.integers(min_value=0, max_value=10_000))
def test_rank_interval_degenerate_rows(attrs_sorted, seed):
    """The rows the paper's API must handle: empty (between two adjacent
    values), single point, full span, everything, and a duplicate value."""
    rng = np.random.default_rng(seed)
    i = int(rng.integers(len(attrs_sorted)))
    v = float(attrs_sorted[i])
    ranges = np.asarray([
        [v + 0.25, v + 0.25],                        # between values: empty
        [v, v],                                      # point (all duplicates)
        [attrs_sorted[0], attrs_sorted[-1]],         # full span
        [attrs_sorted[0] - 10, attrs_sorted[-1] + 10],   # superset
        [attrs_sorted[-1] + 1, attrs_sorted[-1] + 2],    # beyond the end
        [attrs_sorted[0] - 2, attrs_sorted[0] - 1],      # before the start
    ], np.float32)
    lo, hi = check_against_oracle(attrs_sorted, ranges)
    assert lo[2] == 0 and hi[2] == len(attrs_sorted) - 1      # full span
    assert lo[4] > hi[4] and lo[5] > hi[5]                    # both empty
    # the point row covers every duplicate of v, not just position i
    assert np.array_equal(interval_positions(int(lo[1]), int(hi[1])),
                          np.flatnonzero(attrs_sorted == v))


@settings(max_examples=40, deadline=None)
@given(attr_arrays, st.lists(range_pairs, min_size=1, max_size=8),
       st.integers(min_value=1, max_value=8))
def test_clip_interval_matches_per_shard_oracle(attrs_sorted, pairs, n_shards):
    """Heredity at the resolve layer: clipping the *global* rank interval to
    a contiguous shard covers exactly the shard-local oracle positions —
    i.e. ``clip_interval`` equals a per-shard ``searchsorted``."""
    n = len(attrs_sorted)
    n_shards = min(n_shards, n)
    per = n // n_shards
    if per == 0:
        return
    ranges = np.asarray(pairs, np.float32)
    lo, hi = rank_interval(attrs_sorted, ranges)
    for s in range(n_shards):
        rank0 = s * per
        shard = attrs_sorted[rank0:rank0 + per]
        slo, shi = clip_interval(lo, hi, rank0, per)
        for q in range(len(ranges)):
            want = oracle_positions(shard, ranges[q, 0], ranges[q, 1])
            got = interval_positions(int(slo[q]), int(shi[q]))
            assert np.array_equal(got, want), (s, q, got, want)


# ------------------------------------------------- no-hypothesis fallback
def test_rank_interval_oracle_seeded_sweep():
    """Deterministic sweep of the same properties (runs even when hypothesis
    is absent and the ``@given`` tests skip): duplicate-heavy sorted arrays,
    random + degenerate ranges, host/jax lockstep, shard-clip heredity."""
    rng = np.random.default_rng(0)
    for trial in range(25):
        n = int(rng.integers(1, 96))
        attrs = np.sort(rng.integers(-30, 30, n).astype(np.float32))
        pairs = rng.integers(-35, 35, (10, 2)).astype(np.float32)
        s = np.sort(attrs)
        ranges = np.concatenate([pairs, np.asarray([
            [s[0], s[-1]],                       # full span
            [s[n // 2], s[n // 2]],              # point / duplicates
            [s[-1] + 1, s[-1] + 2],              # empty past the end
        ], np.float32)])
        lo, hi = check_against_oracle(attrs, ranges)
        # traced resolve agrees with the host resolve bit-for-bit
        lo_j, hi_j = rank_interval_jax(attrs, ranges)
        assert np.array_equal(np.asarray(lo_j), lo)
        assert np.array_equal(np.asarray(hi_j), hi)
        # shard-clip heredity on a random shard count dividing n
        for n_shards in (1, 2, 4):
            per = n // n_shards
            if per == 0:
                continue
            for shard in range(n_shards):
                rank0 = shard * per
                slo, shi = clip_interval(lo, hi, rank0, per)
                sl = attrs[rank0:rank0 + per]
                for q in range(len(ranges)):
                    want = oracle_positions(sl, ranges[q, 0], ranges[q, 1])
                    got = interval_positions(int(slo[q]), int(shi[q]))
                    assert np.array_equal(got, want), (trial, shard, q)
