"""Streaming index under the serving engine: per-segment cache epochs,
queries racing compaction, the install_quantized cache-epoch fix, and
the WAL durability harness (SIGKILL mid-churn, SIGTERM graceful drain)."""
import os
import threading

import numpy as np
import pytest

from repro.core.rfann import RNSGIndex
from repro.search import SearchCache
from repro.search.cache import CacheEntry
from repro.serving.engine import RFANNEngine
from repro.streaming import BASE_NS, StreamingRFANN


def _entry(k=4):
    return CacheEntry(ids=np.arange(k, dtype=np.int32),
                      dists=np.zeros(k, np.float32), stats={})


# ------------------------------------------------------- per-segment epochs
def test_invalidate_segment_scopes_to_namespace():
    c = SearchCache(max_bytes=1 << 20)
    c.store(("base", 1), _entry())
    c.store(("other", 1), _entry())
    c.invalidate_segment("base")
    assert ("base", 1) not in c._d and ("other", 1) in c._d
    assert c.seg_invalidations == 1
    # global invalidate still drops everything
    c.store(("base", 2), _entry())
    c.invalidate()
    assert len(c) == 0


def test_segment_epoch_fences_late_stores():
    c = SearchCache(max_bytes=1 << 20)
    ep = c.epoch_for("base")
    c.invalidate_segment("base")                # concurrent compaction
    c.store(("base", 1), _entry(), epoch=ep)    # late store: dropped
    assert ("base", 1) not in c._d
    c.store(("base", 2), _entry(), epoch=c.epoch_for("base"))
    assert ("base", 2) in c._d
    # the *global* epoch component still fences per-segment stores
    ep = c.epoch_for("base")
    c.invalidate()
    c.store(("base", 3), _entry(), epoch=ep)
    assert ("base", 3) not in c._d
    # legacy int epochs (pre-segment callers) keep working
    c.store(("x", 1), _entry(), epoch=c.epoch)
    assert ("x", 1) in c._d
    c.store(("x", 2), _entry(), epoch=c.epoch - 1)
    assert ("x", 2) not in c._d


def test_engine_swap_index_segment_scoped():
    rng = np.random.default_rng(0)
    idx = RNSGIndex.build(rng.standard_normal((96, 8)).astype(np.float32),
                          rng.random(96).astype(np.float32), m=8)
    eng = RFANNEngine(idx, cache_bytes=1 << 20, max_wait_ms=0.5)
    try:
        eng.cache.store(("base", 1), _entry())
        eng.cache.store(("other", 1), _entry())
        eng.swap_index(idx, segment="base")     # self-swap, one segment
        assert ("base", 1) not in eng.cache._d
        assert ("other", 1) in eng.cache._d
        eng.swap_index(idx)                     # full swap: everything cold
        assert len(eng.cache._d) == 0
    finally:
        eng.close()


# ------------------------------------ install_quantized must go cache-cold
@pytest.mark.parametrize("precision", ["int8", "bf16"])
def test_install_quantized_after_cache_bumps_epoch_local(precision):
    rng = np.random.default_rng(1)
    vecs = rng.standard_normal((160, 8)).astype(np.float32)
    attrs = rng.random(160).astype(np.float32)
    idx = RNSGIndex.build(vecs, attrs, m=8)
    cache = SearchCache(max_bytes=1 << 20)
    idx.install_cache(cache)
    qv = rng.standard_normal((2, 8)).astype(np.float32)
    ar = np.asarray([[0.0, 1.0]] * 2, np.float32)
    idx.search(qv, ar, k=5, plan="scan", precision=precision)
    assert len(cache) == 2
    idx.search(qv, ar, k=5, plan="scan", precision=precision)
    assert cache.hits == 2
    idx.install_quantized(precision)    # rebuild: rows must not survive
    assert len(cache) == 0
    ns = idx.substrate.cache_ns
    assert cache.epoch_for(ns)[1] >= 1
    res = idx.search(qv, ar, k=5, plan="scan", precision=precision)
    assert cache.hits == 2              # cold again: no new hits
    assert (np.asarray(res.ids) >= 0).any()


def test_install_quantized_after_cache_bumps_epoch_mesh():
    import jax
    from jax.sharding import Mesh
    from repro.serving.distributed import DistributedRFANN
    rng = np.random.default_rng(2)
    vecs = rng.standard_normal((128, 8)).astype(np.float32)
    attrs = rng.random(128).astype(np.float32)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    dist = DistributedRFANN(vecs, attrs, n_shards=1, mesh=mesh, m=8)
    cache = SearchCache(max_bytes=1 << 20)
    dist.install_cache(cache)
    qv = rng.standard_normal((2, 8)).astype(np.float32)
    ar = np.asarray([[0.0, 1.0]] * 2, np.float32)
    dist.search(qv, ar, k=5, plan="graph", ef=128, precision="int8")
    assert len(cache) == 2
    dist.search(qv, ar, k=5, plan="graph", ef=128, precision="int8")
    assert cache.hits == 2
    dist.install_quantized("int8")
    assert len(cache) == 0
    assert cache.epoch_for("mesh")[1] >= 1


# --------------------------------------------- queries racing compactions
def test_queries_racing_compaction_through_engine():
    """N query threads × M compactions through ``RFANNEngine``: no stale
    cache rows (a deleted id never reappears once its delete returned), no
    tombstoned ids ever, and the obs counters total exactly."""
    rng = np.random.default_rng(3)
    n0, d, k = 256, 8, 8
    vecs = rng.standard_normal((n0, d)).astype(np.float32)
    attrs = rng.random(n0).astype(np.float32)
    s = StreamingRFANN(vecs, attrs, m=8, ef_spatial=16, ef_attribute=24,
                       max_delta=10**9)
    eng = RFANNEngine(s, k=k, ef=64, plan="scan", max_wait_ms=0.5,
                      cache_bytes=1 << 20)
    n_threads, n_compactions, reqs_per_thread = 4, 3, 30
    deleted: set = set()
    del_lock = threading.Lock()
    errors: list = []

    def hammer():
        r = np.random.default_rng(threading.get_ident() % 2**31)
        try:
            for _ in range(reqs_per_thread):
                q = r.standard_normal(d).astype(np.float32)
                a, b = np.sort(r.random(2).astype(np.float32))
                with del_lock:
                    dead_before = set(deleted)
                ids = eng.submit(q, (a, b)).result(timeout=60).ids
                bad = set(int(i) for i in ids if i >= 0) & dead_before
                if bad:
                    errors.append(f"tombstoned ids served: {bad}")
        except Exception as e:          # surface in the main thread
            errors.append(repr(e))

    threads = [threading.Thread(target=hammer) for _ in range(n_threads)]
    for t in threads:
        t.start()
    n_ins = n_del = 0
    try:
        for _ in range(n_compactions):
            for _ in range(12):
                eng.insert(rng.standard_normal(d).astype(np.float32),
                           float(rng.random()))
                n_ins += 1
            for _ in range(6):
                live = [i for i in list(eng.index._id_loc)
                        if i not in deleted]
                victim = int(rng.choice(live))
                with del_lock:
                    eng.delete(victim)
                    deleted.add(victim)
                n_del += 1
            assert s.compact(wait=True)
    finally:
        for t in threads:
            t.join(timeout=120)
        eng.close()
        s.close()
    assert not errors, errors
    assert s.compactions == n_compactions
    snap = eng.metrics()
    assert snap["counters"]["stream_compactions_total"] == n_compactions
    assert snap["counters"]["stream_inserts_total"] == n_ins
    assert snap["counters"]["stream_deletes_total"] == n_del
    assert (snap["counters"]["engine_requests_total"]
            == n_threads * reqs_per_thread)
    assert snap["streaming"]["compactions"] == n_compactions
    # the post-compaction live set is exactly base-live ∪ residual delta
    lv, la, li = s.live_items()
    assert len(set(li.tolist())) == len(li)
    assert not (set(li.tolist()) & deleted)


def test_repeat_query_sees_delete_immediately():
    """The stale-cache check in its sharpest form: a cached query row whose
    result contains X must go cold the moment X is deleted (per-segment
    epoch bump), not only at the next compaction."""
    rng = np.random.default_rng(4)
    n0, d, k = 192, 8, 5
    vecs = rng.standard_normal((n0, d)).astype(np.float32)
    attrs = rng.random(n0).astype(np.float32)
    s = StreamingRFANN(vecs, attrs, m=8, max_delta=10**9)
    eng = RFANNEngine(s, k=k, ef=64, plan="scan", max_wait_ms=0.5,
                      cache_bytes=1 << 20)
    try:
        q = rng.standard_normal(d).astype(np.float32)
        rgq = (0.0, 1.0)
        ids0 = eng.submit(q, rgq).result(timeout=60).ids
        victim = int(ids0[0])
        eng.submit(q, rgq).result(timeout=60)       # now cached
        eng.delete(victim)
        ids1 = eng.submit(q, rgq).result(timeout=60).ids
        assert victim not in set(int(i) for i in ids1)
        # and after compaction the answer is still victim-free
        assert s.compact(wait=True)
        ids2 = eng.submit(q, rgq).result(timeout=60).ids
        assert victim not in set(int(i) for i in ids2)
        assert set(int(i) for i in ids2 if i >= 0) \
            == set(int(i) for i in ids1 if i >= 0)
    finally:
        eng.close()
        s.close()


def test_engine_rejects_invalid_compaction_policy():
    """Regression: a zero/negative policy used to be accepted silently and
    wedge ``_maybe_compact`` into a compact-per-op loop."""
    rng = np.random.default_rng(6)
    s = StreamingRFANN(rng.standard_normal((32, 8)).astype(np.float32),
                       rng.random(32).astype(np.float32), m=8)
    with pytest.raises(ValueError, match=r"max_delta=0"):
        RFANNEngine(s, max_delta=0)
    with pytest.raises(ValueError, match=r"compact_every=-2"):
        s.set_compaction_policy(compact_every=-2)


def test_engine_forwards_compaction_policy():
    rng = np.random.default_rng(6)
    s = StreamingRFANN(rng.standard_normal((96, 8)).astype(np.float32),
                       rng.random(96).astype(np.float32), m=8,
                       max_delta=10**9)
    eng = RFANNEngine(s, max_wait_ms=0.5, max_delta=7, compact_every=123)
    try:
        assert s.max_delta == 7 and s.compact_every == 123
        for _ in range(7):      # hits max_delta: background compaction
            eng.insert(rng.standard_normal(8).astype(np.float32),
                       float(rng.random()))
        s.close()               # join the worker
        assert s.compactions == 1
        assert s.stats()["n_delta"] == 0
    finally:
        eng.close()
        s.close()


# ------------------------------------------------------------ durability
def _child_env():
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


def test_engine_wal_churn_survives_sigkill(tmp_path):
    """Hard process death mid-churn (SIGKILL — no atexit, no flush): the
    restarted index must serve exactly the acknowledged live set.  The
    child acks each mutation to a side file only *after* the engine call
    returned, so every acked op was WAL-logged first; recovery must
    reproduce ``live_after(m)`` for some prefix ``m >= acked``."""
    import importlib.util
    import subprocess
    import sys
    import time

    child_py = os.path.join(os.path.dirname(__file__),
                            "_wal_churn_child.py")
    spec = importlib.util.spec_from_file_location("_wal_churn_child",
                                                  child_py)
    child = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(child)

    wal, ckpt, ack = tmp_path / "wal", tmp_path / "ckpt", tmp_path / "ack"
    proc = subprocess.Popen(
        [sys.executable, child_py, str(wal), str(ckpt), str(ack)],
        env=_child_env(), stdout=subprocess.PIPE, stderr=subprocess.STDOUT)
    target, acked = 120, 0
    deadline = time.time() + 240
    try:
        while time.time() < deadline:
            if ack.exists():
                ints = [int(x) for x in ack.read_text().split()
                        if x.isdigit()]
                acked = ints[-1] if ints else 0
                if acked >= target:
                    break
            if proc.poll() is not None:
                break
            time.sleep(0.05)
    finally:
        proc.kill()                             # SIGKILL mid-churn
        out = proc.communicate(timeout=60)[0]
    assert acked >= target, (
        f"child only acked {acked} ops before timeout/exit; output:\n"
        f"{out.decode(errors='replace')[-2000:]}")

    from repro.streaming import StreamingRFANN
    rec = StreamingRFANN.recover(ckpt, wal, attach=False)
    got = set(rec._id_loc)
    n = len(child.script())
    match = next((m for m in range(acked, n + 1)
                  if got == child.live_after(m)), None)
    assert match is not None, (
        f"recovered live set ({len(got)} ids) matches no prefix >= "
        f"acked={acked} — acknowledged mutations were lost")
    # recovered index serves: search over the full attr range returns
    # only live external ids
    q = np.zeros((1, 8), np.float32)
    res = rec.search(q, np.array([[-10.0, 10.0]], np.float32), k=5)
    assert all(int(i) in got for i in res.ids[0] if i >= 0)


def test_serve_sigterm_drains_and_restarts(tmp_path):
    """SIGTERM on the serve launcher: graceful drain (PreemptionHandler),
    WAL sealed, index + calibration checkpointed, exit 0 — then a restart
    restores from the checkpoint and replays the WAL with zero
    acknowledged mutations lost."""
    import subprocess
    import sys
    import time

    wal, ckpt = tmp_path / "wal", tmp_path / "ckpt"
    argv = [sys.executable, "-m", "repro.launch.serve", "--mode", "rfann",
            "--n", "400", "--dim", "8", "--m", "8", "--max-delta", "64",
            "--requests", "100000", "--rate", "40",
            "--wal-dir", str(wal), "--index-path", str(ckpt)]
    proc = subprocess.Popen(argv, env=_child_env(), stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT)
    deadline = time.time() + 240
    try:
        # wait until the engine is up (baseline checkpoint committed and
        # the WAL has started taking appends), then preempt it
        while time.time() < deadline:
            if (ckpt / "manifest.json").exists() and wal.is_dir() \
                    and any(wal.iterdir()):
                break
            assert proc.poll() is None, "serve exited before starting"
            time.sleep(0.2)
        time.sleep(3.0)                         # let churn land in the WAL
        proc.terminate()                        # SIGTERM
        out = proc.communicate(timeout=180)[0].decode(errors="replace")
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.communicate()
    assert proc.returncode == 0, f"serve did not exit cleanly:\n{out[-2000:]}"
    assert "SIGTERM: draining" in out
    assert "index persisted" in out

    # restart: restores + replays, serves a short run to completion
    argv2 = argv[:argv.index("--requests")] + [
        "--requests", "16", "--wal-dir", str(wal),
        "--index-path", str(ckpt)]
    out2 = subprocess.run(argv2, env=_child_env(), stdout=subprocess.PIPE,
                          stderr=subprocess.STDOUT, timeout=240,
                          check=True).stdout.decode(errors="replace")
    assert "restored index" in out2
    assert "replayed" in out2
