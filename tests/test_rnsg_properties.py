"""Property tests for the paper's theorems (hypothesis + exact oracles)."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.construction import build_rnsg
from repro.core.exact import (exact_mrng, exact_rrng, greedy_monotonic_reachable,
                              induced, pair_dists, strongly_connected)
from repro.core.pruning import rrng_prune_np


def _points(n, d, seed):
    rng = np.random.default_rng(seed)
    v = rng.standard_normal((n, d)).astype(np.float32)
    # ids are attribute ranks: vectors independent of attrs ⇒ any order works
    return v


pointsets = st.builds(_points,
                      st.integers(min_value=4, max_value=26),
                      st.integers(min_value=2, max_value=6),
                      st.integers(min_value=0, max_value=10_000))


@settings(max_examples=20, deadline=None)
@given(pointsets)
def test_thm_3_3_monotonic_searchability(vecs):
    """Every pair of RRNG nodes is connected by a strictly-decreasing greedy walk."""
    adj = exact_rrng(vecs)
    n = len(vecs)
    rng = np.random.default_rng(0)
    pairs = rng.integers(0, n, (min(20, n * n), 2))
    for s, t in pairs:
        if s == t:
            continue
        assert greedy_monotonic_reachable(vecs, adj, int(s), int(t)), (s, t)


@settings(max_examples=20, deadline=None)
@given(pointsets, st.integers(0, 1000))
def test_thm_3_5_rrng_heredity(vecs, seed):
    """Induced subgraph of the RRNG == RRNG rebuilt on the interval."""
    n = len(vecs)
    rng = np.random.default_rng(seed)
    lo = int(rng.integers(0, n - 1))
    hi = int(rng.integers(lo + 1, n))
    adj = exact_rrng(vecs)
    sub = induced(adj, lo, hi - 1)
    rebuilt = exact_rrng(vecs[lo:hi])
    assert np.array_equal(sub, rebuilt)


def test_mrng_lacks_heredity():
    """Fig.1b: there exist pointsets where the induced MRNG ≠ rebuilt MRNG."""
    for seed in range(200):
        vecs = _points(12, 2, seed)
        adj = exact_mrng(vecs)
        lo, hi = 2, 9
        sub = induced(adj, lo, hi)
        rebuilt = exact_mrng(vecs[lo:hi + 1])
        if not np.array_equal(sub, rebuilt):
            return  # counterexample found — MRNG is not hereditary
    pytest.fail("no MRNG heredity counterexample found in 200 seeds")


@settings(max_examples=15, deadline=None)
@given(pointsets)
def test_thm_4_3_alg1_full_candidates_equals_rrng(vecs):
    """Algorithm 1 with C = D and m = ∞ reproduces the exact RRNG."""
    n = len(vecs)
    adj = exact_rrng(vecs)
    for x in range(n):
        got = set(rrng_prune_np(x, np.arange(n), vecs, m=10 ** 9))
        # Definition 3.1 prunes via *witness edges from the lower endpoint*;
        # Algorithm 1's per-node sets reproduce each node's RRNG neighborhood.
        want = set(np.flatnonzero(adj[x]).tolist())
        assert got == want, (x, got, want)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 100))
def test_thm_4_6_rnsg_induced_strong_connectivity(seed):
    """RNSG + every interval-induced subgraph stays (strongly) connected."""
    rng = np.random.default_rng(seed)
    n = 256
    vecs = rng.standard_normal((n, 8)).astype(np.float32)
    attrs = rng.random(n).astype(np.float32) + np.arange(n) * 1e-9
    g = build_rnsg(vecs, attrs, m=8, ef_spatial=8, ef_attribute=8)
    for _ in range(5):
        lo = int(rng.integers(0, n - 2))
        hi = int(rng.integers(lo + 1, n))
        sub_n = hi - lo
        adj = np.zeros((sub_n, sub_n), bool)
        for i in range(sub_n):
            for j in g.nbrs[lo + i]:
                if lo <= j < hi:
                    adj[i, j - lo] = True
        # undirected reachability over the bidirectional chain guarantee
        adj = adj | adj.T
        assert strongly_connected(adj), (lo, hi)


def test_thm_4_7_rnsg_heredity_with_induced_knn():
    """RNSG built on V_I with the induced KNN graph == induced RNSG subgraph."""
    rng = np.random.default_rng(3)
    n, d, k = 200, 6, 12
    vecs = rng.standard_normal((n, d)).astype(np.float32)
    attrs = np.arange(n).astype(np.float32)
    from repro.index.knn import exact_knn
    _, knn = exact_knn(vecs, k)
    ef_attr, m = 10, 8
    g = build_rnsg(vecs, attrs, m=m, ef_attribute=ef_attr, knn_ids=knn)
    lo, hi = 40, 160   # interval [lo, hi)
    # induced KNN graph (global neighbors restricted to the interval)
    ind = np.full((hi - lo, k), -1, np.int32)
    for i in range(lo, hi):
        js = [j - lo for j in knn[i] if lo <= j < hi]
        ind[i - lo, :len(js)] = js
    g_sub = build_rnsg(vecs[lo:hi], attrs[lo:hi], m=m, ef_attribute=ef_attr,
                       knn_ids=ind)
    # compare neighbor sets on the interval
    for i in range(hi - lo):
        glob = {j - lo for j in g.nbrs[lo + i] if lo <= j < hi}
        sub = {int(j) for j in g_sub.nbrs[i] if j >= 0}
        assert glob == sub, (i, glob, sub)
