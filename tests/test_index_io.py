"""Persistence-path hardening: OOB-KNN regressions, atomic graph save,
checkpoint fd/KeyError fixes, and the index state/directory round trips
(``repro.index.io``)."""
import json
import os

import numpy as np
import pytest

from repro.checkpoint.checkpoint import CheckpointManager
from repro.core.construction import RNSGGraph, build_rnsg
from repro.core.rfann import RNSGIndex
from repro.index import io
from repro.index.knn import exact_knn
from repro.streaming.streaming import StreamingRFANN


def _corpus(n, d=16, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, d)).astype(np.float32),
            rng.normal(size=n).astype(np.float32))


# ------------------------------------------------------- OOB KNN ids
def test_exact_knn_masks_pad_rows_when_k_exceeds_n():
    v, _ = _corpus(10)
    d, i = exact_knn(v, 32)
    assert i.max() < 10                      # pre-fix: pad-row ids leaked
    assert ((i == -1) == np.isinf(d)).all()
    # each row still has its n-1 real neighbors, all distinct
    for row in i:
        real = row[row >= 0]
        assert len(real) == 9 and len(set(real.tolist())) == 9


def test_build_rnsg_tiny_corpus_ids_in_bounds():
    # n < ef_spatial: pre-fix the adjacency contained ids >= n
    v, a = _corpus(10)
    g = build_rnsg(v, a, m=8, ef_spatial=32, ef_attribute=16)
    assert g.nbrs.max() < 10 and g.nbrs.min() >= -1
    idx = RNSGIndex(g)
    q, r = v[:4], np.sort(np.random.default_rng(1)
                          .normal(size=(4, 2)).astype(np.float32), axis=1)
    for plan in ("graph", "scan"):
        res = idx.search(q, r, k=3, plan=plan)
        assert res.ids.shape == (4, 3)


@pytest.mark.parametrize("n", [1, 2, 3])
def test_build_rnsg_degenerate_corpora(n):
    v, a = _corpus(n)
    g = build_rnsg(v, a, m=4, ef_spatial=8, ef_attribute=4)
    assert g.nbrs.shape[0] == n and g.nbrs.max() < n


# --------------------------------------------------- atomic graph save
def test_graph_save_roundtrips_meta_and_is_atomic(tmp_path):
    v, a = _corpus(64)
    g = build_rnsg(v, a, m=8, ef_spatial=8, ef_attribute=8)
    g.meta["note"] = "hello"
    path = str(tmp_path / "g.npz")
    g.save(path)
    # no tmp litter; the target exists
    assert os.listdir(tmp_path) == ["g.npz"]
    g2 = RNSGGraph.load(path)
    assert g2.meta == g.meta                 # pre-fix: meta was dropped
    assert isinstance(g2.build_seconds, float)   # pre-fix: 0-d ndarray
    assert g2.build_seconds == pytest.approx(g.build_seconds)
    for f in ("vecs", "attrs", "nbrs", "order", "centroid", "dist_c", "rmq"):
        assert np.array_equal(getattr(g, f), getattr(g2, f)), f


def test_graph_save_appends_npz_suffix(tmp_path):
    v, a = _corpus(32)
    g = build_rnsg(v, a, m=8, ef_spatial=8, ef_attribute=8)
    g.save(str(tmp_path / "idx"))            # np.savez would add .npz
    assert (tmp_path / "idx.npz").exists()
    g2 = RNSGGraph.load(str(tmp_path / "idx"))
    assert np.array_equal(g.nbrs, g2.nbrs)


def test_graph_load_legacy_layout(tmp_path):
    # files written before the __meta__ sidecar must still load
    v, a = _corpus(32)
    g = build_rnsg(v, a, m=8, ef_spatial=8, ef_attribute=8)
    legacy = tmp_path / "old.npz"
    np.savez(legacy, vecs=g.vecs, attrs=g.attrs, nbrs=g.nbrs,
             order=g.order, centroid=g.centroid, dist_c=g.dist_c,
             rmq=g.rmq, build_seconds=np.float64(1.5))
    g2 = RNSGGraph.load(str(legacy))
    assert g2.build_seconds == 1.5 and g2.meta == {}
    assert np.array_equal(g.nbrs, g2.nbrs)


# --------------------------------------------------------- checkpoints
def test_checkpoint_restore_mismatch_names_path_and_step(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(7, {"w": np.zeros(3)}, blocking=True)
    with pytest.raises(KeyError, match=r"step 7 .*no entry for tree path "
                                       r"'missing'"):
        cm.restore({"missing": np.zeros(3)}, step=7)


def test_checkpoint_restore_does_not_leak_fds(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, {"w": np.arange(8.0)}, blocking=True)
    fd_dir = "/proc/self/fd"
    before = len(os.listdir(fd_dir))
    for _ in range(32):
        cm.restore({"w": np.zeros(8)})
        cm.meta()
        cm.restore_flat()
    assert len(os.listdir(fd_dir)) <= before + 2    # pre-fix: +1 fd per call


def test_checkpoint_index_roundtrip_with_quantized(tmp_path):
    v, a = _corpus(300)
    idx = RNSGIndex.build(v, a, m=8, ef_spatial=8, ef_attribute=12)
    idx.install_quantized("int8")
    idx.install_quantized("bf16")
    cm = CheckpointManager(str(tmp_path))
    cm.save_index(5, idx)
    got = cm.restore_index()
    assert isinstance(got, RNSGIndex)
    assert np.array_equal(got.g.nbrs, idx.g.nbrs)
    assert got.g.meta == idx.g.meta
    # quantized corpora restored bit-exactly (bf16 via the f32 upcast)
    for p in ("int8", "bf16"):
        want = np.asarray(idx.substrate._quant[p]["data"])
        have = np.asarray(got.substrate._quant[p]["data"])
        assert np.array_equal(want.view(np.uint8), have.view(np.uint8)), p
    s8 = idx.substrate._quant["int8"]["scale"]
    assert np.array_equal(np.asarray(s8),
                          np.asarray(got.substrate._quant["int8"]["scale"]))


def test_checkpoint_restore_index_requires_index_manifest(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, {"w": np.zeros(2)}, blocking=True)
    with pytest.raises(KeyError, match="save_index"):
        cm.restore_index()


# ------------------------------------------------------ directory format
@pytest.mark.parametrize("shards", [1, 4])
def test_dir_format_roundtrip_and_query_parity(tmp_path, shards):
    v, a = _corpus(400)
    idx = RNSGIndex.build(v, a, m=8, ef_spatial=8, ef_attribute=12)
    idx.install_quantized("int8")
    p = str(tmp_path / "idx")
    idx.save(p, shards=shards)
    man = json.loads((tmp_path / "idx" / "manifest.json").read_text())
    n_files = {len(am["files"]) for am in man["arrays"].values()}
    if shards > 1:
        assert shards in n_files             # row arrays actually sharded
    got = RNSGIndex.load(p)
    rng = np.random.default_rng(2)
    q = rng.normal(size=(12, v.shape[1])).astype(np.float32)
    r = np.sort(rng.normal(size=(12, 2)).astype(np.float32), axis=1)
    for plan in ("graph", "scan", "auto"):
        for prec in ("f32", "int8"):
            want = idx.search(q, r, k=4, plan=plan, precision=prec)
            have = got.search(q, r, k=4, plan=plan, precision=prec)
            assert np.array_equal(want.ids, have.ids), (plan, prec)


def test_dir_format_generations_gc(tmp_path):
    v, a = _corpus(128)
    idx = RNSGIndex.build(v, a, m=8, ef_spatial=8, ef_attribute=8)
    p = str(tmp_path / "d")
    m0 = io.save_index(idx, p, shards=2)
    m1 = io.save_index(idx, p, shards=3)
    assert (m0["gen"], m1["gen"]) == (0, 1)
    files = [f for f in os.listdir(p) if f != "manifest.json"]
    assert files and all(".g1." in f for f in files)    # gen-0 collected
    got = io.load_index(p)
    assert np.array_equal(got.g.nbrs, idx.g.nbrs)


def test_streaming_state_roundtrip(tmp_path):
    v, a = _corpus(256)
    s = StreamingRFANN(v, a, m=8, ef_spatial=8, ef_attribute=8,
                       max_delta=10**6)
    s.install_quantized("int8")
    rng = np.random.default_rng(3)
    for _ in range(12):
        s.insert(rng.normal(size=16).astype(np.float32),
                 float(rng.normal()))
    for e in (1, 5, 260):                    # two base rows + one delta row
        s.delete(e)
    p = str(tmp_path / "s")
    io.save_index(s, p, shards=2)
    s2 = io.load_index(p)
    assert isinstance(s2, StreamingRFANN)
    assert s2._next_id == s._next_id
    assert s2._view.n_tombstones == s._view.n_tombstones == 2
    assert s2._view.delta.count == s._view.delta.count
    assert s2._precisions == {"int8"}
    q = rng.normal(size=(10, 16)).astype(np.float32)
    r = np.sort(rng.normal(size=(10, 2)).astype(np.float32), axis=1)
    for prec in ("f32", "int8"):
        want = s.search(q, r, k=4, plan="auto", precision=prec)
        have = s2.search(q, r, k=4, plan="auto", precision=prec)
        assert np.array_equal(want.ids, have.ids), prec
        assert np.allclose(want.dists, have.dists, equal_nan=True), prec
    # restored index stays mutable and ids keep advancing from the ckpt
    nid = s2.insert(np.zeros(16, np.float32), 0.0)
    assert nid == s._next_id
    s2.delete(nid)


def test_rnsg_load_rejects_streaming_dir(tmp_path):
    v, a = _corpus(128)
    s = StreamingRFANN(v, a, m=8, ef_spatial=8, ef_attribute=8)
    p = str(tmp_path / "s")
    io.save_index(s, p)
    with pytest.raises(TypeError, match="StreamingRFANN"):
        RNSGIndex.load(p)


# ----------------------------------------------------- corruption errors
def _saved_dir(tmp_path, shards=1):
    v, a = _corpus(128)
    idx = RNSGIndex.build(v, a, m=8, ef_spatial=8, ef_attribute=8)
    p = tmp_path / "d"
    io.save_index(idx, str(p), shards=shards)
    return p


def test_load_index_truncated_file_names_file_and_generation(tmp_path):
    p = _saved_dir(tmp_path)
    man = json.loads((p / "manifest.json").read_text())
    fn = man["arrays"]["graph/nbrs"]["files"][0]
    (p / fn).write_bytes((p / fn).read_bytes()[:16])    # truncate
    with pytest.raises(io.IndexCorruptionError) as e:
        io.load_index(str(p))
    msg = str(e.value)
    assert fn in msg and "manifest generation 0" in msg


def test_load_index_missing_file_names_file(tmp_path):
    p = _saved_dir(tmp_path)
    man = json.loads((p / "manifest.json").read_text())
    fn = man["arrays"]["graph/rmq"]["files"][0]
    (p / fn).unlink()
    with pytest.raises(io.IndexCorruptionError, match="missing"):
        io.load_index(str(p))


def test_load_index_sharded_crc_mismatch(tmp_path):
    # sharded slabs are read in full, so their CRCs are always verified
    p = _saved_dir(tmp_path, shards=2)
    man = json.loads((p / "manifest.json").read_text())
    fn = man["arrays"]["graph/vecs"]["files"][1]
    blob = bytearray((p / fn).read_bytes())
    blob[-1] ^= 0xFF                        # flip a data byte, length intact
    (p / fn).write_bytes(bytes(blob))
    with pytest.raises(io.IndexCorruptionError, match="CRC32 mismatch"):
        io.load_index(str(p))


def test_load_index_verify_checks_mmapped_files(tmp_path):
    p = _saved_dir(tmp_path, shards=1)
    man = json.loads((p / "manifest.json").read_text())
    fn = man["arrays"]["graph/vecs"]["files"][0]
    blob = bytearray((p / fn).read_bytes())
    blob[-1] ^= 0xFF
    (p / fn).write_bytes(bytes(blob))
    io.load_index(str(p))                   # lazy mmap: not detected ...
    with pytest.raises(io.IndexCorruptionError, match="CRC32 mismatch"):
        io.load_index(str(p), verify=True)  # ... full verify: detected


def test_checkpoint_manager_corrupt_npz_names_step(tmp_path):
    v, a = _corpus(96)
    idx = RNSGIndex.build(v, a, m=8, ef_spatial=8, ef_attribute=8)
    cm = CheckpointManager(str(tmp_path), keep=2)
    cm.save_index(7, idx, blocking=True)
    path = tmp_path / "step_0000000007.npz"
    path.write_bytes(path.read_bytes()[:100])           # truncate the zip
    with pytest.raises(io.IndexCorruptionError) as e:
        cm.restore_index(7)
    assert "step 7" in str(e.value) and path.name in str(e.value)


def test_fsync_dir_tolerates_missing_and_plain_paths(tmp_path):
    io.fsync_dir(tmp_path)                  # a real directory: fsynced
    io.fsync_dir(tmp_path / "nope")         # missing: silent no-op
    f = tmp_path / "f.txt"
    f.write_text("x")
    io.fsync_dir(f)                         # not a dir: silent no-op
