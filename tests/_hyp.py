"""Optional-hypothesis shim: property tests skip (not error) when the
``hypothesis`` package is absent from the environment.

Import ``given, settings, st, HAVE_HYPOTHESIS`` from here instead of from
``hypothesis`` directly.  With hypothesis installed this module is a pure
re-export; without it, ``@given(...)`` turns the test into a skip and the
``st.*`` strategy constructors return inert placeholders so module-level
strategy definitions still evaluate.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                                            # pragma: no cover
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn

    class _Strategy:
        """Inert placeholder accepted anywhere a strategy is stored."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    class _StModule:
        def __getattr__(self, name):
            return _Strategy()

    st = _StModule()
