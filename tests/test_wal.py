"""WAL durability: record format, torn tails, crash-point sweeps.

The crash harness swaps the WAL's syscall layer for ``CrashOps`` (dies at
the N-th durability-relevant operation) and sweeps N across the whole
insert / delete / checkpoint / compaction lifecycle, asserting after each
simulated crash that ``StreamingRFANN.recover`` reproduces a state
bit-identical to a never-crashed oracle that applied some acknowledged
prefix of the same mutation script.
"""
import os
import threading

import numpy as np
import pytest

from repro.index import io
from repro.streaming import (CrashOps, InjectedCrash, ReadOnlyIndexError,
                             StreamingRFANN, WALError, WriteAheadLog)
from repro.streaming import wal as walmod

_BUILD = dict(m=8, ef_spatial=8, ef_attribute=8)
_D = 4


def _corpus(n=32, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal((n, _D)).astype(np.float32),
            rng.standard_normal(n).astype(np.float32))


@pytest.fixture(scope="module")
def base_ckpt(tmp_path_factory):
    """One pristine streaming index, checkpointed once; every crash run
    and every oracle restores from here (no rebuild per crash point)."""
    p = tmp_path_factory.mktemp("walbase") / "base"
    vecs, attrs = _corpus()
    idx = StreamingRFANN(vecs, attrs, max_delta=10_000, **_BUILD)
    io.save_index(idx, p)
    return p


# ---------------------------------------------------------------- records
def test_record_roundtrip_all_ops(tmp_path):
    w = WriteAheadLog(tmp_path / "w", sync="always")
    vec = np.arange(_D, dtype=np.float32)
    w.append_insert(7, 0.5, vec)
    w.append_delete(7)
    w.append_barrier(3, 2)
    w.seal()
    w.close()
    recs = list(walmod.replay(tmp_path / "w"))
    assert [r.lsn for r in recs] == [1, 2, 3, 4]
    assert [r.op_name for r in recs] == ["insert", "delete", "barrier",
                                         "seal"]
    assert recs[0].ext_id == 7 and recs[0].attr == pytest.approx(0.5)
    np.testing.assert_array_equal(recs[0].vector, vec)
    assert recs[2].generation == 3 and recs[2].watermark == 2


def test_lsn_resumes_across_reopen(tmp_path):
    w = WriteAheadLog(tmp_path / "w", sync="always")
    w.append_insert(1, 0.0, np.zeros(_D, np.float32))
    w.close()
    w2 = WriteAheadLog(tmp_path / "w", sync="always")
    assert w2.next_lsn == 2
    assert w2.append_delete(1) == 2
    w2.close()
    assert walmod.last_lsn(tmp_path / "w") == 2


def test_segment_rotation_and_gc(tmp_path):
    w = WriteAheadLog(tmp_path / "w", sync="always", segment_bytes=64)
    for i in range(12):
        w.append_insert(i, 0.0, np.zeros(_D, np.float32))
    assert w.segment_count > 1
    # nothing covered: gc removes nothing
    assert w.gc(0) == 0
    # everything covered: every segment but the live tail goes
    removed = w.gc(12)
    assert removed == w._seq  # segments 0..seq-1
    assert w.segment_count == 1
    # the surviving tail still replays in order
    w.append_delete(3)
    w.close()
    lsns = [r.lsn for r in walmod.replay(tmp_path / "w")]
    assert lsns == sorted(lsns) and lsns[-1] == 13


def test_invalid_sync_policy_rejected(tmp_path):
    with pytest.raises(ValueError, match="sync="):
        WriteAheadLog(tmp_path / "w", sync="sometimes")
    with pytest.raises(ValueError, match="fsync_every_n"):
        WriteAheadLog(tmp_path / "w", fsync_every_n=0)


def test_concurrent_appends_keep_lsn_in_file_order(tmp_path):
    """Mutation-path appends race the compaction thread's barriers; LSNs
    must come out unique and strictly increasing *in file order* — replay
    applies records in file order and skips ``lsn <= watermark``, so an
    out-of-order LSN would silently drop an acknowledged write on
    recovery."""
    w = WriteAheadLog(tmp_path / "w", sync="none", segment_bytes=1 << 20)
    vec = np.zeros(_D, np.float32)
    n_per = 200
    start = threading.Barrier(3)

    def mutate(tid):
        start.wait()
        for i in range(n_per):
            w.append_insert(tid * n_per + i, 0.0, vec)

    def barriers():
        start.wait()
        for g in range(n_per):
            w.append_barrier(g, 0)

    threads = [threading.Thread(target=mutate, args=(t,)) for t in (0, 1)]
    threads.append(threading.Thread(target=barriers))
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    w.close()
    lsns = [r.lsn for r in walmod.replay(tmp_path / "w")]
    assert len(lsns) == 3 * n_per
    assert lsns == list(range(1, 3 * n_per + 1))    # unique, in file order


# ------------------------------------------------------------- torn tails
def test_torn_tail_truncates_and_reopens(tmp_path):
    w = WriteAheadLog(tmp_path / "w", sync="always")
    for i in range(4):
        w.append_insert(i, 0.0, np.zeros(_D, np.float32))
    w.close()
    seg = walmod.list_segments(tmp_path / "w")[-1]
    good = seg.stat().st_size
    with open(seg, "ab") as f:
        f.write(b"\x40\x00\x00\x00\xde\xad")       # half a record
    recs = list(walmod.replay(tmp_path / "w", truncate=True))
    assert [r.lsn for r in recs] == [1, 2, 3, 4]
    assert seg.stat().st_size == good              # physically truncated
    # a reopened log appends cleanly after the torn point
    w2 = WriteAheadLog(tmp_path / "w", sync="always")
    assert w2.append_delete(0) == 5
    w2.close()


def test_corruption_mid_log_discards_later_segments(tmp_path):
    w = WriteAheadLog(tmp_path / "w", sync="always", segment_bytes=64)
    for i in range(10):
        w.append_insert(i, 0.0, np.zeros(_D, np.float32))
    w.close()
    segs = walmod.list_segments(tmp_path / "w")
    assert len(segs) >= 3
    # flip one payload byte in the middle segment: records after the tear
    # (including whole later segments) must not replay — LSN order only
    blob = bytearray(segs[1].read_bytes())
    blob[12] ^= 0xFF
    segs[1].write_bytes(bytes(blob))
    recs = list(walmod.replay(tmp_path / "w", truncate=True))
    first_seg_recs, _, _ = walmod._scan_segment(segs[0])
    assert [r.lsn for r in recs] == [r.lsn for r in first_seg_recs]
    assert not segs[2].exists()                     # later segment removed


# -------------------------------------------------- streaming integration
def test_recover_replays_tail_idempotently(base_ckpt, tmp_path):
    idx = io.load_index(base_ckpt)
    idx.attach_wal(tmp_path / "wal", sync="always")
    idx.set_checkpoint_path(str(tmp_path / "ckpt"))
    added = [idx.insert(np.full(_D, i, np.float32), float(i))
             for i in range(6)]
    idx.delete(added[0])
    idx.delete(3)                                   # base tombstone
    want = dict(idx._id_loc)

    rec = StreamingRFANN.recover(tmp_path / "ckpt", tmp_path / "wal",
                                 attach=False)
    assert sorted(rec._id_loc) == sorted(want)
    assert rec._next_id == idx._next_id
    # replaying again is a no-op (watermark + liveness idempotence)
    assert rec.replay_wal(tmp_path / "wal") == 0
    # recover twice -> bit-identical state
    rec2 = StreamingRFANN.recover(tmp_path / "ckpt", tmp_path / "wal",
                                  attach=False)
    fa, ma = io.index_state(rec)
    fb, mb = io.index_state(rec2)
    assert _state_equal(fa, ma, fb, mb)


def test_checkpoint_writes_barrier_and_gcs(base_ckpt, tmp_path):
    idx = io.load_index(base_ckpt)
    idx.attach_wal(tmp_path / "wal", sync="always", segment_bytes=128)
    idx.set_checkpoint_path(str(tmp_path / "ckpt"))
    for i in range(10):
        idx.insert(np.full(_D, i, np.float32), float(i))
    assert idx._wal.segment_count > 1
    idx.checkpoint()
    d = walmod.describe(tmp_path / "wal")
    assert d["barrier_watermark"] == idx.applied_lsn
    assert d["segments"] == 1                       # history GC'd
    # the post-checkpoint log still recovers the full state
    rec = StreamingRFANN.recover(tmp_path / "ckpt", tmp_path / "wal",
                                 attach=False)
    assert sorted(rec._id_loc) == sorted(idx._id_loc)


def test_wal_failure_degrades_to_read_only(base_ckpt, tmp_path):
    class _DeadDisk(walmod.FileOps):
        def write(self, fd, data):
            raise OSError(28, "No space left on device")

    idx = io.load_index(base_ckpt)
    idx.attach_wal(tmp_path / "wal", sync="always")
    idx.insert(np.zeros(_D, np.float32), 0.0)
    idx._wal.ops = _DeadDisk()
    with pytest.warns(UserWarning, match="read-only"), \
            pytest.raises(ReadOnlyIndexError):
        idx.insert(np.ones(_D, np.float32), 1.0)
    assert idx.read_only and idx.stats()["read_only"] == 1
    with pytest.raises(ReadOnlyIndexError):        # stays rejected
        idx.delete(0)
    # searches keep serving on the degraded index
    res = idx.search(np.zeros((1, _D), np.float32),
                     np.array([[-10.0, 10.0]], np.float32), k=3)
    assert res.ids.shape == (1, 3)


def test_set_compaction_policy_validation(base_ckpt):
    idx = io.load_index(base_ckpt)
    with pytest.raises(ValueError, match=r"max_delta=0"):
        idx.set_compaction_policy(max_delta=0)
    with pytest.raises(ValueError, match=r"max_delta=-3"):
        idx.set_compaction_policy(max_delta=-3)
    with pytest.raises(ValueError, match=r"compact_every=-1"):
        idx.set_compaction_policy(compact_every=-1)
    before = (idx.max_delta, idx.compact_every)
    with pytest.raises(ValueError):
        idx.set_compaction_policy(max_delta=-1, compact_every=5)
    assert (idx.max_delta, idx.compact_every) == before   # no partial apply
    idx.set_compaction_policy(max_delta=7, compact_every=0)
    assert (idx.max_delta, idx.compact_every) == (7, 0)


# ---------------------------------------------------------- crash sweeps
def _script():
    """Deterministic mutation script: inserts, deletes of both delta and
    base rows, and a mid-script checkpoint ("C" — not a mutation)."""
    rng = np.random.default_rng(42)
    ops = []
    for i in range(8):
        ops.append(("I", 1000 + i,
                    rng.standard_normal(_D).astype(np.float32),
                    float(rng.standard_normal())))
    ops += [("D", 3), ("D", 1002), ("C",)]
    for i in range(8, 12):
        ops.append(("I", 1000 + i,
                    rng.standard_normal(_D).astype(np.float32),
                    float(rng.standard_normal())))
    ops += [("D", 7), ("D", 1005)]
    return ops


_MUTS = [op for op in _script() if op[0] != "C"]


def _apply(idx, op):
    if op[0] == "I":
        idx.insert(op[2], op[3], ext_id=op[1])
    elif op[0] == "D":
        idx.delete(op[1])


def _state_equal(fa, ma, fb, mb) -> bool:
    sa, sb = ma["streaming"], mb["streaming"]
    if sa["next_id"] != sb["next_id"]:
        return False
    if set(fa) != set(fb):
        return False
    return all(np.array_equal(np.asarray(fa[k]), np.asarray(fb[k]))
               for k in fa)


def _oracle_state(base_ckpt, m, _cache={}):
    """flat/manifest of a never-crashed index that applied _MUTS[:m]."""
    key = (str(base_ckpt), m)
    if key not in _cache:
        ora = io.load_index(base_ckpt)
        for op in _MUTS[:m]:
            _apply(ora, op)
        _cache[key] = io.index_state(ora)
    return _cache[key]


def _run_to_crash(base_ckpt, rundir, crash_at):
    """One simulated process: restore base, attach a crashy WAL, run the
    script.  Returns (acked mutation count, crashed?, total ops)."""
    idx = io.load_index(base_ckpt)
    co = CrashOps(crash_at)
    acked = 0
    crashed = False
    try:
        idx.attach_wal(rundir / "wal", sync="always", ops=co)
        idx.set_checkpoint_path(str(rundir / "ckpt"))
        for op in _script():
            if op[0] == "C":
                idx.checkpoint()
            else:
                _apply(idx, op)
                acked += 1
    except InjectedCrash:
        crashed = True
    return acked, crashed, co.ops


def test_crash_sweep_mutations_and_checkpoint(base_ckpt, tmp_path):
    """Kill the WAL at EVERY durability-relevant syscall across the whole
    script; recovery must always equal the oracle at the acknowledged
    prefix (or prefix+1: the in-flight record may have reached the disk
    before the crash point)."""
    acked, crashed, total = _run_to_crash(base_ckpt, tmp_path / "probe", -1)
    assert not crashed and acked == len(_MUTS)
    assert total > 0
    for cat in range(total):
        rundir = tmp_path / f"r{cat}"
        acked, crashed, _ = _run_to_crash(base_ckpt, rundir, cat)
        assert crashed, f"crash_at={cat} never fired"
        if not io.is_index_dir(rundir / "ckpt"):
            # died before the baseline checkpoint committed: nothing was
            # acknowledged yet, so there is nothing to recover
            assert acked == 0
            continue
        rec = StreamingRFANN.recover(rundir / "ckpt", rundir / "wal",
                                     attach=False)
        fr, mr = io.index_state(rec)
        candidates = {acked, min(acked + 1, len(_MUTS))}
        assert any(_state_equal(fr, mr, *_oracle_state(base_ckpt, m))
                   for m in candidates), (
            f"crash_at={cat}: recovered state (lsn={rec.applied_lsn}) "
            f"matches no acknowledged prefix in {sorted(candidates)}")


def test_crash_sweep_compaction_checkpoint(base_ckpt, tmp_path,
                                           monkeypatch):
    """Crash at every WAL syscall of the checkpoint that follows a
    compaction (rotate / barrier / gc).  The compacted, fully-mutated
    state must recover bit-identically — the manifest-last commit makes
    the checkpoint atomic, and the WAL tail covers anything after it."""
    monkeypatch.setattr(threading, "excepthook", lambda args: None)

    def run(rundir, crash_at, do_compact):
        idx = io.load_index(base_ckpt)
        co = CrashOps(crash_at)
        idx.attach_wal(rundir / "wal", sync="always", ops=co)
        idx.set_checkpoint_path(str(rundir / "ckpt"))
        for op in _MUTS:
            _apply(idx, op)
        if do_compact:
            idx.compact(wait=True)  # InjectedCrash lands in the worker
        return co

    t0 = run(tmp_path / "p0", -1, False).ops     # ops before compaction
    t1 = run(tmp_path / "p1", -1, True).ops      # ops incl. its checkpoint
    assert t1 > t0

    # oracle: same mutations + a clean compaction, never crashed
    ora = io.load_index(base_ckpt)
    for op in _MUTS:
        _apply(ora, op)
    ora.compact(wait=True)
    fo, mo = io.index_state(ora)

    for cat in range(t0, t1):
        rundir = tmp_path / f"c{cat}"
        run(rundir, cat, True)
        rec = StreamingRFANN.recover(rundir / "ckpt", rundir / "wal",
                                     attach=False)
        fr, mr = io.index_state(rec)
        assert _state_equal(fr, mr, fo, mo), (
            f"crash_at={cat}: post-compaction recovery diverged")
        # the full live set survived regardless of where the crash landed
        assert sorted(rec._id_loc) == sorted(ora._id_loc)
