"""Beam search, entry generation, pruning equivalence, end-to-end recall."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.beam import beam_search_batch
from repro.core.construction import RNSGGraph, build_rnsg
from repro.core.entry import (build_rmq, centroid_dists, entry_from_stack,
                              entry_stacks, rmq_query_np)
from repro.core.pruning import prune_all_jax, rrng_prune_np
from repro.core.rfann import RNSGIndex
from repro.data.ann import (ground_truth, make_attrs, make_vectors,
                            mixed_workload, recall_at_k, selectivity_ranges)

import jax.numpy as jnp


# ---------------------------------------------------------------- entry (Alg 3)
@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(0, 1e6, allow_nan=False), min_size=2, max_size=200),
       st.integers(0, 10_000))
def test_alg3_stack_equals_rmq(dists, seed):
    d = np.asarray(dists, np.float32)
    d += np.arange(len(d)) * 1e-3          # break exact ties deterministically
    stacks = entry_stacks(d)
    rmq = build_rmq(d)
    rng = np.random.default_rng(seed)
    for _ in range(10):
        lo = int(rng.integers(0, len(d)))
        hi = int(rng.integers(lo, len(d)))
        assert entry_from_stack(stacks, d, lo, hi) == rmq_query_np(rmq, d, lo, hi)


def test_alg3_stack_size_logarithmic():
    rng = np.random.default_rng(0)
    d = rng.random(20_000).astype(np.float32)
    sizes = [len(q) for q in entry_stacks(d)]
    # Lemma 4.8: E[|q|] = H_n ≈ ln n ≈ 9.9; generous bound
    assert np.mean(sizes) < 3 * np.log(len(d))


# ---------------------------------------------------------------- pruning (Alg 1)
def test_prune_jax_matches_numpy_reference():
    rng = np.random.default_rng(1)
    n, d, m = 120, 8, 10
    vecs = rng.standard_normal((n, d)).astype(np.float32)
    # candidate sides: full windows (C = D) so both impls see identical input
    from repro.core.construction import _gap_sorted_side
    knn = np.full((n, 1), -1, np.int32)
    cl = _gap_sorted_side(n, knn, n, "l")
    cr = _gap_sorted_side(n, knn, n, "r")
    nbrs = prune_all_jax(vecs, cl, cr, m)
    for x in range(0, n, 7):
        ref = rrng_prune_np(x, np.arange(n), vecs, m)
        got = [int(v) for v in nbrs[x] if v >= 0]
        assert sorted(got) == sorted(ref), x


# ---------------------------------------------------------------- beam search
def _small_index(n=800, d=16, seed=0):
    vecs = make_vectors(n, d, seed=seed)
    attrs = make_attrs(n, seed=seed)
    return vecs, attrs, RNSGIndex.build(vecs, attrs, m=16, ef_spatial=16,
                                        ef_attribute=24)


def test_high_ef_reaches_high_recall():
    vecs, attrs, idx = _small_index()
    nq, k = 60, 10
    qv = make_vectors(nq, 16, seed=5)
    ranges, _ = mixed_workload(attrs, nq, seed=2, levels=6)
    order = np.argsort(attrs, kind="stable")
    gt_r, _ = ground_truth(vecs[order], attrs[order], qv, ranges, k)
    gt = np.where(gt_r >= 0, order[np.maximum(gt_r, 0)], -1)
    ids, _, _ = idx.search(qv, ranges, k=k, ef=128)
    assert recall_at_k(ids, gt) > 0.97


def test_empty_and_singleton_ranges():
    vecs, attrs, idx = _small_index()
    qv = make_vectors(3, 16, seed=9)
    s = np.sort(attrs)
    ranges = np.asarray([
        [s[5] + 1e-7, s[5] + 2e-7],     # empty
        [s[17], s[17]],                 # singleton
        [s[0], s[-1]],                  # full
    ], np.float32)
    ids, dists, _ = idx.search(qv, ranges, k=5, ef=32)
    assert (ids[0] == -1).all()
    assert (ids[1][0] >= 0) and (ids[1][1:] == -1).all()
    assert (ids[2] >= 0).all()


def test_results_respect_range_filter():
    vecs, attrs, idx = _small_index()
    nq = 40
    qv = make_vectors(nq, 16, seed=4)
    ranges = selectivity_ranges(attrs, nq, 0.05, seed=3)
    ids, _, _ = idx.search(qv, ranges, k=10, ef=64)
    for q in range(nq):
        for i in ids[q]:
            if i >= 0:
                assert ranges[q, 0] <= attrs[i] <= ranges[q, 1]


def test_multi_entry_beam():
    vecs, attrs, idx = _small_index()
    g = idx.g
    qv = jnp.asarray(make_vectors(4, 16, seed=11))
    n = g.n
    lo = jnp.zeros(4, jnp.int32)
    hi = jnp.full(4, n - 1, jnp.int32)
    entries = jnp.asarray([[0, n // 2, -1], [5, -1, -1],
                           [n - 1, 1, 2], [7, 8, 9]], jnp.int32)
    ids, d, _ = beam_search_batch(jnp.asarray(g.vecs), jnp.asarray(g.nbrs),
                                  qv, lo, hi, entries, k=5, ef=48)
    assert (np.asarray(ids) >= 0).all()


def test_kernel_backed_beam_matches_default():
    vecs, attrs, idx = _small_index(n=400)
    qv = make_vectors(16, 16, seed=13)
    ranges, _ = mixed_workload(attrs, 16, seed=8, levels=4)
    a, da, _ = idx.search(qv, ranges, k=5, ef=32, use_kernel=False)
    b, db, _ = idx.search(qv, ranges, k=5, ef=32, use_kernel=True)
    assert np.array_equal(a, b)
    assert np.allclose(da, db, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- save/load
def test_index_save_load_roundtrip(tmp_path):
    vecs, attrs, idx = _small_index(n=300)
    p = str(tmp_path / "idx.npz")
    idx.save(p)
    idx2 = RNSGIndex.load(p)
    qv = make_vectors(8, 16, seed=3)
    ranges = selectivity_ranges(attrs, 8, 0.25, seed=1)
    a, _, _ = idx.search(qv, ranges, k=5, ef=32)
    b, _, _ = idx2.search(qv, ranges, k=5, ef=32)
    assert np.array_equal(a, b)


# ---------------------------------------------------------------- beyond-paper
def test_reverse_edges_preserve_heredity_and_help_recall():
    """Beyond-paper reverse-edge augmentation: with an unsaturated cap
    heredity holds exactly (the reverse of an in-range edge stays in range);
    and at the default cap mixed recall at fixed ef does not get worse."""
    from repro.core.construction import build_rnsg
    n, d = 1024, 16
    vecs = make_vectors(n, d, seed=2)
    attrs = np.arange(n).astype(np.float32)
    from repro.index.knn import exact_knn
    _, knn = exact_knn(vecs, 12)
    g = build_rnsg(vecs, attrs, m=8, ef_attribute=10, knn_ids=knn,
                   reverse_edges=True, reverse_cap=256)   # unsaturated cap
    assert (g.nbrs >= 0).sum(1).max() < 256               # cap never binds
    lo, hi = 200, 800
    ind = np.full((hi - lo, 12), -1, np.int32)
    for i in range(lo, hi):
        js = [j - lo for j in knn[i] if lo <= j < hi]
        ind[i - lo, :len(js)] = js
    g_sub = build_rnsg(vecs[lo:hi], attrs[lo:hi], m=8, ef_attribute=10,
                       knn_ids=ind, reverse_edges=True, reverse_cap=256)
    for i in range(hi - lo):
        glob = {j - lo for j in g.nbrs[lo + i] if lo <= j < hi}
        sub = {int(j) for j in g_sub.nbrs[i] if j >= 0}
        assert glob == sub, i

    vecs2 = make_vectors(2048, 16, seed=5)
    attrs2 = make_attrs(2048, seed=5)
    qv = make_vectors(50, 16, seed=77)
    ranges, _ = mixed_workload(attrs2, 50, seed=3, levels=5)
    order = np.argsort(attrs2, kind="stable")
    gt_r, _ = ground_truth(vecs2[order], attrs2[order], qv, ranges, 10)
    gt = np.where(gt_r >= 0, order[np.maximum(gt_r, 0)], -1)
    base = RNSGIndex(build_rnsg(vecs2, attrs2, m=12, ef_spatial=12, ef_attribute=16))
    aug = RNSGIndex(build_rnsg(vecs2, attrs2, m=12, ef_spatial=12, ef_attribute=16,
                               reverse_edges=True))
    rb = recall_at_k(base.search(qv, ranges, k=10, ef=48)[0], gt)
    ra = recall_at_k(aug.search(qv, ranges, k=10, ef=48)[0], gt)
    assert ra >= rb - 0.01, (rb, ra)


def test_nndescent_build_matches_exact_quality():
    """Paper's construction uses NNDescent; our fixed-iteration variant must
    deliver comparable index quality to the exact-KNN build."""
    from repro.core.construction import build_rnsg
    from repro.index.knn import exact_knn, nndescent, knn_recall
    n, d = 2048, 16
    vecs = make_vectors(n, d, seed=1)
    attrs = make_attrs(n, seed=1)
    order = np.argsort(attrs, kind="stable")
    _, ids_exact = exact_knn(vecs[order], 16)
    _, ids_nnd = nndescent(vecs[order], 16, iters=6)
    assert knn_recall(ids_nnd, ids_exact) > 0.9
    qv = make_vectors(50, d, seed=9)
    ranges, _ = mixed_workload(attrs, 50, seed=4, levels=5)
    gt_r, _ = ground_truth(vecs[order], attrs[order], qv, ranges, 10)
    gt = np.where(gt_r >= 0, order[np.maximum(gt_r, 0)], -1)
    ix_e = RNSGIndex.build(vecs, attrs, m=12, ef_spatial=16, ef_attribute=16,
                           knn_method="exact")
    ix_n = RNSGIndex.build(vecs, attrs, m=12, ef_spatial=16, ef_attribute=16,
                           knn_method="nndescent")
    re_ = recall_at_k(ix_e.search(qv, ranges, k=10, ef=64)[0], gt)
    rn = recall_at_k(ix_n.search(qv, ranges, k=10, ef=64)[0], gt)
    assert rn > re_ - 0.05, (re_, rn)
