"""Adaptive query planner: kernel correctness, routing, exactness, ordering,
fixed-shape bucketing, and the bounded engine-stats reservoir."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.rfann import RNSGIndex
from repro.data.ann import (ground_truth, make_attrs, make_vectors,
                            recall_at_k, selectivity_ranges)
from repro.kernels.ops import range_scan
from repro.kernels.range_scan import range_scan_pallas
from repro.kernels.ref import range_scan_ref
from repro.planner import (QueryPlanner, bucket_for_len, ef_bucket,
                           next_pow2, pad_pow2, window_rows)

RNG = np.random.default_rng(0)


def _padded(n, d, tb=128, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    n_pad = -(-n // tb) * tb
    d_pad = -(-d // 128) * 128
    xp = np.zeros((n_pad, d_pad), np.float32)
    xp[:n, :d] = x
    return x, xp, d_pad


# ------------------------------------------------------------- kernel (Pallas)
@pytest.mark.parametrize("bucket", [64, 128, 512])
def test_range_scan_kernel_matches_ref(bucket):
    """Acceptance: Pallas kernel vs jnp reference on masked slices, interpret
    mode on CPU — arbitrary (unaligned) starts, short/empty/clipped lens."""
    n, d, q = 900, 40, 9
    x, xp, d_pad = _padded(n, d)
    starts = RNG.integers(0, n, q).astype(np.int32)
    lens = np.minimum(RNG.integers(0, bucket + 1, q), n - starts).astype(np.int32)
    lens[0] = 0                                    # empty window
    starts[1] = n - 1                              # tail, len clips to 1
    lens[1] = 1
    qv = np.zeros((q, d_pad), np.float32)
    qv[:, :d] = RNG.standard_normal((q, d)).astype(np.float32)
    got_i, got_d = range_scan(jnp.asarray(xp), jnp.asarray(starts),
                              jnp.asarray(lens), jnp.asarray(qv),
                              bucket=bucket, k=5)
    ref_i, ref_d = range_scan_ref(jnp.asarray(xp), jnp.asarray(starts),
                                  jnp.asarray(lens), jnp.asarray(qv),
                                  bucket=bucket, k=5)
    assert np.array_equal(np.asarray(got_i), np.asarray(ref_i))
    gd, rd = np.asarray(got_d), np.asarray(ref_d)
    mask = np.isfinite(rd)
    assert np.array_equal(mask, np.isfinite(gd))
    assert np.allclose(gd[mask], rd[mask], rtol=1e-4, atol=1e-4)


def test_range_scan_is_exact_vs_brute():
    n, d = 700, 24
    x, xp, d_pad = _padded(n, d, seed=3)
    starts = np.asarray([0, 123, 600], np.int32)
    lens = np.asarray([64, 200, 100], np.int32)    # last clips to n
    lens = np.minimum(lens, n - starts)
    qraw = RNG.standard_normal((3, d)).astype(np.float32)
    qv = np.zeros((3, d_pad), np.float32)
    qv[:, :d] = qraw
    ids, _ = range_scan(jnp.asarray(xp), jnp.asarray(starts),
                        jnp.asarray(lens), jnp.asarray(qv), bucket=256, k=7)
    for qi in range(3):
        L, ln = int(starts[qi]), int(lens[qi])
        ex = np.sum((x[L:L + ln] - qraw[qi]) ** 2, axis=1)
        want = set((np.argsort(ex)[:7] + L).tolist())
        got = set(int(i) for i in np.asarray(ids[qi]) if i >= 0)
        assert got == want


# -------------------------------------------------------------------- bucketing
def test_bucketing_helpers():
    assert [next_pow2(v) for v in (1, 2, 3, 64, 65)] == [1, 2, 4, 64, 128]
    assert bucket_for_len(3, min_bucket=64) == 64
    assert bucket_for_len(500) == 512
    assert bucket_for_len(5000, max_bucket=4096) == 4096
    assert window_rows(64) == 256 and window_rows(512) == 640
    assert pad_pow2(1) == 8 and pad_pow2(9) == 16
    assert ef_bucket(length=4, k=10, ef=64) == 16   # floor at next_pow2(k)
    assert ef_bucket(length=40, k=10, ef=64) == 64
    assert ef_bucket(length=10_000, k=10, ef=64) == 64


def test_bucketing_no_recompile_within_signature():
    """Two different batches with the same (bucket, padQ, k) signature must
    hit the compiled kernel cache — no recompilation."""
    n, d = 600, 16
    _, xp, d_pad = _padded(n, d, seed=1)
    xj = jnp.asarray(xp)

    def call(seed):
        rng = np.random.default_rng(seed)
        starts = jnp.asarray(rng.integers(0, n - 80, 8).astype(np.int32))
        lens = jnp.asarray(rng.integers(1, 80, 8).astype(np.int32))
        qv = jnp.asarray(rng.standard_normal((8, d_pad)).astype(np.float32))
        r = range_scan(xj, starts, lens, qv, bucket=128, k=5)
        return np.asarray(r[0])

    call(1)
    size_after_first = range_scan_pallas._cache_size()
    call(2)
    call(3)
    assert range_scan_pallas._cache_size() == size_after_first


# ----------------------------------------------------------------- routing/plan
def test_planner_routes_by_selectivity():
    pl = QueryPlanner(n=100_000, mean_degree=24.0)
    lo = np.asarray([10, 0, 50, 2000])
    hi = np.asarray([40, 99_999, 49, 2100])        # narrow, full, empty, small
    plan = pl.plan_batch(lo, hi, k=10, ef=64)
    assert plan.strategy.tolist() == [0, 1, 0, 0]
    sigs = {p.signature for p in plan.partitions}
    assert all(s[2] == next_pow2(max(s[2], 1)) for s in sigs)   # pow2 pads
    covered = np.concatenate([p.indices for p in plan.partitions])
    assert sorted(covered.tolist()) == [0, 1, 2, 3]             # exact cover


def test_planner_forced_modes():
    pl = QueryPlanner(n=10_000, mean_degree=16.0)
    lo = np.asarray([0, 100])
    hi = np.asarray([9_999, 200])
    assert (pl.plan_batch(lo, hi, k=10, ef=64, mode="scan").strategy == 0).all()
    assert (pl.plan_batch(lo, hi, k=10, ef=64, mode="beam").strategy == 1).all()


def test_choose_strategy_batch_matches_scalar():
    """The vectorized routing decision (the host half of mesh dispatch) must
    agree element-wise with the scalar reference across the whole regime
    spectrum — empty, tiny, boundary, ceiling, full — before and after
    calibration shifts the cost model."""
    pl = QueryPlanner(n=100_000, mean_degree=24.0)
    rng = np.random.default_rng(5)
    lens = np.concatenate([
        np.asarray([0, 1, 5, 10, 11, 64, 65, 12_500, 12_501, 100_000]),
        rng.integers(0, 100_000, 200),
        2 ** rng.integers(0, 17, 50),              # pow2 boundaries
    ])
    for k, ef in ((10, 64), (1, 16), (50, 256)):
        batch = pl.choose_strategy_batch(lens, k=k, ef=ef)
        scalar = np.asarray([pl.choose_strategy(int(ln), k=k, ef=ef)
                             for ln in lens], np.int8)
        assert np.array_equal(batch, scalar), (k, ef)
    # calibration moves the crossover; the two implementations move together
    pl.cost.update_beam(ndist_mean=2000.0, ef=64)
    batch = pl.choose_strategy_batch(lens, k=10, ef=64)
    scalar = np.asarray([pl.choose_strategy(int(ln), k=10, ef=64)
                         for ln in lens], np.int8)
    assert np.array_equal(batch, scalar)
    # and plan_batch routes with the same decisions (lo/hi -> lens)
    lo = np.zeros(len(lens), np.int64)
    plan = pl.plan_batch(lo, lo + lens - 1, k=10, ef=64)
    assert np.array_equal(plan.strategy, batch)


# ------------------------------------------------------------------ end to end
def _small_index(n=512, d=16, seed=0):
    vecs = make_vectors(n, d, seed=seed)
    attrs = make_attrs(n, seed=seed)
    return vecs, attrs, RNSGIndex.build(vecs, attrs, m=16, ef_spatial=16,
                                        ef_attribute=24)


def test_scan_and_beam_agree_on_small_n():
    """With ef ≥ n the beam explores the whole in-range component, so the two
    strategies must return the same exact top-k."""
    n = 256
    vecs, attrs, idx = _small_index(n=n)
    qv = make_vectors(12, 16, seed=4)
    ranges = selectivity_ranges(attrs, 12, 0.3, seed=5)
    si, sd, _ = idx.search(qv, ranges, k=8, ef=n, plan="scan")
    bi, bd, _ = idx.search(qv, ranges, k=8, ef=n, plan="beam")
    for q in range(12):
        assert set(si[q][si[q] >= 0].tolist()) == set(bi[q][bi[q] >= 0].tolist())
    fin = np.isfinite(sd)
    assert np.array_equal(fin, np.isfinite(bd))
    assert np.allclose(sd[fin], bd[fin], rtol=1e-3, atol=1e-3)


def test_mixed_strategy_batch_preserves_request_order():
    vecs, attrs, idx = _small_index(n=1024)
    nq = 20
    qv = make_vectors(nq, 16, seed=8)
    narrow = selectivity_ranges(attrs, nq // 2, 0.01, seed=6)
    wide = selectivity_ranges(attrs, nq // 2, 0.9, seed=7)
    ranges = np.empty((nq, 2), np.float32)
    ranges[0::2] = narrow                          # interleave strategies
    ranges[1::2] = wide
    ids, dists, st = idx.search(qv, ranges, k=5, ef=64, plan="auto")
    assert 0.0 < st["scan_frac"] < 1.0             # genuinely mixed batch
    for q in range(nq):                            # each row == its solo run
        one_i, one_d, _ = idx.search(qv[q:q + 1], ranges[q:q + 1], k=5,
                                     ef=64, plan="auto")
        assert np.array_equal(ids[q], one_i[0]), q
    for q in range(nq):                            # and respects its filter
        for i in ids[q]:
            if i >= 0:
                assert ranges[q, 0] <= attrs[i] <= ranges[q, 1]


def test_auto_plan_recall_not_worse_than_graph():
    vecs, attrs, idx = _small_index(n=1024)
    nq = 40
    qv = make_vectors(nq, 16, seed=3)
    ranges = selectivity_ranges(attrs, nq, 0.02, seed=9)
    order = np.argsort(attrs, kind="stable")
    gt_r, _ = ground_truth(vecs[order], attrs[order], qv, ranges, 10)
    gt = np.where(gt_r >= 0, order[np.maximum(gt_r, 0)], -1)
    rg = recall_at_k(idx.search(qv, ranges, k=10, ef=64, plan="graph")[0], gt)
    ra = recall_at_k(idx.search(qv, ranges, k=10, ef=64, plan="auto")[0], gt)
    assert ra >= rg - 1e-9


def test_cost_model_calibration_moves_estimates():
    vecs, attrs, idx = _small_index(n=1024)
    qv = make_vectors(16, 16, seed=2)
    ranges = selectivity_ranges(attrs, 16, 0.8, seed=2)   # all-beam batch
    idx.search(qv, ranges, k=5, ef=64, plan="auto")
    cm = idx.executor.planner.cost
    assert cm.beam_obs >= 1
    assert cm.ndist_per_ef > 0


# ------------------------------------------------------------------ engine stats
def test_engine_stats_reservoir_is_bounded():
    from repro.serving.engine import EngineStats
    st = EngineStats(reservoir_size=256)
    for i in range(10_000):
        st.record_latency(float(i % 100))
    assert len(st.latencies_ms) == 256
    assert st.lat_seen == 10_000
    s = st.summary()
    assert 25.0 < s["p50_ms"] < 75.0               # sane percentile estimate
    assert s["p99_ms"] <= 99.0


def test_engine_serves_with_planner():
    vecs, attrs, idx = _small_index(n=512)
    from repro.serving.engine import RFANNEngine
    eng = RFANNEngine(idx, k=5, ef=32, max_batch=16, max_wait_ms=5,
                      plan="auto")
    qv = make_vectors(24, 16, seed=6)
    rgs = np.concatenate([selectivity_ranges(attrs, 12, 0.01, seed=1),
                          selectivity_ranges(attrs, 12, 0.9, seed=2)])
    futs = [eng.submit(qv[i], rgs[i]) for i in range(24)]
    res = [f.result(timeout=120) for f in futs]
    eng.close()
    assert len(res) == 24 and all(r[0].shape == (5,) for r in res)
    assert eng.stats.scan_routed > 0
