"""Oracle-backed property tests for the streaming index.

Random interleaved insert / delete / query / compact sequences run against
a brute-force masked oracle over the live set.  Invariants checked on every
query batch:

* the returned id set is a subset of the live in-range points, with
  exactly ``min(k, |live ∩ range|)`` entries;
* a tombstoned (ever-deleted) id is never returned — exact, no tolerance;
* every returned point's recomputed f64 distance is within an epsilon of
  the oracle's k-th distance, and when the k/k+1 distance gap exceeds the
  float32-noise band the id set equals the oracle's top-k **exactly**
  (gap-aware so adversarially tied distances cannot flake);
* after a full compaction the streaming index answers every tested range
  id-identically to a from-scratch offline build on the same live set.

The seeded sweep (500+ steps) always runs; the hypothesis variant widens
the op-sequence space when the package is installed (``tests/_hyp`` shim).
"""
import numpy as np
import pytest

from repro.core.rfann import RNSGIndex
from repro.streaming import StreamingRFANN

from _hyp import given, settings, st


def _pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 1).bit_length()


class Oracle:
    """Brute-force masked ground truth over the live set (f64 distances)."""

    def __init__(self, vecs, attrs, ids):
        self.store = {int(i): (np.asarray(v, np.float64), float(a))
                      for i, v, a in zip(ids, vecs, attrs)}
        self.ever_deleted = set()

    def insert(self, ext_id, vec, attr):
        self.store[int(ext_id)] = (np.asarray(vec, np.float64), float(attr))

    def delete(self, ext_id):
        del self.store[int(ext_id)]
        self.ever_deleted.add(int(ext_id))

    def live_ids(self):
        return sorted(self.store)

    def range_topk(self, q, a, b):
        """All live in-range ids with ascending f64 distances."""
        ids = [i for i, (_, at) in self.store.items() if a <= at <= b]
        if not ids:
            return np.zeros(0, np.int64), np.zeros(0)
        ids = np.asarray(sorted(ids))
        d = np.array([((self.store[int(i)][0] - q) ** 2).sum() for i in ids])
        o = np.argsort(d, kind="stable")
        return ids[o], d[o]

    def dist(self, ext_id, q):
        return ((self.store[int(ext_id)][0] - q) ** 2).sum()


def check_batch(s: StreamingRFANN, oracle: Oracle, qv, ar, k, ef, plan):
    """Assert every invariant on one query batch (see module docstring)."""
    res = s.search(qv, ar, k=k, ef=ef, plan=plan)
    ids = np.asarray(res.ids)
    for qi in range(len(qv)):
        got = [int(i) for i in ids[qi] if i >= 0]
        q64 = np.asarray(qv[qi], np.float64)
        want_ids, want_d = oracle.range_topk(q64, ar[qi][0], ar[qi][1])
        m = len(want_ids)
        assert len(got) == min(k, m), (plan, got, want_ids[:k])
        assert len(set(got)) == len(got), f"duplicate ids: {got}"
        assert not (set(got) & oracle.ever_deleted), \
            f"tombstoned id returned: {set(got) & oracle.ever_deleted}"
        assert set(got) <= set(want_ids.tolist()), (plan, got, want_ids)
        if m == 0:
            continue
        dk = want_d[min(k, m) - 1]
        eps = 1e-3 * (1.0 + dk)
        for i in got:
            assert oracle.dist(i, q64) <= dk + eps, \
                (plan, i, oracle.dist(i, q64), dk)
        if m <= k or want_d[k] - dk > 2 * eps:      # unambiguous top-k
            assert set(got) == set(want_ids[:k].tolist()), \
                (plan, sorted(got), sorted(want_ids[:k].tolist()))


def _mk(rng, n0, d, **kw):
    vecs = rng.standard_normal((n0, d)).astype(np.float32)
    attrs = rng.random(n0).astype(np.float32)
    s = StreamingRFANN(vecs, attrs, m=8, ef_spatial=16, ef_attribute=24,
                       **kw)
    return s, Oracle(vecs, attrs, range(n0))


def _rand_range(rng):
    a, b = np.sort(rng.random(2).astype(np.float32))
    if rng.random() < 0.1:          # occasionally the full range
        a, b = np.float32(0.0), np.float32(1.0)
    return a, b


def test_seeded_interleaved_sweep():
    """500+ randomized interleaved steps vs the brute-force oracle —
    always on (no hypothesis dependency), fixed seed."""
    rng = np.random.default_rng(20260808)
    n0, d, k = 224, 10, 5
    s, oracle = _mk(rng, n0, d, max_delta=64)
    plans = ["scan", "auto", "scan", "auto", "graph"]
    steps = 520
    n_queries = 0
    for step in range(steps):
        r = rng.random()
        if r < 0.40:                                    # insert
            v = rng.standard_normal(d).astype(np.float32)
            a = float(rng.random())
            i = s.insert(v, a)
            oracle.insert(i, v, a)
        elif r < 0.62 and len(oracle.store) > 16:       # delete
            victim = int(rng.choice(oracle.live_ids()))
            s.delete(victim)
            oracle.delete(victim)
        elif r < 0.67:                                  # explicit compact
            s.compact(wait=True)
        else:                                           # query batch
            qv = rng.standard_normal((2, d)).astype(np.float32)
            ar = np.stack([_rand_range(rng) for _ in range(2)])
            plan = plans[n_queries % len(plans)]
            # covering ef (pow2: bounded retraces) makes graph/auto exact
            ef = _pow2(len(s._view.base_ids) + s._view.delta.count)
            check_batch(s, oracle, qv, ar, k, ef, plan)
            n_queries += 1
    assert n_queries >= 100
    assert s.compactions >= 1, "sweep never compacted"
    # sweep bookkeeping agrees with the oracle
    st_ = s.stats()
    assert st_["n_live"] == len(oracle.store)
    lv, la, li = s.live_items()
    assert set(li.tolist()) == set(oracle.live_ids())
    s.close()


def test_post_compaction_identity():
    """A fully compacted streaming index answers every tested range
    id-identically to a fresh offline build on the same live set."""
    rng = np.random.default_rng(99)
    n0, d, k = 200, 8, 7
    s, oracle = _mk(rng, n0, d, max_delta=10**9)
    for _ in range(60):
        v = rng.standard_normal(d).astype(np.float32)
        a = float(rng.random())
        oracle.insert(s.insert(v, a), v, a)
    for _ in range(50):
        victim = int(rng.choice(oracle.live_ids()))
        s.delete(victim)
        oracle.delete(victim)
    assert s.compact(wait=True)
    st_ = s.stats()
    assert st_["n_delta"] == 0 and st_["tombstones"] == 0
    lv, la, li = s.live_items()
    fresh = RNSGIndex.build(lv, la, m=8, ef_spatial=16, ef_attribute=24)
    qv = rng.standard_normal((16, d)).astype(np.float32)
    ar = np.stack([_rand_range(rng) for _ in range(16)])
    for plan in ("scan", "auto", "graph"):
        rs = s.search(qv, ar, k=k, ef=128, plan=plan)
        rf = fresh.search(qv, ar, k=k, ef=128, plan=plan)
        fresh_ext = np.where(np.asarray(rf.ids) >= 0,
                             li[np.maximum(np.asarray(rf.ids), 0)], -1)
        assert np.array_equal(np.asarray(rs.ids), fresh_ext), plan
    s.close()


def test_tombstones_survive_racing_compaction_reconcile():
    """Mutations that land *during* a rebuild are reconciled at the swap:
    deletes during the build win (tombstoned on the new base), inserts
    stay as the residual delta."""
    rng = np.random.default_rng(5)
    n0, d = 160, 8
    s, oracle = _mk(rng, n0, d, max_delta=10**9)
    for _ in range(24):
        v = rng.standard_normal(d).astype(np.float32)
        a = float(rng.random())
        oracle.insert(s.insert(v, a), v, a)
    # start the compaction, then race mutations in before it swaps by
    # driving the worker entry point synchronously on a captured view
    v0 = s._view
    post_ins, post_del = [], []
    for _ in range(6):
        v = rng.standard_normal(d).astype(np.float32)
        a = float(rng.random())
        i = s.insert(v, a)
        oracle.insert(i, v, a)
        post_ins.append(i)
    for _ in range(6):
        victim = int(rng.choice(oracle.live_ids()))
        s.delete(victim)
        oracle.delete(victim)
        post_del.append(victim)
    s._compacting.set()
    s._compact_run(v0)              # rebuild of v0 + reconciling swap
    st_ = s.stats()
    assert s.compactions == 1
    # deletes during the build are tombstones or physically gone
    lv, la, li = s.live_items()
    assert not (set(li.tolist()) & set(post_del))
    # inserts during the build survived (residual delta or folded base)
    assert set(post_ins) <= set(li.tolist())
    assert set(li.tolist()) == set(oracle.live_ids())
    qv = rng.standard_normal((4, d)).astype(np.float32)
    ar = np.stack([_rand_range(rng) for _ in range(4)])
    check_batch(s, oracle, qv, ar, 5, 512, "scan")
    check_batch(s, oracle, qv, ar, 5, 512, "graph")
    s.close()


OPS = st.lists(
    st.tuples(st.sampled_from(["ins", "del", "query", "compact"]),
              st.integers(0, 2**31 - 1)),
    min_size=6, max_size=36)


@settings(max_examples=12, deadline=None)
@given(ops=OPS)
def test_hypothesis_interleaved(ops):
    """Hypothesis-driven op sequences (skips when hypothesis is absent —
    the seeded sweep above covers the property regardless)."""
    rng = np.random.default_rng(2026)
    n0, d, k = 96, 8, 4
    s, oracle = _mk(rng, n0, d, max_delta=48)
    try:
        for op, seed in ops:
            r = np.random.default_rng(seed)
            if op == "ins":
                v = r.standard_normal(d).astype(np.float32)
                a = float(r.random())
                oracle.insert(s.insert(v, a), v, a)
            elif op == "del":
                if len(oracle.store) > 8:
                    victim = int(r.choice(oracle.live_ids()))
                    s.delete(victim)
                    oracle.delete(victim)
            elif op == "compact":
                s.compact(wait=True)
            else:
                qv = r.standard_normal((2, d)).astype(np.float32)
                ar = np.stack([_rand_range(r) for _ in range(2)])
                check_batch(s, oracle, qv, ar, k, 256, "scan")
        qv = rng.standard_normal((2, d)).astype(np.float32)
        ar = np.stack([_rand_range(rng) for _ in range(2)])
        check_batch(s, oracle, qv, ar, k, 256, "auto")
    finally:
        s.close()


def test_delta_only_and_empty_range():
    """Edge coverage: results entirely from the delta, and empty ranges."""
    rng = np.random.default_rng(11)
    d, k = 8, 5
    s, oracle = _mk(rng, 64, d, max_delta=10**9)
    # inserts clustered in an attribute band the base never saw
    for _ in range(20):
        v = rng.standard_normal(d).astype(np.float32)
        a = float(2.0 + rng.random())       # base attrs are in [0, 1)
        oracle.insert(s.insert(v, a), v, a)
    qv = rng.standard_normal((3, d)).astype(np.float32)
    ar = np.asarray([[2.0, 3.0]] * 3, np.float32)       # delta-only band
    check_batch(s, oracle, qv, ar, k, 128, "scan")
    ar_empty = np.asarray([[5.0, 6.0]] * 3, np.float32)  # nothing there
    res = s.search(qv, ar_empty, k=k, ef=128, plan="auto")
    assert (np.asarray(res.ids) == -1).all()
    s.close()
