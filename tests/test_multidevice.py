"""Multi-device tests (subprocess: these need XLA_FLAGS set before jax import,
which must not leak into the rest of the suite)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


@pytest.mark.slow
def test_sharded_train_step_moe_ep():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.registry import get_smoke_config
        from repro.models.lm import Model
        from repro.models.params import ShardPlan, logical_axes
        from repro.parallel.sharding import (make_act_sharder, set_mesh_compat,
                                             tree_shardings,
                                             batch_logical, spec_for_logical)
        from repro.launch.specs import concrete_batch
        from repro.training.train_step import build_train_step, init_train_state

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_smoke_config("llama4-maverick-400b-a17b")
        plan = ShardPlan(tp=2, fsdp=4)
        model = Model(cfg, plan, mesh=mesh, act_shard=make_act_sharder(mesh))
        state = init_train_state(model, jax.random.key(0))
        lax_tree = logical_axes(cfg, plan)
        psh = tree_shardings(lax_tree, model.param_shapes(), mesh)
        state = {"params": jax.device_put(state["params"], psh),
                 "opt": {"m": jax.device_put(state["opt"]["m"], psh),
                         "v": jax.device_put(state["opt"]["v"], psh),
                         "step": state["opt"]["step"]}}
        rng = np.random.default_rng(0)
        batch = concrete_batch(cfg, "train", 8, 16, rng)
        blog = batch_logical(cfg, "train")
        bsh = {k: NamedSharding(mesh, spec_for_logical(blog[k], v.shape, mesh))
               for k, v in batch.items()}
        batch = jax.device_put(batch, bsh)
        with set_mesh_compat(mesh):
            state2, m = jax.jit(build_train_step(model))(state, batch)
        assert np.isfinite(float(m["loss"])), m
        # MoE EP path must actually emit an all-to-all
        with set_mesh_compat(mesh):
            txt = jax.jit(build_train_step(model)).lower(state, batch).compile().as_text()
        assert "all-to-all" in txt, "expected EP all-to-all in HLO"
        print("OK", float(m["loss"]))
    """)
    assert "OK" in out


@pytest.mark.slow
def test_moe_ep_sharded_matches_local():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp, dataclasses
        from repro.configs.registry import get_smoke_config
        from repro.models.lm import Model
        from repro.models.params import ShardPlan, resolve_dims
        from repro.models.moe import moe_ffn
        from repro.parallel.sharding import set_mesh_compat
        cfg = dataclasses.replace(get_smoke_config("mixtral-8x7b"), dtype="float32")
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        dm = resolve_dims(cfg, ShardPlan(tp=2, fsdp=2))
        rng = np.random.default_rng(0)
        b, s, d = 4, 8, cfg.d_model
        x = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
        e, f = cfg.n_experts, cfg.d_ff
        p = {"router": jnp.asarray(rng.standard_normal((d, e)), jnp.float32),
             "w_in": jnp.asarray(rng.standard_normal((e, d, f)) * .1, jnp.float32),
             "w_gate": jnp.asarray(rng.standard_normal((e, d, f)) * .1, jnp.float32),
             "w_out": jnp.asarray(rng.standard_normal((e, f, d)) * .1, jnp.float32),
             "norm": jnp.ones((d,), jnp.float32)}
        y_local, _ = moe_ffn(x, p, cfg, dm, mesh=None)
        with set_mesh_compat(mesh):
            y_shard, _ = jax.jit(lambda x, p: moe_ffn(x, p, cfg, dm, mesh=mesh))(x, p)
        err = float(jnp.max(jnp.abs(y_local - y_shard)))
        assert err < 1e-4, err
        print("OK", err)
    """)
    assert "OK" in out


@pytest.mark.slow
def test_distributed_rfann_shard_map_matches_local():
    out = _run("""
        import numpy as np, jax
        from repro.data.ann import make_vectors, make_attrs, mixed_workload
        from repro.serving.distributed import DistributedRFANN
        vecs = make_vectors(1024, 8, seed=0); attrs = make_attrs(1024, seed=0)
        mesh = jax.make_mesh((8,), ("data",))
        qv = make_vectors(16, 8, seed=5)
        rg, _ = mixed_workload(attrs, 16, seed=1, levels=4)
        d_local = DistributedRFANN(vecs, attrs, n_shards=8, m=16,
                                   ef_spatial=16, ef_attribute=16)
        ids_a, d_a = d_local.search(qv, rg, k=5, ef=48)
        d_mesh = DistributedRFANN(vecs, attrs, n_shards=8, mesh=mesh, m=16,
                                  ef_spatial=16, ef_attribute=16)
        ids_b, d_b = d_mesh.search(qv, rg, k=5, ef=48)
        assert np.array_equal(ids_a, ids_b), (ids_a, ids_b)
        print("OK")
    """)
    assert "OK" in out


def test_distributed_delta_tombstone_parity_8_shards():
    """Streaming segments on the sharded paths (subprocess, 8 forced host
    devices): a rank-space tombstone mask threaded through ``live=`` must
    give identical merged top-k on the mesh and local paths, and merging
    either with the same brute-force delta segment through the shared
    ``merge_topk`` stays identical — with no tombstoned id ever surfacing."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.data.ann import make_vectors, make_attrs, mixed_workload
        from repro.search import merge_topk
        from repro.serving.distributed import DistributedRFANN
        from repro.streaming import DeltaView
        vecs = make_vectors(1024, 8, seed=0); attrs = make_attrs(1024, seed=0)
        rng = np.random.default_rng(3)
        live = rng.random(1024) > 0.2           # rank-space tombstones
        qv = make_vectors(12, 8, seed=5)
        rg, _ = mixed_workload(attrs, 12, seed=1, levels=4)
        mesh = jax.make_mesh((8,), ("data",))
        kw = dict(n_shards=8, m=16, ef_spatial=16, ef_attribute=16)
        d_local = DistributedRFANN(vecs, attrs, **kw)
        d_mesh = DistributedRFANN(vecs, attrs, mesh=mesh, **kw)
        # a delta segment of 64 fresh points, searched once and merged with
        # both paths' base results through the one shared merge_topk
        dv = make_vectors(64, 8, seed=9); da_ = make_attrs(64, seed=9)
        o = np.argsort(da_, kind="stable")
        delta = DeltaView(dv[o], da_[o],
                          np.arange(2048, 2048 + 64, dtype=np.int32)[o])
        order = np.argsort(attrs, kind="stable")
        dead = set(order[~live].tolist())
        for plan in ("graph", "auto"):
            ia, da = d_local.search(qv, rg, k=5, ef=64, plan=plan, live=live)
            ib, db = d_mesh.search(qv, rg, k=5, ef=64, plan=plan, live=live)
            assert np.array_equal(ia, ib), plan
            di, dd = delta.search(qv, rg, 5)
            merged = []
            for ids, ds in ((ia, da), (ib, db)):
                mi, _ = merge_topk(
                    jnp.asarray(np.stack([ids.astype(np.int32), di])),
                    jnp.asarray(np.stack([np.where(ids >= 0, ds, np.inf),
                                          dd])), 5)
                merged.append(np.asarray(mi))
            assert np.array_equal(merged[0], merged[1]), plan
            got = set(int(x) for x in merged[0].ravel() if x >= 0)
            assert not (got & dead), (plan, got & dead)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_async_local_dispatch_matches_sequential_8_shards():
    """Concurrency acceptance (subprocess, 8 forced host devices): the async
    local path — every shard's substrate dispatch enqueued before any block
    — must reproduce the sequential baseline's merged top-k exactly, on a
    mixed narrow/wide/degenerate workload under every plan, with a shared
    result cache giving bit-identical repeat batches on top.

    In-process twin (tier-1, smaller corpus, no subprocess):
    tests/test_async_cache.py::test_async_local_matches_sequential_8_shards.
    This copy runs the full-size workload in a clean interpreter so async
    scheduling is exercised without the rest of the suite's jit caches."""
    out = _run("""
        import numpy as np
        from repro.data.ann import make_vectors, make_attrs, selectivity_ranges
        from repro.search import SearchCache
        from repro.serving.distributed import DistributedRFANN
        vecs = make_vectors(1024, 16, seed=0); attrs = make_attrs(1024, seed=0)
        qv = make_vectors(24, 16, seed=5)
        s = np.sort(attrs)
        rg = np.concatenate([
            selectivity_ranges(attrs, 10, 0.01, seed=1),
            selectivity_ranges(attrs, 10, 0.5, seed=2),
            np.asarray([[s[5] + 1e-7, s[5] + 2e-7],      # globally empty
                        [s[17], s[17]],                  # single point
                        [s[3], s[40]],                   # one-shard clip
                        [s[0], s[-1]]], np.float32)])    # full span
        kw = dict(n_shards=8, m=16, ef_spatial=16, ef_attribute=16)
        d_seq = DistributedRFANN(vecs, attrs, async_dispatch=False, **kw)
        d_async = DistributedRFANN(vecs, attrs, async_dispatch=True, **kw)
        for plan in ("graph", "auto", "scan", "beam"):
            ia, da = d_seq.search(qv, rg, k=5, ef=48, plan=plan)
            ib, db = d_async.search(qv, rg, k=5, ef=48, plan=plan)
            assert np.array_equal(ia, ib), plan
            assert np.array_equal(da, db), plan
        cache = SearchCache(8 << 20)
        d_async.install_cache(cache)
        i1, d1 = d_async.search(qv, rg, k=5, ef=48, plan="auto")
        i2, d2 = d_async.search(qv, rg, k=5, ef=48, plan="auto")
        assert np.array_equal(i1, i2) and np.array_equal(d1, d2)
        assert cache.hits == 8 * len(rg), cache.snapshot()
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_production_mesh_shapes():
    out = _run("""
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        m2 = make_production_mesh(multi_pod=True)
        assert dict(m1.shape) == {"data": 16, "model": 16}
        assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
        print("OK")
    """, devices=512)
    assert "OK" in out


@pytest.mark.slow
def test_gpipe_pipeline_fwd_and_grad_parity():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.parallel.pipeline import gpipe
        from repro.parallel.sharding import set_mesh_compat
        mesh = jax.make_mesh((4,), ("pp",))
        S, M, B, D = 4, 8, 2, 16
        rng = np.random.default_rng(0)
        params = {"w": jnp.asarray(rng.standard_normal((S, D, D)) * .3, jnp.float32),
                  "b": jnp.asarray(rng.standard_normal((S, D)) * .1, jnp.float32)}
        x = jnp.asarray(rng.standard_normal((M, B, D)), jnp.float32)
        stage_fn = lambda p, h: jnp.tanh(h @ p["w"] + p["b"])
        pipe = gpipe(stage_fn, mesh, "pp", S, M)
        with set_mesh_compat(mesh):
            y = jax.jit(pipe)(params, x)
        ref = x
        for s in range(S):
            ref = jnp.tanh(ref @ params["w"][s] + params["b"][s])
        assert float(jnp.max(jnp.abs(y - ref))) < 1e-5
        loss_pipe = lambda p: jnp.sum(pipe(p, x) ** 2)
        def loss_ref(p):
            h = x
            for s in range(S):
                h = jnp.tanh(h @ p["w"][s] + p["b"][s])
            return jnp.sum(h ** 2)
        with set_mesh_compat(mesh):
            g1 = jax.jit(jax.grad(loss_pipe))(params)
        g2 = jax.grad(loss_ref)(params)
        err = max(float(jnp.max(jnp.abs(a - b)))
                  for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
        assert err < 1e-4, err
        print("OK", err)
    """, devices=4)
    assert "OK" in out
