"""Async + cached search substrate: cache hit/miss/eviction under the byte
budget, invalidation on index swap, async local-path parity with the
sequential baseline, and the engine's resolve/dispatch pipelining."""
import numpy as np
import pytest

from repro.core.rfann import RNSGIndex
from repro.data.ann import make_attrs, make_vectors, selectivity_ranges
from repro.search import SearchCache, SearchRequest
from repro.search.cache import CacheEntry, query_key
from repro.serving.distributed import DistributedRFANN
from repro.serving.engine import RFANNEngine


def _corpus(n=256, d=16, seed=0):
    return make_vectors(n, d, seed=seed), make_attrs(n, seed=seed)


def _index(n=256, d=16, seed=0):
    vecs, attrs = _corpus(n, d, seed)
    return RNSGIndex.build(vecs, attrs, m=16, ef_spatial=16,
                           ef_attribute=24), vecs, attrs


# ------------------------------------------------------------ cache mechanics
def test_cache_hit_miss_counters_through_search():
    ix, vecs, attrs = _index()
    cache = SearchCache(max_bytes=1 << 20)
    ix.install_cache(cache)
    qv = make_vectors(8, 16, seed=7)
    rg = selectivity_ranges(attrs, 8, 0.2, seed=11)
    r1 = ix.search(qv, rg, k=5, ef=64, plan="auto")
    assert cache.misses == 8 and cache.hits == 0
    assert r1.stats["cache_hits"] == 0
    r2 = ix.search(qv, rg, k=5, ef=64, plan="auto")
    assert cache.hits == 8 and r2.stats["cache_hits"] == 8
    # hits are the stored bytes verbatim
    assert np.array_equal(r1.ids, r2.ids)
    assert np.array_equal(r1.dists, r2.dists)
    # a different k misses (k is part of the key)
    ix.search(qv, rg, k=3, ef=64, plan="auto")
    assert cache.misses == 16
    # partial-hit batch: old rows hit, new rows miss, request order kept
    qv2 = np.concatenate([qv[:4], make_vectors(4, 16, seed=99)])
    r3 = ix.search(qv2, rg, k=5, ef=64, plan="auto")
    assert r3.stats["cache_hits"] == 4
    assert np.array_equal(r3.ids[:4], r1.ids[:4])


def test_cache_miss_path_batch_dedup():
    """Identical rows inside one dynamic batch dispatch ONCE on the miss
    path: the duplicates fan out from the single executed result, are
    bit-identical to it, and only one entry lands in the cache."""
    ix, vecs, attrs = _index()
    cache = SearchCache(max_bytes=1 << 20)
    qv1 = make_vectors(3, 16, seed=7)
    rg1 = selectivity_ranges(attrs, 3, 0.2, seed=11)
    # rows 0..2 unique; rows 3..6 duplicate row 0 / row 1
    qv = np.concatenate([qv1, qv1[:2], qv1[:2]])
    rg = np.concatenate([rg1, rg1[:2], rg1[:2]])
    base = ix.search(qv, rg, k=5, ef=64, plan="auto")       # uncached oracle
    ix.install_cache(cache)
    res = ix.search(qv, rg, k=5, ef=64, plan="auto")
    assert res.stats["batch_dedup"] == 4
    assert cache.dedup_hits == 4
    assert len(cache) == 3                  # only the unique keys stored
    assert np.array_equal(res.ids, base.ids)
    assert np.array_equal(res.ids[3], res.ids[0])
    assert np.array_equal(res.dists[4], res.dists[1])
    # per-row stats fanned out with the result
    assert res.stats["strategy"][3] == res.stats["strategy"][0]
    # second pass: every row (duplicates included) is a plain hit
    r2 = ix.search(qv, rg, k=5, ef=64, plan="auto")
    assert r2.stats["cache_hits"] == 7
    assert np.array_equal(r2.ids, base.ids)
    ix.install_cache(None)


def test_cache_eviction_under_byte_budget():
    k = 5
    entry_bytes = CacheEntry(np.zeros(k, np.int32), np.zeros(k, np.float32),
                             {"hops": 0, "ndist": 0, "strategy": 0}).nbytes
    cache = SearchCache(max_bytes=2 * entry_bytes)      # room for exactly 2
    q = np.arange(4, dtype=np.float32)

    def key(i):
        return query_key(q + i, 0, 10, k, 64, "auto")

    def entry():
        return CacheEntry(np.zeros(k, np.int32), np.zeros(k, np.float32),
                          {"hops": 0, "ndist": 0, "strategy": 0})

    cache.store(key(0), entry())
    cache.store(key(1), entry())
    assert len(cache) == 2 and cache.evictions == 0
    cache.store(key(2), entry())                        # evicts LRU = key(0)
    assert len(cache) == 2 and cache.evictions == 1
    assert cache.bytes <= cache.max_bytes
    assert cache.lookup(key(0)) is None                 # evicted
    assert cache.lookup(key(1)) is not None
    # lookup refreshed key(1): storing another entry now evicts key(2)
    cache.store(key(3), entry())
    assert cache.lookup(key(2)) is None
    assert cache.lookup(key(1)) is not None
    # an entry larger than the whole budget is refused, not thrashed
    big = CacheEntry(np.zeros(4096, np.int32), np.zeros(4096, np.float32), {})
    cache.store(key(4), big)
    assert cache.lookup(key(4)) is None and len(cache) == 2


def test_cache_invalidation_on_index_swap():
    ix1, _, attrs = _index(seed=0)
    ix2, _, _ = _index(seed=1)          # different corpus, same shapes
    qv = make_vectors(6, 16, seed=7)
    rg = selectivity_ranges(attrs, 6, 0.3, seed=11)
    want2 = ix2.search(qv, rg, k=5, ef=64, plan="auto")     # uncached truth

    eng = RFANNEngine(ix1, k=5, ef=64, max_batch=8, max_wait_ms=5,
                      plan="auto", cache_bytes=1 << 20)
    futs = [eng.submit(qv[i], rg[i]) for i in range(6)]
    res1 = [f.result(timeout=120) for f in futs]
    assert len(eng.cache) > 0
    eng.swap_index(ix2)
    assert eng.cache.invalidations == 1 and len(eng.cache) == 0
    futs = [eng.submit(qv[i], rg[i]) for i in range(6)]
    res2 = [f.result(timeout=120) for f in futs]
    eng.close()
    # post-swap answers come from ix2, not stale ix1 rows
    for i, r in enumerate(res2):
        assert np.array_equal(r.ids, want2.ids[i]), i
    assert any(not np.array_equal(a.ids, b.ids)
               for a, b in zip(res1, res2))    # the corpora really differ


def test_invalidate_epoch_fences_in_flight_stores():
    """A dispatch that split before invalidate() must not repopulate the
    cache afterwards (the swap_index race): its stores carry the old epoch
    and are dropped under the store lock."""
    ix, vecs, attrs = _index()
    cache = SearchCache(max_bytes=1 << 20)
    ix.install_cache(cache)
    qv = make_vectors(4, 16, seed=7)
    rg = selectivity_ranges(attrs, 4, 0.2, seed=11)
    lo, hi = ix.rank_range(rg)
    # dispatch (split happens here, capturing the epoch) ...
    p = ix.substrate.dispatch(SearchRequest(
        queries=qv, lo=lo, hi=hi, k=5, ef=32, strategy="auto"))
    # ... invalidate while the batch is "in flight" ...
    cache.invalidate()
    res = p.result()                    # finalize stores with the old epoch
    assert res.ids.shape == (4, 5)      # the result itself is still served
    assert len(cache) == 0              # but nothing repopulated the cache
    # and direct late stores are fenced the same way
    cache.store_batch([query_key(qv[i], lo[i], hi[i], 5, 32, "auto")
                       for i in range(4)], res, epoch=cache.epoch - 1)
    assert len(cache) == 0


def test_distributed_local_stats_aggregate():
    """The distributed local path must surface cache_hits / scan_frac in
    its merged SearchResult (the engine's monitoring reads them)."""
    vecs, attrs = _corpus(512, 16, seed=3)
    dist = DistributedRFANN(vecs, attrs, n_shards=4, m=16, ef_spatial=16,
                            ef_attribute=16)
    cache = SearchCache(1 << 20)
    dist.install_cache(cache)
    qv = make_vectors(8, 16, seed=5)
    rg = selectivity_ranges(attrs, 8, 0.3, seed=6)
    lo, hi = dist.rank_range(rg)
    r1 = dist.search_ranks(qv, lo, hi, k=5, ef=48, plan="auto")
    assert r1.stats["cache_hits"] == 0 and "scan_frac" in r1.stats
    r2 = dist.search_ranks(qv, lo, hi, k=5, ef=48, plan="auto")
    # every shard hit every row -> normalized count = the full batch
    assert r2.stats["cache_hits"] == 8
    assert np.array_equal(r1.ids, r2.ids)


# --------------------------------------------------------- async local path
def test_async_local_matches_sequential_8_shards():
    """The async local path (dispatch every shard before blocking any) must
    produce the sequential loop's merged top-k exactly, for every plan."""
    vecs, attrs = _corpus(512, 16, seed=3)
    kw = dict(n_shards=8, m=16, ef_spatial=16, ef_attribute=16)
    d_seq = DistributedRFANN(vecs, attrs, async_dispatch=False, **kw)
    d_async = DistributedRFANN(vecs, attrs, async_dispatch=True, **kw)
    qv = make_vectors(16, 16, seed=5)
    s = np.sort(attrs)
    rg = np.concatenate([
        selectivity_ranges(attrs, 6, 0.01, seed=1),      # narrow
        selectivity_ranges(attrs, 6, 0.5, seed=2),       # wide
        np.asarray([[s[5] + 1e-7, s[5] + 2e-7],          # globally empty
                    [s[17], s[17]],                      # single point
                    [s[3], s[40]],                       # one-shard clip
                    [s[0], s[-1]]], np.float32)])        # full span
    for plan in ("graph", "auto", "scan", "beam"):
        ia, da = d_seq.search(qv, rg, k=5, ef=48, plan=plan)
        ib, db = d_async.search(qv, rg, k=5, ef=48, plan=plan)
        assert np.array_equal(ia, ib), plan
        assert np.array_equal(da, db), plan


def test_pending_search_is_idempotent_and_lazy():
    ix, vecs, attrs = _index()
    qv = make_vectors(4, 16, seed=7)
    rg = selectivity_ranges(attrs, 4, 0.2, seed=11)
    lo, hi = ix.rank_range(rg)
    p = ix.substrate.dispatch(SearchRequest(
        queries=qv, lo=lo, hi=hi, k=5, ef=32, strategy="auto"))
    r1 = p.result()
    assert p.result() is r1                      # idempotent
    want = ix.search(qv, rg, k=5, ef=32, plan="auto")
    assert np.array_equal(r1.ids, want.ids)


# ------------------------------------------------------- engine pipelining
def test_engine_pipelining_smoke():
    """Two-stage engine: many small batches flow through the resolver ->
    dispatcher hand-off; every future resolves with the right shape and the
    same answers a direct search gives; repeat submissions hit the cache."""
    ix, vecs, attrs = _index(512, 16, seed=4)
    eng = RFANNEngine(ix, k=5, ef=32, max_batch=8, max_wait_ms=2,
                      plan="auto", cache_bytes=1 << 20, pipeline_depth=2)
    qv = make_vectors(32, 16, seed=5)
    rg = selectivity_ranges(attrs, 32, 0.4, seed=6)
    futs = [eng.submit(qv[i], rg[i]) for i in range(32)]
    rows = [f.result(timeout=120) for f in futs]
    want = ix.search(qv, rg, k=5, ef=32, plan="auto")
    for i, r in enumerate(rows):
        assert r.ids.shape == (5,)
        assert np.array_equal(r.ids, want.ids[i]), i
    # second wave: served from the cache, still correct
    futs = [eng.submit(qv[i], rg[i]) for i in range(32)]
    rows2 = [f.result(timeout=120) for f in futs]
    assert eng.stats.cache_hits >= 32
    for a, b in zip(rows, rows2):
        assert np.array_equal(a.ids, b.ids)
        assert np.array_equal(a.dists, b.dists)
    assert eng.stats.served == 64 and eng.stats.batches >= 2
    summ = eng.stats.summary()
    assert 0.0 < summ["cache_hit_frac"] <= 1.0
    eng.close()
