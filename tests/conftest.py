# NOTE: do NOT set XLA_FLAGS here — smoke tests and benches must see 1 device;
# multi-device tests spawn subprocesses (tests/test_multidevice.py) and the
# dry-run sets its own flags as its first import action.
import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
