"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels.ops import gather_dist, gather_topk, l2dist
from repro.kernels.ref import gather_dist_ref, gather_topk_ref, l2dist_ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("q,n,d", [
    (1, 1, 1), (4, 7, 3), (128, 128, 128), (128, 256, 64),
    (100, 300, 130), (257, 129, 515), (33, 1000, 96),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_l2dist_shapes_dtypes(q, n, d, dtype):
    a = jnp.asarray(RNG.standard_normal((q, d)), dtype)
    b = jnp.asarray(RNG.standard_normal((n, d)), dtype)
    got = l2dist(a, b)
    want = l2dist_ref(a, b)
    tol = 1e-3 if dtype == jnp.float32 else 0.15
    assert got.shape == (q, n)
    assert float(jnp.max(jnp.abs(got - want))) < tol * max(1.0, d / 64)


@pytest.mark.parametrize("n,m,d", [(50, 8, 16), (1000, 32, 64), (77, 5, 130),
                                   (8, 64, 256)])
def test_gather_dist_shapes(n, m, d):
    x = jnp.asarray(RNG.standard_normal((n, d)), jnp.float32)
    ids = jnp.asarray(RNG.integers(-2, n + 2, m), jnp.int32)   # incl. OOB
    q = jnp.asarray(RNG.standard_normal(d), jnp.float32)
    got = gather_dist(x, ids, q)
    want = gather_dist_ref(x, ids, q)
    assert np.allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,m,d,k", [
    (50, 8, 16, 5), (1000, 32, 64, 10), (77, 5, 130, 8), (8, 64, 256, 3),
    (200, 1, 7, 4), (128, 200, 32, 10), (300, 130, 24, 128),
])
def test_gather_topk_matches_ref(n, m, d, k):
    """Blocked gather+top-k kernel (interpret mode) vs the jnp oracle:
    masked (negative) ids never enter the top-k, ids come back sorted by
    ascending distance with ties toward the lower input index, pads are
    (-1, +inf).  Covers tile tails (m not a tile multiple) and k up to the
    128-lane row."""
    x = jnp.asarray(RNG.standard_normal((n, d)), jnp.float32)
    ids = jnp.asarray(RNG.integers(0, n, m), jnp.int32)
    ids = jnp.where(jnp.asarray(RNG.random(m)) < 0.3, -1, ids)  # masked rows
    q = jnp.asarray(RNG.standard_normal(d), jnp.float32)
    gi, gd = gather_topk(x, ids, q, k=k)
    ri, rd = gather_topk_ref(x, ids, q, k=k)
    assert np.array_equal(np.asarray(gi), np.asarray(ri))
    fin = np.isfinite(np.asarray(rd))
    assert np.allclose(np.asarray(gd)[fin], np.asarray(rd)[fin],
                       rtol=1e-4, atol=1e-4)
    assert not np.isfinite(np.asarray(gd)[~fin]).any()


def test_gather_topk_all_masked():
    x = jnp.asarray(RNG.standard_normal((10, 4)), jnp.float32)
    gi, gd = gather_topk(x, jnp.full(6, -1, jnp.int32),
                         jnp.zeros(4, jnp.float32), k=4)
    assert (np.asarray(gi) == -1).all()
    assert not np.isfinite(np.asarray(gd)).any()


def test_gather_topk_rejects_oversized_k():
    from repro.kernels.gather_dist import gather_topk_pallas
    x = jnp.zeros((500, 8), jnp.float32)
    ids = jnp.zeros(400, jnp.int32)
    with pytest.raises(ValueError, match="running top-k"):
        gather_topk_pallas(x, ids, jnp.zeros(8, jnp.float32), k=200,
                           interpret=True)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 40), st.integers(1, 40), st.integers(1, 70),
       st.integers(0, 2**31 - 1))
def test_l2dist_property(q, n, d, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((q, d)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    got = np.asarray(l2dist(a, b))
    want = np.asarray(l2dist_ref(a, b))
    assert got.shape == want.shape
    assert np.allclose(got, want, rtol=1e-3, atol=1e-3)
    assert (got >= 0).all()


def test_l2dist_zero_distance_on_identical_rows():
    x = jnp.asarray(RNG.standard_normal((32, 48)), jnp.float32)
    dmat = np.asarray(l2dist(x, x))
    assert np.allclose(np.diag(dmat), 0.0, atol=1e-4)
