"""Model zoo: per-arch smoke (reduced config — forward/train step, shapes, no
NaNs), prefill/decode consistency, attention & SSD & MoE oracles."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import get_config, get_smoke_config, list_archs
from repro.launch.specs import concrete_batch
from repro.models.attention import flash_attention
from repro.models.lm import Model
from repro.models.moe import _moe_local
from repro.models.params import ShardPlan
from repro.models.ssm import ssd_chunked
from repro.kernels.ref import flash_attention_ref

RNG = np.random.default_rng(0)
ARCHS = list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = concrete_batch(cfg, "train", 2, 32, RNG)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode_consistency(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    B, S = 2, 16
    batch = concrete_batch(cfg, "prefill", B, S, RNG)
    pre = jax.jit(lambda p, b: model.prefill(p, b, cache_len=S + 4))
    dec = jax.jit(model.decode)
    cache, logits = pre(params, batch)
    assert logits.shape[0] == B
    nxt = jnp.argmax(logits[:, :cfg.vocab_size], -1).astype(jnp.int32)
    l_dec, cache = dec(params, cache, jnp.asarray(S, jnp.int32), nxt)
    toks2 = jnp.concatenate([batch["tokens"], nxt[:, None]], axis=1)
    _, l_full = pre(params, dict(batch, tokens=toks2))
    assert float(jnp.max(jnp.abs(l_dec - l_full))) < 1e-3, arch


def test_smoke_loss_decreases_under_training():
    from repro.launch.train import main as train_main
    state, losses = train_main(["--arch", "qwen1.5-4b", "--steps", "30",
                                "--batch", "4", "--seq", "64",
                                "--log-every", "1000"])
    assert losses[-1] < losses[0] - 0.1, losses[::10]


def test_shape_applicability_contract():
    cells = {(a, s): SHAPES[s].applicable(get_config(a))
             for a in ARCHS for s in SHAPES}
    assert sum(1 for v in cells.values() if v) == 32        # 40 - 8 long skips
    assert cells[("mamba2-780m", "long_500k")]
    assert cells[("jamba-1.5-large-398b", "long_500k")]
    assert not cells[("llama3-8b", "long_500k")]


# ---------------------------------------------------------------- attention
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("chunks", [(64, 64), (16, 32), (128, 8)])
def test_flash_attention_matches_ref(causal, chunks):
    B, S, H, hd = 2, 50, 4, 16
    q = jnp.asarray(RNG.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, H, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, H, hd)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, q_chunk=chunks[0],
                          kv_chunk=chunks[1])
    want = flash_attention_ref(q, k, v, causal=causal)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-3


def test_flash_attention_unroll_and_blockskip_match_scan():
    B, S, H, hd = 1, 64, 2, 8
    q = jnp.asarray(RNG.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, H, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, H, hd)), jnp.float32)
    base = flash_attention(q, k, v, q_chunk=16, kv_chunk=16)
    unr = flash_attention(q, k, v, q_chunk=16, kv_chunk=16, unroll=True)
    skip = flash_attention(q, k, v, q_chunk=16, kv_chunk=16, unroll=True,
                           block_skip=True)
    assert float(jnp.max(jnp.abs(base - unr))) < 1e-5
    assert float(jnp.max(jnp.abs(base - skip))) < 1e-5


def test_flash_attention_gqa_and_window():
    B, S, H, Kh, hd = 1, 40, 8, 2, 8
    q = jnp.asarray(RNG.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, Kh, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, Kh, hd)), jnp.float32)
    kr = jnp.repeat(k, H // Kh, axis=2)
    vr = jnp.repeat(v, H // Kh, axis=2)
    got = flash_attention(q, k, v, q_chunk=16, kv_chunk=16)
    want = flash_attention_ref(q, kr, vr, causal=True)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-3
    # sliding window == explicit mask reference
    w = 8
    gotw = flash_attention(q, kr, vr, window=w, q_chunk=16, kv_chunk=16)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / np.sqrt(hd)
    i = jnp.arange(S)
    mask = (i[None, :] <= i[:, None]) & (i[None, :] > i[:, None] - w)
    logits = jnp.where(mask[None, None], logits, -1e30)
    wantw = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(logits, -1), vr)
    assert float(jnp.max(jnp.abs(gotw - wantw))) < 1e-3


# ---------------------------------------------------------------- SSD oracle
def _ssd_sequential(x, dt, a, bm, cm):
    """Naive state-space recurrence oracle."""
    b, s, h, p = x.shape
    n = bm.shape[-1]
    state = np.zeros((b, h, p, n), np.float32)
    ys = []
    for t in range(s):
        da = np.exp(dt[:, t] * a)                                  # (B,H)
        state = state * da[:, :, None, None] + np.einsum(
            "bhp,bn->bhpn", x[:, t] * dt[:, t][..., None], bm[:, t])
        ys.append(np.einsum("bhpn,bn->bhp", state, cm[:, t]))
    return np.stack(ys, 1), state


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_ssd_chunked_matches_sequential(chunk):
    b, s, h, p, n = 2, 32, 3, 4, 5
    x = RNG.standard_normal((b, s, h, p)).astype(np.float32)
    dt = (0.1 + RNG.random((b, s, h))).astype(np.float32)
    a = -(0.5 + RNG.random(h)).astype(np.float32)
    bm = RNG.standard_normal((b, s, n)).astype(np.float32)
    cm = RNG.standard_normal((b, s, n)).astype(np.float32)
    y, st = ssd_chunked(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(a),
                        jnp.asarray(bm), jnp.asarray(cm), chunk=chunk)
    y_ref, st_ref = _ssd_sequential(x, dt, a, bm, cm)
    assert np.allclose(np.asarray(y), y_ref, rtol=1e-3, atol=1e-3)
    assert np.allclose(np.asarray(st), st_ref, rtol=1e-3, atol=1e-3)


def test_ssd_unroll_matches_scan():
    b, s, h, p, n = 1, 24, 2, 3, 4
    x = RNG.standard_normal((b, s, h, p)).astype(np.float32)
    dt = (0.1 + RNG.random((b, s, h))).astype(np.float32)
    a = -(0.5 + RNG.random(h)).astype(np.float32)
    bm = RNG.standard_normal((b, s, n)).astype(np.float32)
    cm = RNG.standard_normal((b, s, n)).astype(np.float32)
    y1, s1 = ssd_chunked(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(a),
                         jnp.asarray(bm), jnp.asarray(cm), chunk=8)
    y2, s2 = ssd_chunked(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(a),
                         jnp.asarray(bm), jnp.asarray(cm), chunk=8, unroll=True)
    assert np.allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    assert np.allclose(np.asarray(s1), np.asarray(s2), atol=1e-5)


# ---------------------------------------------------------------- MoE oracle
def test_moe_sort_dispatch_matches_dense_oracle():
    t, d, f, e, k = 64, 8, 16, 4, 2
    xt = jnp.asarray(RNG.standard_normal((t, d)), jnp.float32)
    router = jnp.asarray(RNG.standard_normal((d, e)), jnp.float32)
    w_in = jnp.asarray(RNG.standard_normal((e, d, f)) * 0.1, jnp.float32)
    w_gate = jnp.asarray(RNG.standard_normal((e, d, f)) * 0.1, jnp.float32)
    w_out = jnp.asarray(RNG.standard_normal((e, f, d)) * 0.1, jnp.float32)
    # capacity_factor = e ⇒ no drops ⇒ must equal the dense oracle
    y, aux = _moe_local(xt, router, w_in, w_gate, w_out, k=k, cf=float(e))
    probs = jax.nn.softmax(xt @ router, -1)
    gates, eidx = jax.lax.top_k(probs, k)
    gates = gates / jnp.sum(gates, -1, keepdims=True)
    dense = jnp.zeros_like(xt)
    for kk in range(k):
        for ee in range(e):
            sel = (eidx[:, kk] == ee)
            h = jax.nn.silu(xt @ w_gate[ee]) * (xt @ w_in[ee])
            yo = h @ w_out[ee]
            dense = dense + jnp.where(sel[:, None], gates[:, kk:kk + 1] * yo, 0)
    assert float(jnp.max(jnp.abs(y - dense))) < 1e-4
