"""Quantized scoring path (int8/bf16 + exact f32 rerank): corpus artifacts,
kernel parity vs the jnp oracles (interpret mode), the rerank exactness
contract, end-to-end strategy/mesh/engine parity, per-precision cache keys +
TTL/epoch staleness, per-precision cost calibration, the shared benchmark
``recall_at_k``, and uniform SearchRequest validation messages."""
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

ROOT = Path(__file__).resolve().parent.parent
if str(ROOT) not in sys.path:           # benchmarks/ is a namespace package
    sys.path.insert(0, str(ROOT))

from benchmarks.common import recall_at_k as bench_recall_at_k  # noqa: E402
from repro.core.beam import rerank_pool  # noqa: E402
from repro.core.rfann import RNSGIndex  # noqa: E402
from repro.data.ann import (make_attrs, make_vectors,  # noqa: E402
                            selectivity_ranges)
from repro.kernels.ops import (gather_dist, gather_rerank,  # noqa: E402
                               gather_topk, range_scan)
from repro.kernels.quantize import (PRECISIONS, RERANK_CAP,  # noqa: E402
                                    dequantize, quantize_corpus,
                                    rerank_depth, sort_candidates)
from repro.kernels.ref import (gather_dist_ref, gather_rerank_ref,  # noqa: E402
                               gather_topk_ref, range_scan_ref)
from repro.planner import QueryPlanner  # noqa: E402
from repro.planner.cost import PRECISION_PRIOR, CostModel  # noqa: E402
from repro.search import SearchCache, SearchRequest, query_key  # noqa: E402
from repro.search.cache import CacheEntry  # noqa: E402

RNG = np.random.default_rng(0)
QUANT = ("int8", "bf16")


def _padded(n, d, tb=128, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    n_pad = -(-n // tb) * tb
    d_pad = -(-d // 128) * 128
    xp = np.zeros((n_pad, d_pad), np.float32)
    xp[:n, :d] = x
    return x, xp, d_pad


def _quant_ops(xp, precision):
    """(scoring array, scale-or-None) as the kernels consume them."""
    qc = quantize_corpus(jnp.asarray(xp), precision)
    return qc.data, qc.scale


# ------------------------------------------------------------ corpus artifact
def test_int8_roundtrip_error_bound():
    x = RNG.standard_normal((200, 17)).astype(np.float32) * 3.0
    x[:, 5] = 0.0                                   # all-zero dimension
    qc = quantize_corpus(jnp.asarray(x), "int8")
    assert qc.data.dtype == jnp.int8 and qc.scale.shape == (17,)
    err = np.abs(np.asarray(dequantize(qc)) - x)
    bound = np.asarray(qc.scale)[None, :] * 0.5 + 1e-6
    assert (err <= bound).all()
    assert (np.asarray(dequantize(qc))[:, 5] == 0.0).all()   # exact zeros


def test_bf16_corpus_and_bytes():
    x = RNG.standard_normal((64, 32)).astype(np.float32)
    b = quantize_corpus(jnp.asarray(x), "bf16")
    i = quantize_corpus(jnp.asarray(x), "int8")
    assert b.data.dtype == jnp.bfloat16 and b.scale is None
    assert b.bytes_per_vector == 64 and i.bytes_per_vector == 32   # vs 128
    with pytest.raises(ValueError, match="invalid precision"):
        quantize_corpus(jnp.asarray(x), "f16")


def test_sort_candidates_pads_last():
    ids = jnp.asarray([[7, -1, 3, 9, -1], [0, 2, 1, -1, 5]], jnp.int32)
    got = np.asarray(sort_candidates(ids))
    assert got.tolist() == [[3, 7, 9, -1, -1], [0, 1, 2, 5, -1]]


def test_rerank_depth_clamps():
    assert rerank_depth(10, 64) == RERANK_CAP       # 4*64 hits the lane cap
    assert rerank_depth(10, 8) == 32                # ~4*ef regime
    assert rerank_depth(10, 1) == 10                # never below k
    assert rerank_depth(200, 8) == 200              # k beats the cap
    assert rerank_depth(10, 64, cap=64) == 64       # caller-tightened cap


# ------------------------------------------------- kernel parity (interpret)
@pytest.mark.parametrize("precision", QUANT)
def test_gather_kernels_quantized_match_ref(precision):
    """gather_dist / gather_topk scoring a quantized corpus (with the int8
    scale dequantized in VMEM) must match the jnp oracle bit-for-bit on ids
    and to f32 tolerance on distances — masked ids included."""
    n, m, d, k = 200, 37, 48, 9
    x = RNG.standard_normal((n, d)).astype(np.float32)
    data, scale = _quant_ops(x, precision)
    ids = jnp.asarray(RNG.integers(0, n, m), jnp.int32)
    ids = jnp.where(jnp.asarray(RNG.random(m)) < 0.3, -1, ids)
    q = jnp.asarray(RNG.standard_normal(d), jnp.float32)
    got = gather_dist(data, jnp.maximum(ids, 0), q, scale=scale)
    want = gather_dist_ref(data, jnp.maximum(ids, 0), q, scale=scale)
    assert np.allclose(got, want, rtol=1e-3, atol=1e-3)
    gi, gd = gather_topk(data, ids, q, k=k, scale=scale)
    ri, rd = gather_topk_ref(data, ids, q, k=k, scale=scale)
    assert np.array_equal(np.asarray(gi), np.asarray(ri))
    fin = np.isfinite(np.asarray(rd))
    assert np.allclose(np.asarray(gd)[fin], np.asarray(rd)[fin],
                       rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("precision", QUANT)
def test_range_scan_quantized_matches_ref(precision):
    n, d, q, bucket, k = 900, 40, 9, 256, 7
    _, xp, d_pad = _padded(n, d)
    data, scale = _quant_ops(xp, precision)
    starts = RNG.integers(0, n, q).astype(np.int32)
    lens = np.minimum(RNG.integers(0, bucket + 1, q),
                      n - starts).astype(np.int32)
    lens[0] = 0                                     # empty window
    qv = np.zeros((q, d_pad), np.float32)
    qv[:, :d] = RNG.standard_normal((q, d)).astype(np.float32)
    got_i, got_d = range_scan(data, jnp.asarray(starts), jnp.asarray(lens),
                              jnp.asarray(qv), bucket=bucket, k=k,
                              scale=scale)
    ref_i, ref_d = range_scan_ref(data, jnp.asarray(starts),
                                  jnp.asarray(lens), jnp.asarray(qv),
                                  bucket=bucket, k=k, scale=scale)
    assert np.array_equal(np.asarray(got_i), np.asarray(ref_i))
    gd, rd = np.asarray(got_d), np.asarray(ref_d)
    mask = np.isfinite(rd)
    assert np.array_equal(mask, np.isfinite(gd))
    assert np.allclose(gd[mask], rd[mask], rtol=1e-3, atol=1e-3)


def test_gather_rerank_matches_ref():
    n, d, q, m, k = 300, 24, 11, 40, 8
    x = jnp.asarray(RNG.standard_normal((n, d)), jnp.float32)
    ids = RNG.integers(0, n, (q, m)).astype(np.int32)
    ids[RNG.random((q, m)) < 0.25] = -1             # sparse survivor lists
    ids[3] = -1                                     # one fully-empty pool
    qv = jnp.asarray(RNG.standard_normal((q, d)), jnp.float32)
    gi, gd = gather_rerank(x, jnp.asarray(ids), qv, k=k)
    ri, rd = gather_rerank_ref(x, jnp.asarray(ids), qv, k=k)
    assert np.array_equal(np.asarray(gi), np.asarray(ri))
    fin = np.isfinite(np.asarray(rd))
    assert np.allclose(np.asarray(gd)[fin], np.asarray(rd)[fin],
                       rtol=1e-4, atol=1e-4)


# -------------------------------------------------------- rerank exactness
@pytest.mark.parametrize("precision", QUANT)
@pytest.mark.parametrize("use_kernel", [False, True])
def test_scan_rerank_restores_exact_f32_topk(precision, use_kernel):
    """The tentpole invariant: quantized scan keeping ``rerank_depth``
    survivors + f32 rerank returns the exact f32 top-k id set — empty and
    sub-k slices included."""
    n, d, k, ef, bucket = 700, 24, 7, 16, 256
    _, xp, d_pad = _padded(n, d, seed=3)
    data, scale = _quant_ops(xp, precision)
    starts = np.asarray([0, 123, 600, 42, 42], np.int32)
    lens = np.asarray([64, 200, 100, 0, 3], np.int32)   # empty + sub-k rows
    lens = np.minimum(lens, n - starts)
    qv = np.zeros((len(starts), d_pad), np.float32)
    qv[:, :d] = RNG.standard_normal((len(starts), d)).astype(np.float32)
    f32_i, f32_d = range_scan(jnp.asarray(xp), jnp.asarray(starts),
                              jnp.asarray(lens), jnp.asarray(qv),
                              bucket=bucket, k=k)
    rq = rerank_depth(k, ef)
    q_i, _ = range_scan(data, jnp.asarray(starts), jnp.asarray(lens),
                        jnp.asarray(qv), bucket=bucket, k=rq, scale=scale)
    ids, dists = rerank_pool(jnp.asarray(xp), q_i, jnp.asarray(qv), k,
                             use_kernel=use_kernel)
    assert np.array_equal(np.asarray(ids), np.asarray(f32_i))
    fin = np.isfinite(np.asarray(f32_d))
    assert np.allclose(np.asarray(dists)[fin], np.asarray(f32_d)[fin],
                       rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(5, 40), st.integers(2, 8),
       st.integers(1, 6))
def test_rerank_roundtrip_property(seed, n, d, k):
    """Property (hypothesis via the _hyp shim): for any corpus, quantizing
    to int8, taking every row as the survivor pool, and f32-reranking
    restores the exact f32 top-k id set — quantization error can reorder
    the quantized pass but never the reranked result."""
    k = min(k, n)
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    qv = rng.standard_normal((1, d)).astype(np.float32)
    pool = jnp.asarray(np.arange(n, dtype=np.int32)[None, :])
    ids, dists = rerank_pool(jnp.asarray(x), pool, jnp.asarray(qv), k,
                             use_kernel=False)
    d2 = np.sum((x - qv[0]) ** 2, axis=1)
    want = np.argsort(d2, kind="stable")[:k]
    assert np.array_equal(np.asarray(ids)[0], want)
    assert np.allclose(np.asarray(dists)[0], d2[want], rtol=1e-4, atol=1e-4)


# -------------------------------------------------- end-to-end parity suites
@pytest.fixture(scope="module")
def quant_index():
    n, d = 300, 24
    vecs = make_vectors(n, d, seed=0)
    attrs = make_attrs(n, seed=0)
    ix = RNSGIndex.build(vecs, attrs, m=12)
    for p in QUANT:
        ix.install_quantized(p)
    nq = 10
    qv = make_vectors(nq, d, seed=7)
    ranges = selectivity_ranges(attrs, nq, 0.3, seed=3)
    ranges[0] = [2.0, 1.0]                          # empty attribute range
    return ix, qv, ranges, n


@pytest.mark.parametrize("plan", ["graph", "auto", "scan", "beam"])
def test_strategy_parity_all_precisions(quant_index, plan):
    """Every strategy × precision at covering ef returns the exact f32
    top-k id set, with exact-f32 distances on the quantized rows."""
    ix, qv, ranges, n = quant_index
    k = 5
    base = ix.search(qv, ranges, k=k, ef=n, plan=plan)
    for prec in QUANT:
        res = ix.search(qv, ranges, k=k, ef=n, plan=plan, precision=prec)
        assert np.array_equal(np.sort(res.ids, 1), np.sort(base.ids, 1)), \
            (plan, prec)
        m = res.ids >= 0
        assert np.allclose(res.dists[m], base.dists[m], atol=1e-3), \
            (plan, prec)


def test_mesh_parity_all_precisions():
    from jax.sharding import Mesh

    from repro.serving.distributed import DistributedRFANN
    n, d, nq, k = 256, 24, 8, 5
    vecs = make_vectors(n, d, seed=0)
    attrs = make_attrs(n, seed=0)
    qv = make_vectors(nq, d, seed=7)
    ranges = selectivity_ranges(attrs, nq, 0.4, seed=3)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    dist = DistributedRFANN(vecs, attrs, n_shards=1, mesh=mesh, m=12)
    for p in QUANT:
        dist.install_quantized(p)
    for plan in ("graph", "auto"):
        i0, d0 = dist.search(qv, ranges, k=k, ef=n, plan=plan)
        for prec in QUANT:
            i1, d1 = dist.search(qv, ranges, k=k, ef=n, plan=plan,
                                 precision=prec)
            assert np.array_equal(np.sort(i0, 1), np.sort(i1, 1)), \
                (plan, prec)
            m = np.asarray(i1) >= 0
            assert np.allclose(np.asarray(d1)[m], np.asarray(d0)[m],
                               atol=1e-3), (plan, prec)


def test_quantized_routed_counters(quant_index):
    from repro.obs import MetricsRegistry
    ix, qv, ranges, n = quant_index
    reg = MetricsRegistry()
    ix.install_metrics(reg)
    try:
        ix.search(qv, ranges, k=5, ef=32, plan="scan", precision="int8")
        assert reg.counter("queries_int8_total").value == len(qv)
        assert reg.counter("rerank_rows_total").value > 0
    finally:
        ix.install_metrics(None)


# --------------------------------------------------- cache keys / TTL / epoch
def test_query_key_separates_precision_and_width():
    q = np.ones(8, np.float32)
    base = query_key(q, 0, 10, 5, 64, "auto")
    assert base[-1] == "f32" and base[-2] == 1      # defaults ride the key
    assert query_key(q, 0, 10, 5, 64, "auto", precision="int8") != base
    assert query_key(q, 0, 10, 5, 64, "auto", beam_width=4) != base


def _entry(cal_epoch=None):
    return CacheEntry(np.zeros(4, np.int32), np.zeros(4, np.float32), {},
                      cal_epoch=cal_epoch)


def test_cache_ttl_expires_auto_rows():
    now = [100.0]
    c = SearchCache(1 << 20, ttl_s=10.0, clock=lambda: now[0])
    c.store("auto_row", _entry(cal_epoch=0))
    c.store("forced_row", _entry(cal_epoch=None))
    assert c.lookup("auto_row", cal_epoch=0) is not None
    now[0] += 11.0
    assert c.lookup("auto_row", cal_epoch=0) is None    # aged out
    assert c.expired == 1 and len(c) == 1
    now[0] += 1000.0
    assert c.lookup("forced_row") is not None           # never age-expired


def test_cache_epoch_mismatch_expires_auto_rows():
    c = SearchCache(1 << 20)                            # no TTL configured
    c.store("row", _entry(cal_epoch=3))
    assert c.lookup("row", cal_epoch=3) is not None
    assert c.lookup("row", cal_epoch=4) is None         # calibration moved
    assert c.expired == 1 and c.snapshot()["expired"] == 1


def test_save_calibration_bumps_epoch(tmp_path):
    p = QueryPlanner(1000, 8.0)
    assert p.calibration_epoch == 0
    path = str(tmp_path / "cal.json")
    p.save_calibration(path)
    p.save_calibration(path)
    assert p.calibration_epoch == 2
    p2 = QueryPlanner(1000, 8.0)
    p2.load_calibration(path)                           # schema round-trips
    assert p2.calibration_epoch == 0                    # load does not bump


def test_auto_rows_expire_after_save_calibration(quant_index, tmp_path):
    """End to end: an auto-routed cached row stored before
    ``save_calibration`` is expired (re-executed) after the epoch bump."""
    ix, qv, ranges, n = quant_index
    cache = SearchCache(1 << 20)
    ix.install_cache(cache)
    try:
        ix.search(qv, ranges, k=5, ef=32, plan="auto")          # populate
        ix.search(qv, ranges, k=5, ef=32, plan="auto")          # all hits
        assert cache.hits >= len(qv) and cache.expired == 0
        ix.planner.save_calibration(str(tmp_path / "cal.json"))
        res = ix.search(qv, ranges, k=5, ef=32, plan="auto")    # re-executed
        assert cache.expired >= len(qv)
        assert res.stats["cache_hits"] == 0
    finally:
        ix.install_cache(None)


# ------------------------------------------------- per-precision cost model
def test_cost_precision_factor_prior_then_measured():
    cm = CostModel(8.0)
    for p, prior in PRECISION_PRIOR.items():
        assert cm.precision_factor("scan", p) == prior
    cm.observe_wall("scan", 10.0, 1.0, 100)                     # f32
    cm.observe_wall("scan", 10.0, 0.5, 100, precision="int8")
    assert cm.precision_factor("scan", "int8") == pytest.approx(0.5)
    assert cm.precision_factor("beam", "int8") == PRECISION_PRIOR["int8"]
    assert cm.predict_scan_units(64, precision="int8") == pytest.approx(
        cm.predict_scan_units(64) * 0.5)


def test_cost_state_dict_roundtrip_and_back_compat():
    cm = CostModel(8.0)
    cm.observe_wall("scan", 10.0, 1.0, 100)
    cm.observe_wall("beam", 5.0, 2.0, 100, precision="bf16")
    state = cm.state_dict()
    assert state["scan_us"] == state["scan_us_p"]["f32"]        # old keys = f32
    cm2 = CostModel(8.0)
    cm2.load_state_dict(state)
    assert cm2._scan_us_p == cm._scan_us_p
    assert cm2._beam_us_p == cm._beam_us_p
    # files from before per-precision tracking: scalar keys seed the dicts
    old = {k: v for k, v in state.items()
           if k not in ("scan_us_p", "beam_us_p")}
    cm3 = CostModel(8.0)
    cm3.load_state_dict(old)
    assert cm3._scan_us_p.get("f32") == state["scan_us"]


# ------------------------------------------------------ shared recall_at_k
def test_recall_at_k_gt_smaller_than_k():
    found = np.asarray([[3, 7, 9], [1, 2, 4]])
    gt = np.asarray([[3, -1, -1], [-1, -1, -1]])    # sub-k + empty rows
    assert bench_recall_at_k(found, gt) == 1.0      # denominator = valid gt
    assert bench_recall_at_k(np.asarray([[7, 8, 9], [0, 0, 0]]), gt) == 0.0


def test_recall_at_k_tie_handling():
    gt = np.asarray([[0, 1]])
    gt_d = np.asarray([[1.0, 2.0]])
    found = np.asarray([[0, 5]])
    found_d = np.asarray([[1.0, 2.0]])              # id 5 ties the gt worst
    assert bench_recall_at_k(found, gt) == 0.5      # set-only view: a miss
    assert bench_recall_at_k(found, gt, gt_dists=gt_d,
                             found_dists=found_d) == 1.0
    # hits stay capped at |gt-valid| even with many boundary ties
    many = np.asarray([[0, 5, 6, 7]])
    many_d = np.asarray([[1.0, 2.0, 2.0, 2.0]])
    assert bench_recall_at_k(many, gt, gt_dists=gt_d,
                             found_dists=many_d) == 1.0


# --------------------------------------------------- request validation
@pytest.mark.parametrize("kw,msg", [
    (dict(strategy="bogus"), "invalid strategy='bogus'"),
    (dict(precision="f16"), "invalid precision='f16'"),
    (dict(k=0), "invalid k=0"),
    (dict(ef=0), "invalid ef=0"),
    (dict(beam_width=0), "invalid beam_width=0"),
])
def test_request_validation_names_field_and_value(kw, msg):
    base = dict(queries=np.zeros((1, 4), np.float32),
                lo=np.zeros(1, np.int64), hi=np.zeros(1, np.int64))
    with pytest.raises(ValueError) as ei:
        SearchRequest(**{**base, **kw})
    assert f"SearchRequest: {msg}" in str(ei.value)


def test_precisions_exported():
    from repro.search import PRECISIONS as P2
    assert P2 == PRECISIONS == ("f32", "int8", "bf16")
