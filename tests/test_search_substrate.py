"""Unified search substrate: single-source resolve, strategy parity across
every execution path (including the shard_map mesh-auto path), empty-partition
guards, beam early-out, calibration persistence."""
import json
import os
import re
import subprocess
import sys
import textwrap
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.beam import beam_search_batch
from repro.core.rfann import RNSGIndex
from repro.data.ann import make_attrs, make_vectors, selectivity_ranges
from repro.planner import QueryPlanner
from repro.planner.planner import Partition
from repro.search import SearchRequest, SearchResult, select_entry
from repro.serving.distributed import DistributedRFANN

SRC = Path(__file__).resolve().parent.parent / "src" / "repro"


# ------------------------------------------------------- single-source resolve
def test_resolve_is_single_source():
    """Acceptance: exactly one implementation of rank-interval mapping and
    RMQ entry selection under src/repro — searchsorted / rmq_query_jax are
    *called* only from the substrate's resolve module.  The batched beam's
    bounded frontier merge uses ``searchsorted`` as a sorted-list merge
    primitive (no rank semantics); those lines carry an explicit
    ``sorted-merge`` marker and are the only exemption."""
    call = re.compile(r"\b(?:np|jnp)\.searchsorted\s*\(|rmq_query_jax\s*\(")
    offenders = []
    for py in SRC.rglob("*.py"):
        rel = py.relative_to(SRC).as_posix()
        if rel == "search/resolve.py":          # the one allowed home
            continue
        for ln, line in enumerate(py.read_text().splitlines(), 1):
            if line.lstrip().startswith("#"):
                continue
            if rel == "core/entry.py" and line.lstrip().startswith(
                    "def rmq_query_jax"):       # the definition itself
                continue
            if rel == "core/beam.py" and "sorted-merge" in line:
                continue                        # merge primitive, not resolve
            if call.search(line):
                offenders.append(f"{rel}:{ln}: {line.strip()}")
    assert not offenders, "\n".join(offenders)


# ------------------------------------------------------------- strategy parity
def _corpus(n=256, d=16, seed=0):
    vecs = make_vectors(n, d, seed=seed)
    attrs = make_attrs(n, seed=seed)
    return vecs, attrs


def _degenerate_ranges(attrs, nq, seed):
    """Random selectivities plus the degenerate rows the paper's API must
    handle: empty, single-point, full-span."""
    s = np.sort(attrs)
    rngs = [selectivity_ranges(attrs, nq - 3, 0.2, seed=seed)]
    rngs.append(np.asarray([
        [s[5] + 1e-7, s[5] + 2e-7],     # empty
        [s[17], s[17]],                 # single point
        [s[0], s[-1]],                  # full span
    ], np.float32))
    return np.concatenate(rngs)


def test_strategy_parity_all_paths():
    """With ef >= n every strategy is exact, so plan=graph/auto/scan/beam and
    the sharded DistributedRFANN (graph and per-shard-planned, async and
    sequential) must return identical id sets — including degenerate ranges.
    Cached re-runs of every single-index strategy must additionally be
    **bit-identical** (ids and dists) to the uncached run that populated
    the cache."""
    from repro.search import SearchCache

    n, d, nq, k = 256, 16, 15, 8
    vecs, attrs = _corpus(n, d)
    idx = RNSGIndex.build(vecs, attrs, m=16, ef_spatial=16, ef_attribute=24)
    dist = DistributedRFANN(vecs, attrs, n_shards=4, m=16, ef_spatial=16,
                            ef_attribute=24)
    qv = make_vectors(nq, d, seed=7)
    ranges = _degenerate_ranges(attrs, nq, seed=11)

    runs = {}
    for plan in ("graph", "auto", "scan", "beam"):
        uncached = idx.search(qv, ranges, k=k, ef=n, plan=plan)
        runs[plan] = uncached.ids
        # cached parity: the populating (miss) pass and the all-hit pass
        # must both be bit-identical to the uncached run
        idx.install_cache(SearchCache(1 << 20))
        fill = idx.search(qv, ranges, k=k, ef=n, plan=plan)
        hit = idx.search(qv, ranges, k=k, ef=n, plan=plan)
        idx.install_cache(None)
        assert hit.stats["cache_hits"] == nq
        for res in (fill, hit):
            assert np.array_equal(res.ids, uncached.ids), plan
            assert np.array_equal(res.dists, uncached.dists), plan
    # batched expansion: every strategy at beam_width=4 doubles as a
    # correctness oracle for the bounded-merge + hashed-visited frontier
    for plan in ("graph", "auto", "beam"):
        runs[f"{plan}_bw4"] = idx.search(qv, ranges, k=k, ef=n, plan=plan,
                                         beam_width=4).ids
    runs["dist_graph"] = dist.search(qv, ranges, k=k, ef=n, plan="graph")[0]
    runs["dist_auto"] = dist.search(qv, ranges, k=k, ef=n, plan="auto")[0]
    runs["dist_graph_bw4"] = dist.search(qv, ranges, k=k, ef=n, plan="graph",
                                         beam_width=4)[0]
    dist.async_dispatch = False
    runs["dist_auto_seq"] = dist.search(qv, ranges, k=k, ef=n,
                                        plan="auto")[0]

    base = runs.pop("graph")
    for q in range(nq):
        want = set(base[q][base[q] >= 0].tolist())
        for name, ids in runs.items():
            got = set(ids[q][ids[q] >= 0].tolist())
            assert got == want, (name, q, sorted(got), sorted(want))
    # degenerate rows behave as specified
    assert (base[nq - 3] == -1).all()                       # empty
    assert base[nq - 2][0] >= 0 and (base[nq - 2][1:] == -1).all()  # single
    assert (base[nq - 1] >= 0).all()                        # full span


def test_search_result_is_tuple_compatible():
    vecs, attrs = _corpus(128, 8)
    idx = RNSGIndex.build(vecs, attrs, m=8, ef_spatial=8, ef_attribute=12)
    qv = make_vectors(4, 8, seed=1)
    rg = selectivity_ranges(attrs, 4, 0.3, seed=2)
    res = idx.search(qv, rg, k=3, ef=16)
    assert isinstance(res, SearchResult)
    ids, dists, stats = res                     # legacy unpacking
    assert np.array_equal(ids, res[0]) and np.array_equal(dists, res[1])
    assert stats is res.stats and len(res) == 3
    row = res.row(2)
    assert row.ids.shape == (3,) and row.stats["hops"].shape == ()


# ------------------------------------------------------- mesh strategy parity
def test_mesh_auto_parity_single_device():
    """The mesh-auto machinery (host plan -> replicated strategy vector ->
    branchless per-shard select -> restitch -> merge) on a 1-device mesh:
    every mesh plan must match the mesh graph path's id sets, with both
    strategies exercised in one shard_map call."""
    import jax

    from repro.planner.planner import BEAM, SCAN
    from repro.search import rank_interval

    n, d, nq, k = 256, 16, 15, 8
    vecs, attrs = _corpus(n, d)
    mesh = jax.make_mesh((1,), ("data",))
    dist = DistributedRFANN(vecs, attrs, n_shards=1, mesh=mesh, m=16,
                            ef_spatial=16, ef_attribute=24)
    qv = make_vectors(nq, d, seed=7)
    ranges = _degenerate_ranges(attrs, nq, seed=11)

    lo, hi = rank_interval(dist.attrs_sorted, ranges)
    strat, _ = dist.mesh_substrate.plan_strategies(lo, hi, k=k, ef=64,
                                                   mode="auto")
    assert (strat == SCAN).any() and (strat == BEAM).any()   # mixed batch

    base, _ = dist.search(qv, ranges, k=k, ef=n, plan="graph")
    for plan, bw in (("auto", 1), ("scan", 1), ("beam", 1),
                     ("graph", 4), ("auto", 4)):
        ids, dists = dist.search(qv, ranges, k=k, ef=n, plan=plan,
                                 beam_width=bw)
        for q in range(nq):
            want = set(base[q][base[q] >= 0].tolist())
            got = set(ids[q][ids[q] >= 0].tolist())
            assert got == want, (plan, bw, q, sorted(got), sorted(want))
    # degenerate rows behave as specified on the mesh too
    assert (base[nq - 3] == -1).all()                        # empty
    assert base[nq - 2][0] >= 0 and (base[nq - 2][1:] == -1).all()
    assert (base[nq - 1] >= 0).all()                         # full span
    # zero-query mesh request: no dispatch, well-shaped empty result
    e_ids, e_d = dist.search(qv[:0], ranges[:0], k=k, ef=n, plan="auto")
    assert e_ids.shape == (0, k) and e_d.shape == (0, k)


@pytest.mark.slow
def test_mesh_auto_parity_multidevice():
    """Acceptance (subprocess: XLA_FLAGS must precede jax import): on an
    8-device mesh, plan='auto' routes a mixed narrow/wide batch to BOTH
    strategies inside one shard_map call and returns id sets identical to
    the graph-only mesh path — including intervals empty on most shards
    (clipped to a single shard) and globally empty intervals."""
    root = Path(__file__).resolve().parent.parent
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=str(root / "src"))
    code = textwrap.dedent("""
        import numpy as np, jax
        from repro.data.ann import make_vectors, make_attrs, selectivity_ranges
        from repro.planner.planner import BEAM, SCAN
        from repro.search import rank_interval
        from repro.serving.distributed import DistributedRFANN

        vecs = make_vectors(1024, 16, seed=0)
        attrs = make_attrs(1024, seed=0)
        mesh = jax.make_mesh((8,), ("data",))
        qv = make_vectors(24, 16, seed=7)
        s = np.sort(attrs)
        rg = np.concatenate([
            selectivity_ranges(attrs, 10, 0.01, seed=3),     # narrow -> scan
            selectivity_ranges(attrs, 10, 0.5, seed=4),      # wide -> beam
            np.asarray([[s[5] + 1e-7, s[5] + 2e-7],          # globally empty
                        [s[17], s[17]],                      # single point
                        [s[3], s[40]],                       # shard 0 only:
                        [s[0], s[-1]]], np.float32)])        #  7 empty clips
        dist = DistributedRFANN(vecs, attrs, n_shards=8, mesh=mesh, m=16,
                                ef_spatial=16, ef_attribute=24)
        lo, hi = rank_interval(dist.attrs_sorted, rg)
        strat, _ = dist.mesh_substrate.plan_strategies(lo, hi, k=8, ef=64,
                                                       mode='auto')
        assert (strat == SCAN).any() and (strat == BEAM).any(), strat
        base, _ = dist.search(qv, rg, k=8, ef=1024, plan='graph')
        ids, _ = dist.search(qv, rg, k=8, ef=1024, plan='auto')
        for q in range(len(rg)):
            want = set(base[q][base[q] >= 0].tolist())
            got = set(ids[q][ids[q] >= 0].tolist())
            assert got == want, (q, sorted(got), sorted(want))
        assert (base[20] == -1).all()                        # empty row
        print('OK', strat.tolist())
    """)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "OK" in r.stdout


def test_mesh_ndist_feedback_moves_cost_model():
    """ROADMAP item: the traced mesh bodies all-gather a per-shard ndist
    scalar, so warm routed dispatches move the planner's ``ndist_per_ef``
    EMA — previously the mesh path never calibrated it.  ``plan='graph'``
    (the paper's pure path) must still never calibrate."""
    import jax

    n, d, nq, k = 256, 16, 12, 8
    vecs, attrs = _corpus(n, d)
    mesh = jax.make_mesh((1,), ("data",))
    dist = DistributedRFANN(vecs, attrs, n_shards=1, mesh=mesh, m=16,
                            ef_spatial=16, ef_attribute=24)
    planner = dist.mesh_substrate.planner
    qv = make_vectors(nq, d, seed=7)
    wide = selectivity_ranges(attrs, nq, 0.6, seed=5)       # routes to beam
    assert planner.cost.beam_obs == 0
    dist.search(qv, wide, k=k, ef=64, plan="beam")          # cold: warms only
    assert planner.cost.beam_obs == 0
    prior = planner.cost.ndist_per_ef
    dist.search(qv, wide, k=k, ef=64, plan="beam")          # warm: calibrates
    assert planner.cost.beam_obs == 1
    assert planner.cost.ndist_per_ef != prior               # EMA moved
    obs_g = planner.cost.beam_obs
    dist.search(qv, wide, k=k, ef=64, plan="graph")         # warm fn, but the
    dist.search(qv, wide, k=k, ef=64, plan="graph")         # pure path never
    assert planner.cost.beam_obs == obs_g                   # calibrates
    # the mixed scan+beam planned body feeds the EMA too
    mixed = np.concatenate([selectivity_ranges(attrs, nq // 2, 0.01, seed=6),
                            selectivity_ranges(attrs, nq - nq // 2, 0.6,
                                               seed=7)])
    dist.search(qv, mixed, k=k, ef=64, plan="auto")         # warms
    obs = planner.cost.beam_obs
    dist.search(qv, mixed, k=k, ef=64, plan="auto")
    assert planner.cost.beam_obs > obs


# ------------------------------------------------------ empty-partition guard
def test_plan_never_emits_empty_partitions():
    pl = QueryPlanner(n=10_000, mean_degree=16.0)
    rng = np.random.default_rng(0)
    for mode in ("auto", "scan", "beam"):
        for q in (0, 1, 7, 33):
            lo = rng.integers(0, 10_000, q)
            hi = lo + rng.integers(-5, 5_000, q)     # includes empty ranges
            plan = pl.plan_batch(lo, hi, k=10, ef=64, mode=mode)
            assert all(len(p.indices) > 0 for p in plan.partitions)
            covered = (np.concatenate([p.indices for p in plan.partitions])
                       if plan.partitions else np.zeros(0, np.int64))
            assert sorted(covered.tolist()) == list(range(q))


def test_empty_partition_and_empty_batch_do_not_crash():
    """Regression: dispatching a zero-query partition used to die on
    ``idx[-1:]``; the substrate now guards it and zero-query requests."""
    vecs, attrs = _corpus(128, 8)
    idx = RNSGIndex.build(vecs, attrs, m=8, ef_spatial=8, ef_attribute=12)
    sub = idx.substrate
    ids, d, st = sub._run_beam(np.zeros((0, 8), np.float32),
                               np.zeros(0, np.int64), np.zeros(0, np.int64),
                               np.zeros(0, np.int64), 16, 8, 5,
                               calibrate=False)
    assert ids.shape == (0, 5) and st["hops"].shape == (0,)
    for plan in ("graph", "auto", "scan", "beam"):
        res = sub.run(SearchRequest(queries=np.zeros((0, 8), np.float32),
                                    lo=np.zeros(0, np.int64),
                                    hi=np.zeros(0, np.int64),
                                    k=5, ef=16, strategy=plan))
        assert res.ids.shape == (0, 5)


# ------------------------------------------------------------- beam early-out
def test_beam_early_out_same_results_fewer_hops():
    """Narrow range (in-range count << ef): the pool never fills, so the
    legacy condition burns steps_cap; the early-out must return identical
    results in far fewer hops."""
    n, d, ef = 512, 16, 64
    vecs, attrs = _corpus(n, d, seed=3)
    idx = RNSGIndex.build(vecs, attrs, m=16, ef_spatial=16, ef_attribute=24)
    g = idx.g
    nq = 8
    qv = jnp.asarray(make_vectors(nq, d, seed=9))
    lo = jnp.asarray(np.full(nq, 100, np.int32))
    hi = jnp.asarray(np.full(nq, 115, np.int32))     # 16 in-range nodes < ef
    entry = select_entry(jnp.asarray(g.rmq), jnp.asarray(g.dist_c), lo, hi, n)
    args = (jnp.asarray(g.vecs), jnp.asarray(g.nbrs), qv, lo, hi, entry)
    i_new, d_new, st_new = beam_search_batch(*args, k=5, ef=ef,
                                             early_stop=True)
    i_old, d_old, st_old = beam_search_batch(*args, k=5, ef=ef,
                                             early_stop=False)
    assert np.array_equal(np.asarray(i_new), np.asarray(i_old))
    assert np.allclose(np.asarray(d_new), np.asarray(d_old), equal_nan=True)
    steps_cap = 8 * ef + 64
    assert (np.asarray(st_old["hops"]) == steps_cap).all()   # the old burn
    assert (np.asarray(st_new["hops"]) < 64).all()           # early exit


# ------------------------------------------------- calibration persistence
def test_calibration_save_load_roundtrip(tmp_path):
    vecs, attrs = _corpus(512, 16, seed=1)
    idx = RNSGIndex.build(vecs, attrs, m=16, ef_spatial=16, ef_attribute=24)
    qv = make_vectors(16, 16, seed=2)
    rg = np.concatenate([selectivity_ranges(attrs, 8, 0.01, seed=1),
                         selectivity_ranges(attrs, 8, 0.8, seed=2)])
    for _ in range(3):                       # calibrate (incl. warm calls)
        idx.search(qv, rg, k=5, ef=64, plan="auto")
    p = str(tmp_path / "calib.json")
    idx.planner.save_calibration(p)
    state = json.load(open(p))
    assert state["version"] == 1 and state["cost"]["beam_obs"] >= 1
    # atomic write: the rename left no temp file, and re-saving over an
    # existing path replaces it wholesale (never truncates in place)
    assert [f.name for f in tmp_path.iterdir()] == ["calib.json"]
    idx.planner.save_calibration(p)
    assert json.load(open(p)) == state

    fresh = QueryPlanner(n=idx.g.n, mean_degree=16.0)
    assert fresh.cost.state_dict() != idx.planner.cost.state_dict()
    fresh.load_calibration(p)
    assert fresh.cost.state_dict() == idx.planner.cost.state_dict()

    # calibration is per-index: a corpus-size mismatch must not load
    wrong = QueryPlanner(n=idx.g.n + 1, mean_degree=16.0)
    with pytest.raises(ValueError, match="corpus"):
        wrong.load_calibration(p)


def test_engine_wires_calibration(tmp_path):
    from repro.serving.engine import RFANNEngine
    vecs, attrs = _corpus(512, 16, seed=4)
    idx = RNSGIndex.build(vecs, attrs, m=16, ef_spatial=16, ef_attribute=24)
    p = str(tmp_path / "engine_calib.json")
    eng = RFANNEngine(idx, k=5, ef=32, max_batch=8, max_wait_ms=5,
                      plan="auto", calibration_path=p)
    qv = make_vectors(16, 16, seed=5)
    rg = selectivity_ranges(attrs, 16, 0.5, seed=6)
    futs = [eng.submit(qv[i], rg[i]) for i in range(16)]
    for f in futs:
        assert f.result(timeout=120).ids.shape == (5,)
    eng.close()                                  # persists on shutdown
    saved = json.load(open(p))["cost"]

    idx2 = RNSGIndex(idx.g)                      # fresh substrate + planner
    eng2 = RFANNEngine(idx2, k=5, ef=32, plan="auto", calibration_path=p)
    eng2.close()
    # startup restored the persisted state exactly (JSON floats round-trip)
    assert idx2.planner.cost.state_dict() == saved
