"""Observability tests: registry thread-safety, histogram percentile
correctness against the np.percentile oracle, per-query trace completeness
on every execution path, and exporter round-trips."""
import threading

import jax
import numpy as np
import pytest

from repro.core.rfann import RNSGIndex
from repro.data.ann import make_attrs, make_vectors, mixed_workload
from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry, QueryTrace,
                       format_stats_line, maybe_span, parse_prometheus,
                       to_prometheus)
from repro.search import SearchCache
from repro.serving.distributed import DistributedRFANN
from repro.serving.engine import RFANNEngine

REQUIRED_SPANS = {"resolve", "plan", "dispatch", "stitch"}


# ------------------------------------------------------------- metrics core
def test_counter_thread_safety():
    """8 threads x 5000 increments must land exactly — the per-metric lock
    never loses an update."""
    c = Counter("hammer")
    n_threads, per = 8, 5000

    def work():
        for _ in range(per):
            c.inc()

    ts = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert c.value == n_threads * per


def test_histogram_concurrent_observe():
    h = Histogram("lat")
    n_threads, per = 6, 400

    def work(seed):
        rng = np.random.default_rng(seed)
        for _ in range(per // 8):
            h.observe_many(rng.uniform(0.1, 100.0, 8))

    ts = [threading.Thread(target=work, args=(i,)) for i in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert h.count == n_threads * per
    edges, cum = h.bucket_counts()
    assert int(cum[-1]) == h.count              # cumulative folds everything


@pytest.mark.parametrize("dist_name", ["lognormal", "uniform", "bimodal"])
def test_histogram_percentiles_vs_oracle(dist_name):
    """p50/p90/p99 within one bucket's relative width (growth - 1) of the
    exact np.percentile answer."""
    rng = np.random.default_rng(3)
    vals = {
        "lognormal": np.exp(rng.normal(1.0, 1.2, 20_000)),
        "uniform": rng.uniform(0.5, 300.0, 20_000),
        "bimodal": np.concatenate([rng.uniform(0.2, 2.0, 10_000),
                                   rng.uniform(50.0, 500.0, 10_000)]),
    }[dist_name]
    growth = 1.25
    h = Histogram("lat", growth=growth)
    h.observe_many(vals)
    for p in (50, 90, 99):
        # the histogram implements the rank (inverted-CDF) quantile; the
        # default linear interpolation diverges arbitrarily at density gaps
        exact = float(np.percentile(vals, p, method="inverted_cdf"))
        got = h.percentile(p)
        rel = abs(got - exact) / exact
        assert rel <= (growth - 1) + 0.02, (p, got, exact, rel)
    snap = h.snapshot()
    assert snap["count"] == len(vals)
    assert np.isclose(snap["mean"], vals.mean())        # sum is exact
    assert snap["min"] == pytest.approx(vals.min())
    assert snap["max"] == pytest.approx(vals.max())


def test_histogram_edge_cases():
    h = Histogram("lat")
    assert h.percentile(50) == 0.0                      # empty -> 0
    assert h.snapshot()["count"] == 0
    h.observe(7.5)
    # single value: every percentile clamps to the one observation
    assert h.percentile(1) == pytest.approx(7.5)
    assert h.percentile(50) == pytest.approx(7.5)
    assert h.percentile(99) == pytest.approx(7.5)
    h2 = Histogram("tiny")
    h2.observe(1e-9)                                    # below first edge
    assert h2.percentile(50) == pytest.approx(1e-9)     # clamped to min
    h2.observe(1e9)                                     # overflow bucket
    assert h2.percentile(99) == pytest.approx(1e9)      # clamped to max


def test_registry_get_or_create_and_type_conflict():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")
    reg.gauge("g").set(4.5)
    reg.histogram("h").observe(2.0)
    reg.register_producer("section", lambda: dict(a=1, nested=dict(b=2.5),
                                                  skipped="str"))
    snap = reg.snapshot()
    assert snap["counters"]["x"] == 0
    assert snap["gauges"]["g"] == 4.5
    assert snap["histograms"]["h"]["count"] == 1
    assert snap["section"] == {"a": 1.0, "nested_b": 2.5}


def test_registry_dead_producer_never_kills_export():
    reg = MetricsRegistry()
    reg.register_producer("bad", lambda: 1 / 0)
    reg.register_producer("good", lambda: dict(v=1.0))
    snap = reg.snapshot()
    assert "bad" not in snap and snap["good"] == {"v": 1.0}


# --------------------------------------------------------------- exporters
def test_prometheus_roundtrip_and_bucket_invariants():
    reg = MetricsRegistry()
    reg.counter("reqs").inc(42)
    reg.gauge("depth").set(3)
    h = reg.histogram("lat_ms")
    h.observe_many(np.random.default_rng(0).uniform(0.5, 50.0, 1000))
    reg.register_producer("cache", lambda: dict(bytes=1024))
    text = to_prometheus(reg)
    samples = parse_prometheus(text)
    assert samples[("rnsg_reqs", "")] == 42
    assert samples[("rnsg_depth", "")] == 3
    assert samples[("rnsg_cache_bytes", "")] == 1024
    assert samples[("rnsg_lat_ms_count", "")] == 1000
    assert samples[("rnsg_lat_ms_sum", "")] == pytest.approx(h.sum)
    # cumulative buckets: nondecreasing in le, +Inf bucket == count
    buckets = [(float(lbl.split('"')[1].replace("+Inf", "inf")), v)
               for (name, lbl), v in samples.items()
               if name == "rnsg_lat_ms_bucket"]
    buckets.sort()
    vals = [v for _, v in buckets]
    assert vals == sorted(vals)
    assert buckets[-1][0] == float("inf") and buckets[-1][1] == 1000


def test_parse_prometheus_rejects_malformed():
    with pytest.raises(ValueError):
        parse_prometheus("this is { not a metric\n")


def test_format_stats_line_shape():
    reg = MetricsRegistry()
    reg.histogram("engine_e2e_ms").observe_many([1.0, 2.0, 3.0])
    reg.register_producer("engine", lambda: dict(
        served=10, batches=2, mean_batch=5.0, scan_frac=0.5,
        cache_hit_frac=0.1))
    line = format_stats_line(reg.snapshot())
    assert line.startswith("[obs] served=10 batches=2")
    assert "p50=" in line and "p99=" in line


# ------------------------------------------------------------------- traces
def test_maybe_span_null_object():
    with maybe_span(None, "dispatch") as sp:
        sp.attrs["k"] = 1                    # dropped, never raises
        sp.attrs.update(x=2)
    tr = QueryTrace()
    with maybe_span(tr, "dispatch", a=1) as sp:
        sp.attrs["b"] = 2
    assert tr.get("dispatch").attrs == {"a": 1, "b": 2}
    assert tr.wall_ms("dispatch") >= 0.0
    d = tr.to_dict()
    assert d["spans"][0]["name"] == "dispatch"


# small shared corpora for the path-coverage matrix -------------------------
N, D, Q = 256, 16, 8


@pytest.fixture(scope="module")
def corpus():
    vecs = make_vectors(N, D, seed=0)
    attrs = make_attrs(N, seed=0)
    qv = make_vectors(Q, D, seed=7)
    ranges, _ = mixed_workload(attrs, Q, seed=3)
    return vecs, attrs, qv, ranges


@pytest.fixture(scope="module")
def local_index(corpus):
    vecs, attrs, _, _ = corpus
    return RNSGIndex.build(vecs, attrs, m=8, ef_spatial=16, ef_attribute=24)


@pytest.fixture(scope="module")
def dist_local(corpus):
    vecs, attrs, _, _ = corpus
    return DistributedRFANN(vecs, attrs, n_shards=2, m=8, ef_spatial=16,
                            ef_attribute=24)


@pytest.fixture(scope="module")
def dist_mesh(corpus):
    vecs, attrs, _, _ = corpus
    mesh = jax.make_mesh((1,), ("data",))
    return DistributedRFANN(vecs, attrs, n_shards=1, mesh=mesh, m=8,
                            ef_spatial=16, ef_attribute=24)


def _index(path, local_index, dist_local, dist_mesh):
    return dict(local=local_index, dist=dist_local, mesh=dist_mesh)[path]


@pytest.mark.parametrize("path", ["local", "dist", "mesh"])
@pytest.mark.parametrize("plan", ["graph", "auto", "scan", "beam"])
def test_trace_completeness(path, plan, corpus, local_index, dist_local,
                            dist_mesh):
    """Every strategy x every execution path yields a complete span set
    with the routing decision and cache outcome recorded — and tracing
    never changes the returned ids."""
    _, _, qv, ranges = corpus
    idx = _index(path, local_index, dist_local, dist_mesh)
    tr = QueryTrace(request_id=f"{path}-{plan}")
    traced = idx.search(qv, ranges, k=5, ef=32, plan=plan, trace=tr)
    plain = idx.search(qv, ranges, k=5, ef=32, plan=plan)
    t_ids = traced[0] if isinstance(traced, tuple) else traced.ids
    p_ids = plain[0] if isinstance(plain, tuple) else plain.ids
    np.testing.assert_array_equal(np.asarray(t_ids), np.asarray(p_ids))

    names = set(tr.names())
    assert REQUIRED_SPANS <= names, (path, plan, tr.names())
    plan_sp = tr.get("plan")
    assert plan_sp.attrs["strategy_mode"] == plan
    if plan == "graph":
        assert plan_sp.attrs.get("chosen") == "graph"
    else:
        assert "strategy" in plan_sp.attrs       # per-query routing vector
        assert "scan_frac" in plan_sp.attrs
    disp = tr.get("dispatch")
    assert "cache_enabled" in disp.attrs         # cache outcome always there
    assert disp.attrs["cache_enabled"] is False
    for sp in tr.spans:
        assert sp.wall_ms >= 0.0
    # every span survives JSON conversion
    d = tr.to_dict()
    assert {s["name"] for s in d["spans"]} >= REQUIRED_SPANS


@pytest.mark.parametrize("path", ["local", "dist", "mesh"])
def test_trace_cache_outcome(path, corpus, local_index, dist_local,
                             dist_mesh):
    """Second identical batch is served from the cache: the dispatch span
    records dispatched=0 and cache_hits=Q (resolve/stitch still present)."""
    _, _, qv, ranges = corpus
    idx = _index(path, local_index, dist_local, dist_mesh)
    cache = SearchCache(max_bytes=4 << 20)
    idx.install_cache(cache)
    try:
        idx.search(qv, ranges, k=5, ef=32, plan="auto")         # populate
        tr = QueryTrace()
        idx.search(qv, ranges, k=5, ef=32, plan="auto", trace=tr)
        disps = tr.all("dispatch")
        assert disps, tr.names()
        for sp in disps:
            assert sp.attrs["cache_enabled"] is True
            assert sp.attrs["dispatched"] == 0
            assert sp.attrs["cache_hits"] == Q
        assert {"resolve", "dispatch", "stitch"} <= set(tr.names())
    finally:
        idx.install_cache(None)


# ------------------------------------------------------------------- engine
def test_engine_concurrent_submit_exact_totals(local_index):
    """N client threads x M submits: every future resolves, and both the
    EngineStats and the registry counters account for exactly N*M."""
    eng = RFANNEngine(local_index, k=5, ef=32, plan="auto", max_batch=32,
                      max_wait_ms=1.0)
    try:
        n_threads, per = 4, 24
        rng = np.random.default_rng(0)
        qs = rng.standard_normal((n_threads, per, D)).astype(np.float32)
        errs = []

        def client(t):
            try:
                futs = [eng.submit(qs[t, i], (-0.5, 0.5))
                        for i in range(per)]
                for f in futs:
                    r = f.result(timeout=60)
                    assert r.ids.shape == (5,)
            except Exception as e:          # pragma: no cover - diagnostics
                errs.append(e)

        ts = [threading.Thread(target=client, args=(t,))
              for t in range(n_threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert not errs
        assert eng.stats.served == n_threads * per
        snap = eng.metrics()
        assert snap["counters"]["engine_requests_total"] == n_threads * per
        assert snap["counters"]["queries_total"] == n_threads * per
        assert snap["engine"]["served"] == n_threads * per
    finally:
        eng.close()


def test_engine_metrics_percentiles_dedup_and_trace(local_index):
    """End-to-end engine observability: non-trivial p50/p99, batch dedup
    surfaced in stats, sampled trace parked on last_trace, prometheus dump
    round-trips with the core families."""
    eng = RFANNEngine(local_index, k=5, ef=32, plan="auto", max_batch=64,
                      max_wait_ms=40.0, cache_bytes=1 << 20,
                      trace_sample_every=1)
    try:
        q = make_vectors(1, D, seed=9)[0]
        # one burst of identical requests coalesces into one batch: row 0
        # misses, rows 1.. are intra-batch duplicates
        futs = [eng.submit(q, (-0.5, 0.5)) for _ in range(16)]
        for f in futs:
            f.result(timeout=60)
        assert eng.stats.dedup_hits > 0
        summ = eng.stats.summary()
        assert summ["dedup_hits"] == eng.stats.dedup_hits
        assert summ["lat_seen"] == 16

        snap = eng.metrics()
        lat = snap["histograms"]["engine_e2e_ms"]
        assert lat["count"] == 16
        assert 0 < lat["p50"] <= lat["p99"]
        assert snap["histograms"]["engine_batch_size"]["count"] >= 1
        assert eng.last_trace is not None
        assert {"resolve", "dispatch", "stitch"} <= set(eng.last_trace.names())

        text = to_prometheus(eng.registry)
        samples = parse_prometheus(text)
        names = {n for (n, _) in samples}
        assert "rnsg_engine_requests_total" in names
        assert "rnsg_engine_e2e_ms_count" in names
        assert "rnsg_queries_total" in names
        assert samples[("rnsg_engine_requests_total", "")] == 16
    finally:
        eng.close()


def test_engine_trace_survives_untraced_index(corpus):
    """An index predating the trace API (tuple-returning baseline) keeps
    working when trace sampling is on — the engine drops the kwarg."""
    vecs, attrs, qv, _ = corpus

    class Legacy:
        def search(self, q, rg, *, k=10, ef=64, plan="auto"):
            q2 = np.atleast_2d(q)
            return (np.zeros((len(q2), k), np.int32),
                    np.zeros((len(q2), k), np.float32))

    eng = RFANNEngine(Legacy(), k=5, ef=32, plan="auto",
                      trace_sample_every=1, max_wait_ms=1.0)
    try:
        r = eng.submit(qv[0], (-0.5, 0.5)).result(timeout=30)
        assert r.ids.shape == (5,)
        assert eng.stats.served == 1
    finally:
        eng.close()
