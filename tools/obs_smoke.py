#!/usr/bin/env python
"""CI smoke for the observability stack: run a short serve with metrics
enabled, then assert both exporter formats parse and carry the core
metric families with non-trivial latency percentiles.

    PYTHONPATH=src python tools/obs_smoke.py

Exit code 0 = every assertion held.  This drives the real launcher
(``repro.launch.serve --mode rfann --metrics-path ...``) rather than a
synthetic registry, so it catches wiring regressions anywhere on the
engine -> substrate -> exporter path.
"""
import json
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main as serve_main          # noqa: E402
from repro.obs import CORE_FAMILIES, parse_prometheus      # noqa: E402


def check(cond, msg):
    if not cond:
        print(f"[obs-smoke] FAIL: {msg}")
        sys.exit(1)
    print(f"[obs-smoke] ok: {msg}")


def main():
    with tempfile.TemporaryDirectory() as td:
        prom = os.path.join(td, "metrics.prom")
        serve_main(["--mode", "rfann", "--n", "2048", "--requests", "128",
                    "--max-batch", "32", "--plan", "auto", "--cache-mb", "4",
                    "--trace-sample-every", "4", "--metrics-path", prom])
        check(os.path.exists(prom), "prometheus dump written")
        check(os.path.exists(prom + ".json"), "json snapshot written")

        with open(prom) as f:
            text = f.read()
        samples = parse_prometheus(text)           # raises on malformed lines
        names = {n for (n, _) in samples}
        check(len(samples) > 0, f"prometheus dump parsed ({len(samples)} samples)")
        for fam in CORE_FAMILIES:
            present = any(n == fam or n.startswith(fam + "_") for n in names)
            check(present, f"core family {fam} present")
        # cumulative-bucket sanity on the e2e histogram
        e2e_count = samples.get(("rnsg_engine_e2e_ms_count", ""), 0.0)
        check(e2e_count > 0, f"e2e histogram counted {int(e2e_count)} requests")

        with open(prom + ".json") as f:
            snap = json.load(f)
        lat = snap["histograms"]["engine_e2e_ms"]
        check(lat["count"] > 0, "json snapshot has e2e observations")
        check(lat["p50"] > 0 and lat["p99"] > 0,
              f"non-trivial percentiles p50={lat['p50']:.2f}ms "
              f"p99={lat['p99']:.2f}ms")
        check(lat["p50"] <= lat["p99"], "p50 <= p99")
        check(snap["engine"]["served"] == 128, "engine served every request")
    print("[obs-smoke] PASS")


if __name__ == "__main__":
    main()
