"""Intra-repo link checker for the markdown docs (``make docs-check``).

Scans README.md and docs/*.md for inline markdown links ``[text](target)``
and fails (exit 1) when a relative target — optionally carrying a
``#anchor`` — does not resolve to an existing file or directory.  External
schemes (http/https/mailto) and pure in-page anchors are skipped; image
links (``![alt](target)``) are checked the same way.

  PYTHONPATH=src python tools/docs_check.py [root]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def doc_files(root: Path):
    yield from sorted(root.glob("*.md"))
    yield from sorted((root / "docs").glob("*.md"))


def check_file(path: Path, root: Path):
    """Yields (line_number, target) for every broken relative link."""
    in_fence = False
    for ln, line in enumerate(path.read_text().splitlines(), 1):
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:                      # code blocks aren't hyperlinks
            continue
        for m in LINK.finditer(line):
            target = m.group(1)
            if target.startswith(SKIP_PREFIXES):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = (path.parent / rel).resolve()
            try:                          # links must stay inside the repo
                resolved.relative_to(root.resolve())
            except ValueError:
                yield ln, target
                continue
            if not resolved.exists():
                yield ln, target


def main() -> int:
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else \
        Path(__file__).resolve().parent.parent
    broken = []
    checked = 0
    for md in doc_files(root):
        checked += 1
        for ln, target in check_file(md, root):
            broken.append(f"{md.relative_to(root)}:{ln}: broken link -> "
                          f"{target}")
    for b in broken:
        print(b)
    print(f"[docs-check] {checked} files, {len(broken)} broken links")
    return 1 if broken else 0


if __name__ == "__main__":
    raise SystemExit(main())
