#!/usr/bin/env python
"""Capture a jax.profiler device trace around a small batched-beam run.

    PYTHONPATH=src python tools/profile_capture.py [--out results/profiles]

Writes a profile directory (viewable with ``tensorboard --logdir`` or
Perfetto) containing the device timeline for a short beam-width sweep.
Host-side ``TraceAnnotation`` spans emitted by the substrate
(``rnsg.scan_dispatch``, ``rnsg.beam_dispatch``, ...) appear in the trace,
so kernel time lines up with the dispatch stages of docs/observability.md.
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np                                         # noqa: E402

from repro.core.rfann import RNSGIndex                     # noqa: E402
from repro.data.ann import make_attrs, make_vectors, mixed_workload  # noqa: E402
from repro.obs import device_trace                         # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/profiles")
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--nq", type=int, default=128)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--ef", type=int, default=64)
    args = ap.parse_args()

    log_dir = os.path.join(args.out, time.strftime("%Y%m%d-%H%M%S"))
    os.makedirs(log_dir, exist_ok=True)

    vecs = make_vectors(args.n, args.dim, seed=0)
    attrs = make_attrs(args.n, seed=0)
    qv = make_vectors(args.nq, args.dim, seed=7)
    ranges, _ = mixed_workload(attrs, args.nq, seed=3)
    print(f"[profile] building RNSG index (n={args.n}) ...")
    idx = RNSGIndex.build(vecs, attrs, m=16)

    # warm every dispatch shape OUTSIDE the trace so the capture holds
    # steady-state kernels, not one-off jit compilation
    for bw in (1, 4):
        idx.search(qv, ranges, k=args.k, ef=args.ef, plan="auto",
                   beam_width=bw)

    print(f"[profile] capturing device trace into {log_dir}")
    with device_trace(log_dir):
        for bw in (1, 4):
            res = idx.search(qv, ranges, k=args.k, ef=args.ef, plan="auto",
                             beam_width=bw)
            np.asarray(res.ids)        # block so device work lands in-trace
    print(f"[profile] done — view with: tensorboard --logdir {log_dir}")


if __name__ == "__main__":
    main()
