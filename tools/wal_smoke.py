#!/usr/bin/env python
"""CI smoke for the durability stack: WAL append/replay, the checkpoint
barrier + segment GC lifecycle, a sampled crash-point sweep, torn-tail
truncation, and read-only degradation on append failure.

    PYTHONPATH=src python tools/wal_smoke.py

Exit code 0 = every assertion held.  This drives the real streaming
index + WAL (``repro.streaming``) end to end — mutate, crash, recover,
compare bit-for-bit against a never-crashed oracle — so it catches
wiring regressions anywhere on the append -> checkpoint -> replay path.
The exhaustive every-op sweep lives in tests/test_wal.py; this smoke
samples crash points to stay fast enough for CI.
"""
import os
import sys
import tempfile
import warnings

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.index import io as iio                           # noqa: E402
from repro.streaming import (CrashOps, InjectedCrash,       # noqa: E402
                             ReadOnlyIndexError, StreamingRFANN, WALError)
from repro.streaming import wal as walmod                   # noqa: E402

BUILD = dict(m=8, ef_spatial=8, ef_attribute=8)
N0, D = 32, 8


def check(cond, msg):
    if not cond:
        print(f"[wal-smoke] FAIL: {msg}")
        sys.exit(1)
    print(f"[wal-smoke] ok: {msg}")


def corpus():
    rng = np.random.default_rng(3)
    return (rng.standard_normal((N0, D)).astype(np.float32),
            rng.standard_normal(N0).astype(np.float32))


def muts():
    """Deterministic mutation script: inserts from ext_id 1000, a couple
    of deletes, and one explicit checkpoint ("C")."""
    rng = np.random.default_rng(9)
    ops = []
    for i in range(6):
        ops.append(("I", 1000 + i,
                    rng.standard_normal(D).astype(np.float32),
                    float(rng.standard_normal())))
    ops.append(("D", 2))
    ops.append(("C",))
    for i in range(6, 10):
        ops.append(("I", 1000 + i,
                    rng.standard_normal(D).astype(np.float32),
                    float(rng.standard_normal())))
    ops.append(("D", 1003))
    return ops


def apply_muts(idx, script):
    for op in script:
        if op[0] == "I":
            idx.insert(op[2], op[3], ext_id=op[1])
        elif op[0] == "D":
            idx.delete(op[1])
        else:
            idx.checkpoint()


def state_of(idx):
    flat, meta = iio.index_state(idx)
    return flat, meta["streaming"]["next_id"]


def states_equal(a, b):
    fa, na = a
    fb, nb = b
    if na != nb or set(fa) != set(fb):
        return False
    return all(np.array_equal(fa[k], fb[k]) for k in fa)


def oracle_state(base_ckpt, m, _cache={}):
    """State of a never-crashed index after the first ``m`` *mutations*
    (checkpoints change durability artifacts, not index state)."""
    if m not in _cache:
        ora = iio.load_index(base_ckpt)
        apply_muts(ora, [op for op in muts() if op[0] != "C"][:m])
        _cache[m] = state_of(ora)
    return _cache[m]


def main():
    vecs, attrs = corpus()
    with tempfile.TemporaryDirectory() as td:
        base_ckpt = os.path.join(td, "base")
        iio.save_index(
            StreamingRFANN(vecs, attrs, max_delta=10**9, **BUILD), base_ckpt)

        # --- happy path: churn, checkpoint barrier + GC, clean recover ---
        wd = os.path.join(td, "wal_clean")
        ck = os.path.join(td, "ckpt_clean")
        idx = iio.load_index(base_ckpt)
        idx.attach_wal(wd, sync="batch", segment_bytes=256)
        idx.set_checkpoint_path(ck, ensure=True)
        apply_muts(idx, muts())
        d = walmod.describe(wd)
        check(d["counts"]["barrier"] >= 1, "checkpoint wrote a barrier record")
        check(d["barrier_watermark"] > 0, "barrier carries an LSN watermark")
        n_segs_live = d["segments"]
        idx.checkpoint()
        check(walmod.describe(wd)["segments"] <= n_segs_live,
              "checkpoint GC'd sealed segments behind the watermark")
        want = state_of(idx)
        rec = StreamingRFANN.recover(ck, wd, attach=False)
        check(states_equal(state_of(rec), want),
              "clean recover is bit-identical to the live index")

        # --- sampled crash sweep: every recovered state must equal an
        # acked-prefix oracle (acked or acked+1: the in-flight record may
        # have reached the disk before the crash) ---
        script = muts()
        n_muts = len([op for op in script if op[0] != "C"])
        probe = CrashOps(crash_at=-1)
        wd0 = os.path.join(td, "wal_probe")
        idx = iio.load_index(base_ckpt)
        idx.attach_wal(wd0, sync="always", ops=probe)
        idx.set_checkpoint_path(os.path.join(td, "ckpt_probe"), ensure=True)
        apply_muts(idx, script)
        total = probe.ops
        points = sorted(set(range(1, total, max(1, total // 12))) | {total - 1})
        for t in points:
            wdt = os.path.join(td, f"wal_{t}")
            ckt = os.path.join(td, f"ckpt_{t}")
            idx = iio.load_index(base_ckpt)
            acked = 0
            try:
                idx.attach_wal(wdt, sync="always", ops=CrashOps(crash_at=t))
                idx.set_checkpoint_path(ckt, ensure=True)
                for op in script:
                    apply_muts(idx, [op])
                    acked += op[0] != "C"
            except (InjectedCrash, WALError, ReadOnlyIndexError):
                pass
            if not os.path.isdir(ckt) or not iio.is_index_dir(ckt):
                check(acked == 0, f"crash@{t}: no checkpoint => nothing acked")
                continue
            rec = StreamingRFANN.recover(ckt, wdt, attach=False)
            got = state_of(rec)
            ok = any(states_equal(got, oracle_state(base_ckpt, m))
                     for m in (acked, min(acked + 1, n_muts)))
            check(ok, f"crash@{t}/{total}: recovered == oracle prefix "
                      f"(acked={acked})")

        # --- torn tail: truncate mid-record, replay repairs and resumes ---
        wd = os.path.join(td, "wal_torn")
        ck = os.path.join(td, "ckpt_torn")
        idx = iio.load_index(base_ckpt)
        idx.attach_wal(wd, sync="always")
        idx.set_checkpoint_path(ck, ensure=True)
        apply_muts(idx, [op for op in script if op[0] != "C"])
        seg = walmod.list_segments(wd)[-1]
        with open(seg, "r+b") as f:
            f.truncate(os.path.getsize(seg) - 3)
        rec = StreamingRFANN.recover(ck, wd, attach=False)
        check(states_equal(state_of(rec), oracle_state(base_ckpt, n_muts - 1))
              or states_equal(state_of(rec), oracle_state(base_ckpt, n_muts)),
              "torn tail truncated to last whole record; prefix preserved")

        # --- read-only degradation: append failure must not crash serving ---
        class DeadDisk(walmod.FileOps):
            def write(self, fd, data):
                raise OSError(28, "No space left on device")

        idx = iio.load_index(base_ckpt)
        idx.attach_wal(os.path.join(td, "wal_ro"), sync="always")
        idx._wal.ops = DeadDisk()
        got_ro = False
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            try:
                idx.insert(np.zeros(D, np.float32), 0.0, ext_id=5000)
            except ReadOnlyIndexError:
                got_ro = True
        check(got_ro, "WAL append failure raises ReadOnlyIndexError")
        check(idx.read_only, "index flipped to read-only, not crashed")
        res = idx.search(vecs[:1], np.array([[-10.0, 10.0]], np.float32),
                         k=4, ef=16)
        check(np.asarray(res.ids).shape == (1, 4),
              "read-only index still serves searches")

    print("[wal-smoke] PASS")


if __name__ == "__main__":
    main()
