PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test test-fast verify lint bench-quick bench-planner bench-substrate \
        bench-full quickstart

# tier-1 verify (the command CI runs)
test:
	$(PY) -m pytest -x -q

# alias for the tier-1 command
verify: test

# ruff when available; syntax-check fallback in minimal containers
lint:
	@if $(PY) -c "import ruff" >/dev/null 2>&1; then \
	  $(PY) -m ruff check src tests benchmarks examples; \
	else \
	  echo "[lint] ruff unavailable; falling back to compileall"; \
	  $(PY) -m compileall -q src tests benchmarks examples; \
	fi

# skip the slow multidevice subprocess tests
test-fast:
	$(PY) -m pytest -x -q --ignore=tests/test_multidevice.py

bench-quick:
	$(PY) -m benchmarks.run --only qps_recall,kernels

bench-planner:
	$(PY) -m benchmarks.run --only planner

bench-substrate:
	$(PY) -m benchmarks.run --only search_substrate

bench-full:
	$(PY) -m benchmarks.run --full

quickstart:
	$(PY) examples/quickstart.py
