PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test test-fast verify lint docs-check bench-quick bench-planner \
        bench-substrate bench-mesh bench-cache bench-beam bench-beam-smoke \
        bench-quant bench-quant-smoke bench-stream bench-stream-smoke \
        bench-build bench-build-smoke bench-wal bench-all bench-full \
        quickstart obs-smoke wal-smoke profile

# tier-1 verify (the command CI runs)
test:
	$(PY) -m pytest -x -q

# alias for the tier-1 command
verify: test

# ruff when available; syntax-check fallback in minimal containers
lint:
	@if $(PY) -c "import ruff" >/dev/null 2>&1; then \
	  $(PY) -m ruff check src tests benchmarks examples; \
	else \
	  echo "[lint] ruff unavailable; falling back to compileall"; \
	  $(PY) -m compileall -q src tests benchmarks examples; \
	fi

# fail on broken intra-repo links in README.md / docs/*.md
docs-check:
	$(PY) tools/docs_check.py

# skip the slow multidevice subprocess tests
test-fast:
	$(PY) -m pytest -x -q --ignore=tests/test_multidevice.py

bench-quick:
	$(PY) -m benchmarks.run --only qps_recall,kernels

bench-planner:
	$(PY) -m benchmarks.run --only planner

bench-substrate:
	$(PY) -m benchmarks.run --only search_substrate

# mesh-path strategy routing (re-execs itself with 8 forced host devices)
bench-mesh:
	$(PY) -m benchmarks.run --only mesh_auto

# result cache + async local-path dispatch (results/bench/async_cache.csv)
bench-cache:
	$(PY) -m benchmarks.run --only async_cache

# batched beam expansion sweep (results/bench/beam_width.csv + BENCH_beam.json)
bench-beam:
	$(PY) -m benchmarks.run --only beam_width

# tiny-scale CI smoke of the same sweep (interpret-mode kernels on CPU):
# catches kernel/beam regressions fast without meaningful wall numbers
bench-beam-smoke:
	$(PY) -m benchmarks.run --only beam_width --n 1024

# quantized scoring (int8/bf16 + exact f32 rerank) vs the f32 baseline
# (results/bench/quantized.csv + BENCH_quant.json)
bench-quant:
	$(PY) -m benchmarks.run --only quantized

# tiny-scale CI smoke: asserts int8/bf16 scan id parity vs the f32 oracle
# and the beam recall envelope, all in Pallas interpret mode
bench-quant-smoke:
	$(PY) -m benchmarks.run --only quantized --n 1024

# streaming ingest: QPS/recall vs delta fraction {0,1%,5%,20%} + compaction
# pause p99 (results/bench/streaming.csv + BENCH_stream.json)
bench-stream:
	$(PY) -m benchmarks.run --only streaming

# tiny-scale CI smoke of the same trajectory (interpret-mode kernels)
bench-stream-smoke:
	$(PY) -m benchmarks.run --only streaming --n 1024

# sharded construction + persistence: build wall vs shard count (asserting
# bit-identity to the single-host build per point) and save/restore wall vs
# rebuild (results/bench/build.csv + BENCH_build.json); re-execs itself
# under 8 forced host devices
bench-build:
	$(PY) -m benchmarks.run --only build

# tiny-scale CI smoke of the same trajectory: sharded-parity + directory
# save/restore round-trip under the 8-device re-exec
bench-build-smoke:
	$(PY) -m benchmarks.run --only build --n 1024

# WAL durability cost: insert throughput per sync policy (nowal/none/
# batch/always) + recovery replay wall (results/bench/wal.csv +
# BENCH_wal.json)
bench-wal:
	$(PY) -m benchmarks.run --only wal

# smoke-sized perf trajectory: writes BENCH_substrate.json, BENCH_beam.json
# and BENCH_quant.json at the repo root so the numbers are tracked per PR
bench-all:
	$(PY) -m benchmarks.run --only search_substrate,beam_width,quantized \
	    --n 2048

bench-full:
	$(PY) -m benchmarks.run --full

quickstart:
	$(PY) examples/quickstart.py

# short serve with metrics; asserts the JSON + Prometheus exports parse and
# carry the core metric families (CI runs this)
obs-smoke:
	$(PY) tools/obs_smoke.py

# durability smoke: sampled crash-point sweep, checkpoint barrier + GC,
# torn-tail truncation and read-only degradation, bit-compared against a
# never-crashed oracle (CI runs this)
wal-smoke:
	$(PY) tools/wal_smoke.py

# jax.profiler device trace around a small beam run -> results/profiles/
profile:
	$(PY) tools/profile_capture.py
