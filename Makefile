PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test test-fast bench-quick bench-planner bench-full quickstart

# tier-1 verify (the command CI runs)
test:
	$(PY) -m pytest -x -q

# skip the slow multidevice subprocess tests
test-fast:
	$(PY) -m pytest -x -q --ignore=tests/test_multidevice.py

bench-quick:
	$(PY) -m benchmarks.run --only qps_recall,kernels

bench-planner:
	$(PY) -m benchmarks.run --only planner

bench-full:
	$(PY) -m benchmarks.run --full

quickstart:
	$(PY) examples/quickstart.py
